"""repro.obs — the seeing layer: tracing, metrics, and closed-loop introspection.

Dependency-light modules (importable from anywhere in the repo, no jax at
import time):

  * :mod:`repro.obs.trace`   — hierarchical spans with a ``sync`` knob
    (``block_until_ready`` on declared outputs at span exit, so GPU/TPU
    time is attributed to the span that incurred it), a process-global
    recorder that is a no-op when disabled, and Chrome trace-event JSON
    export that opens in Perfetto — one lane per phase (plan / build /
    fixpoint / select / ring / repair / query);
  * :mod:`repro.obs.metrics` — counters, gauges, and streaming histograms
    (p50/p95/p99 without storing samples) behind a named registry, exported
    as a JSONL snapshot; snapshots from separate processes merge without
    sample loss (``MetricsRegistry.merge`` / ``from_jsonl``);
  * :mod:`repro.obs.shardprof` — measured per-shard, per-ring-step profiles
    from serial/mesh builds and fixpoints, comparable to the planner's
    predicted ``PlanStats`` (the ``partition.predicted_vs_measured_*``
    gauges close the plan-vs-reality loop);
  * :mod:`repro.obs.slo`     — per-query-class latency budgets with
    rolling-window p99 evaluation, breach counters, and a breach callback;
  * :mod:`repro.obs.flight`  — an always-on bounded ring of recent spans,
    dumped to Perfetto-loadable JSON on engine exception or SLO breach
    (importing this package installs its span listener);
  * :mod:`repro.obs.report`  — a self-contained HTML perf report stitching
    the BENCH records, phase breakdown, shard skew, and SLO state.

Drivers expose tracing/metrics via ``--trace OUT.json`` /
``--metrics OUT.jsonl``; see docs/observability.md.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               counter, gauge, histogram, load_jsonl,
                               registry)
from repro.obs.trace import (PHASES, Recorder, Span, add_span_listener,
                             get_recorder, remove_span_listener, span,
                             traced, tracing_enabled)
# importing flight installs the always-on span listener (bounded ring)
from repro.obs.flight import FlightRecorder, get_flight_recorder
from repro.obs.slo import SLOConfig, SLOWatchdog
from repro.obs.shardprof import (MeasuredProfile, ShardProfiler,
                                 last_profile, profiles)

__all__ = [
    "PHASES", "Recorder", "Span", "get_recorder", "span", "traced",
    "tracing_enabled", "add_span_listener", "remove_span_listener",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "counter", "gauge",
    "histogram", "load_jsonl", "registry",
    "FlightRecorder", "get_flight_recorder",
    "SLOConfig", "SLOWatchdog",
    "MeasuredProfile", "ShardProfiler", "last_profile", "profiles",
]
