"""repro.obs — the seeing layer: tracing + metrics for every execution path.

Two dependency-free modules (importable from anywhere in the repo, no jax
at import time):

  * :mod:`repro.obs.trace`   — hierarchical spans with a ``sync`` knob
    (``block_until_ready`` on declared outputs at span exit, so GPU/TPU
    time is attributed to the span that incurred it), a process-global
    recorder that is a no-op when disabled, and Chrome trace-event JSON
    export that opens in Perfetto — one lane per phase (plan / build /
    fixpoint / select / ring / repair / query);
  * :mod:`repro.obs.metrics` — counters, gauges, and streaming histograms
    (p50/p95/p99 without storing samples) behind a named registry, exported
    as a JSONL snapshot.

Drivers expose both via ``--trace OUT.json`` / ``--metrics OUT.jsonl``
(``python -m repro im|serve``); see docs/observability.md.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               counter, gauge, histogram, load_jsonl,
                               registry)
from repro.obs.trace import (PHASES, Recorder, Span, get_recorder, span,
                             traced, tracing_enabled)

__all__ = [
    "PHASES", "Recorder", "Span", "get_recorder", "span", "traced",
    "tracing_enabled",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "counter", "gauge",
    "histogram", "load_jsonl", "registry",
]
