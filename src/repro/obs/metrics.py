"""Counters, gauges, and streaming histograms behind a named registry.

The numbers the repo's perf story argues from — ring bytes and bucket loads
from the partition planner, repair sweep counts and dirty-shard fractions
from delta repair, per-query-class latency and memo hit-rate from the
engine, bank build time from the store — all land here, in one process-wide
:class:`MetricsRegistry`, and export as a JSONL snapshot (one JSON object
per line, the ``name``/``kind``/value schema :mod:`benchmarks.trend`
consumes).

Histograms are streaming: geometric buckets (growth factor 1.04, i.e.
~2% relative resolution) hold counts only, so p50/p95/p99 come out of a
few hundred integers regardless of sample count — no sample storage, no
numpy dependency.

Dependency-free and import-cycle-safe, like :mod:`repro.obs.trace`.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, Optional, Tuple

_GROWTH = 1.04               # bucket growth factor: <= ~2% relative error
_LOG_GROWTH = math.log(_GROWTH)
_V0 = 1e-9                   # smallest resolvable magnitude (1 ns in seconds)
#: Nudge on the (log-space) bucket index so a value sitting exactly on a
#: bucket boundary ``_V0 * G^i`` always lands in bucket ``i``. Without it,
#: ``log(v / _V0) / log(G)`` comes out as ``i - 1e-16`` for ~5% of indices
#: (libm rounding) and the value mis-buckets one slot low — the bucket-
#: alignment bug that made two processes disagree about the same observation
#: when their snapshots were merged.
_IDX_EPS = 1e-9


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def merge(self, other: "Counter") -> None:
        """Fold another process's count in (counts are additive)."""
        self.value += other.value

    def summary(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-written value (residency, imbalance, bytes...)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def merge(self, other: "Gauge") -> None:
        """Gauges are last-writer-wins: the merged-in snapshot is treated as
        newer (merge order is the caller's timeline)."""
        self.value = other.value

    def summary(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Streaming distribution: p50/p95/p99 without storing samples.

    Values are assigned to geometric buckets ``[_V0 * G^i, _V0 * G^(i+1))``;
    a percentile query walks the cumulative counts and returns the matched
    bucket's geometric midpoint, so the answer is within one bucket width
    (~2% relative) of the exact order statistic. Non-positive values share
    one underflow bucket reported as 0.0.
    """

    __slots__ = ("buckets", "count", "total", "min", "max", "unit")
    kind = "histogram"

    def __init__(self, unit: str = ""):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.unit = unit

    @staticmethod
    def _index(v: float) -> int:
        if v <= _V0:
            return -1          # underflow bucket (zeros, negatives)
        # _IDX_EPS keeps exact bucket-boundary values in their own bucket
        # (int() truncation + libm rounding shifted them one slot low)
        return int(math.log(v / _V0) / _LOG_GROWTH + _IDX_EPS)

    @staticmethod
    def _midpoint(idx: int) -> float:
        if idx < 0:
            return 0.0
        return _V0 * math.exp((idx + 0.5) * _LOG_GROWTH)

    def observe(self, v: float) -> None:
        v = float(v)
        idx = self._index(v)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100])."""
        if self.count == 0:
            return 0.0
        # nearest-rank on the cumulative bucket counts; exact min/max at the
        # extremes so p0/p100 round-trip the observed range
        if q <= 0:
            return self.min
        if q >= 100:
            return self.max
        rank = q / 100.0 * self.count
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                mid = self._midpoint(idx)
                return min(max(mid, self.min), self.max)
        return self.max  # pragma: no cover - rank <= count always hits

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram in without sample loss: bucket counts are
        added index-by-index (both sides use the identical geometric grid,
        so no re-binning — and no resolution loss — ever happens), and
        count/sum/min/max combine exactly. Percentiles of the merged
        histogram match a single histogram that observed both streams."""
        for idx, cnt in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + cnt
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        if not self.unit:
            self.unit = other.unit

    def summary(self) -> dict:
        # "buckets" carries the raw geometric-grid counts (keys are bucket
        # indices as strings — JSON object keys), which is what makes a
        # JSONL snapshot mergeable without sample loss
        return {"count": self.count, "sum": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "buckets": {str(i): c for i, c in sorted(self.buckets.items())}}

    @classmethod
    def from_summary(cls, rec: dict) -> "Histogram":
        """Reconstruct from a :meth:`summary`-shaped dict (a ``load_jsonl``
        row). Rows written before bucket serialization existed degrade to a
        single bucket at the mean (count/sum stay exact)."""
        h = cls(unit=rec.get("unit", ""))
        h.count = int(rec.get("count", 0))
        h.total = float(rec.get("sum", 0.0))
        if h.count:
            h.min = float(rec.get("min", 0.0))
            h.max = float(rec.get("max", 0.0))
        buckets = rec.get("buckets")
        if buckets is None and h.count:
            buckets = {str(cls._index(h.total / h.count)): h.count}
        for idx, cnt in (buckets or {}).items():
            h.buckets[int(idx)] = int(cnt)
        return h


_MetricKey = Tuple[str, Tuple[Tuple[str, str], ...]]


class MetricsRegistry:
    """Named, tag-aware metric store. ``counter``/``gauge``/``histogram``
    are get-or-create (same name+tags -> same instance), so call sites
    don't thread metric objects around."""

    def __init__(self):
        self._metrics: Dict[_MetricKey, object] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(name: str, tags: dict) -> _MetricKey:
        return name, tuple(sorted((str(k), str(v)) for k, v in tags.items()))

    def _get(self, cls, name: str, tags: dict, **kw):
        key = self._key(name, tags)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(**kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, **tags) -> Counter:
        return self._get(Counter, name, tags)

    def gauge(self, name: str, **tags) -> Gauge:
        return self._get(Gauge, name, tags)

    def histogram(self, name: str, *, unit: str = "", **tags) -> Histogram:
        return self._get(Histogram, name, tags, unit=unit)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- merge (multi-process aggregation) ---------------------------------

    def merge(self, rows) -> int:
        """Fold a snapshot into this registry: ``rows`` is either another
        :class:`MetricsRegistry` or an iterable of ``load_jsonl`` rows.
        Counters add, gauges take the merged-in value, histograms combine
        bucket-exact (no sample loss) — this is how per-process metrics from
        mesh workers or separate CI jobs aggregate into one view. Returns
        the number of series merged."""
        if isinstance(rows, MetricsRegistry):
            rows = rows.snapshot()
        n = 0
        for rec in rows:
            name, tags = rec["name"], rec.get("tags", {})
            kind = rec.get("kind", "counter")
            if kind == "counter":
                other = Counter()
                other.value = rec.get("value", 0)
                self.counter(name, **tags).merge(other)
            elif kind == "gauge":
                other = Gauge()
                other.value = float(rec.get("value", 0.0))
                self.gauge(name, **tags).merge(other)
            elif kind == "histogram":
                other = Histogram.from_summary(rec)
                self.histogram(name, unit=other.unit, **tags).merge(other)
            else:
                raise ValueError(f"unknown metric kind in snapshot: {kind!r}")
            n += 1
        return n

    @classmethod
    def from_jsonl(cls, *paths: str) -> "MetricsRegistry":
        """Build a registry by merging one or more JSONL snapshots (the
        per-process files mesh/CI jobs write via ``--metrics``)."""
        reg = cls()
        for path in paths:
            reg.merge(load_jsonl(path))
        return reg

    # -- export ------------------------------------------------------------

    def snapshot(self) -> Iterable[dict]:
        """One JSON-ready dict per metric: ``{"name", "kind", "tags",
        **summary}`` (histograms add ``unit`` and the percentile fields)."""
        with self._lock:
            items = list(self._metrics.items())
        out = []
        for (name, tags), m in sorted(items, key=lambda kv: kv[0]):
            rec = {"name": name, "kind": m.kind, "tags": dict(tags)}
            if isinstance(m, Histogram) and m.unit:
                rec["unit"] = m.unit
            rec.update(m.summary())
            out.append(rec)
        return out

    def write_jsonl(self, path: str) -> int:
        """Append-free JSONL snapshot (one metric per line); returns the
        metric count written. The schema matches what
        ``benchmarks/trend.py`` can diff across CI runs."""
        snap = list(self.snapshot())
        with open(path, "w") as f:
            for rec in snap:
                f.write(json.dumps(rec) + "\n")
        return len(snap)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global default registry all repo call sites use."""
    return _REGISTRY


def counter(name: str, **tags) -> Counter:
    return _REGISTRY.counter(name, **tags)


def gauge(name: str, **tags) -> Gauge:
    return _REGISTRY.gauge(name, **tags)


def histogram(name: str, *, unit: str = "", **tags) -> Histogram:
    return _REGISTRY.histogram(name, unit=unit, **tags)


def load_jsonl(path: str) -> list:
    """Read a snapshot written by :meth:`MetricsRegistry.write_jsonl`."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
