"""Hierarchical, device-sync-aware tracing with Chrome/Perfetto export.

DiFuseR's claims are throughput claims, and JAX makes throughput easy to
misreport: dispatch returns before the device finishes, so a bare
``perf_counter`` pair around a jitted call measures queueing, not execution.
Spans fix that with a ``sync`` knob — outputs declared on a span (up front
via ``span(..., sync=out)`` or at runtime via ``sp.sync(value)``) get
``jax.block_until_ready`` called on them *inside* the span, at exit, so
device time is attributed to the span that incurred it.

Design constraints:

  * **Zero-dependency**: nothing here imports jax (or numpy) at module
    load; ``block_until_ready`` is imported lazily only when a live span
    actually has outputs to sync. The module is importable anywhere in the
    repo without cycles.
  * **No-op when disabled** (< 2% overhead target): with the recorder off,
    ``span(...)`` returns one shared ``_NULL_SPAN`` singleton — no
    allocation, no timestamps, no syncing. Callers that need wall time
    regardless (the engine's latency accounting, benchmarks) pass
    ``timed=True`` and always get a real measuring span; it just skips the
    recording step while the recorder is off.
  * **One lane per phase**: every span carries a ``phase`` (one of
    :data:`PHASES`); the Chrome-trace export maps each phase to its own
    ``tid`` so Perfetto renders plan / build / fixpoint / select / ring /
    repair / query work as distinct lanes. Spans with no explicit phase
    inherit the enclosing span's (thread-local stack), else ``"other"``.

Usage::

    from repro.obs import trace
    with trace.span("store.build_bank", phase="build", bank=b) as sp:
        m = sp.sync(backend.build_matrix(...))   # blocks at span exit

    rec = trace.get_recorder()
    rec.start(); ...workload...; rec.stop()
    rec.save_chrome_trace("trace.json")          # open in ui.perfetto.dev
"""
from __future__ import annotations

import functools
import json
import threading
import time
from typing import Any, Dict, List, Optional

#: Fixed lane order of the Perfetto view; index == Chrome-trace ``tid``.
PHASES = ("plan", "build", "fixpoint", "select", "ring", "repair", "query",
          "other")
_PHASE_TID = {p: i for i, p in enumerate(PHASES)}


def _block_until_ready(value):
    """Lazy ``jax.block_until_ready`` — pytree-aware, and a no-op for
    leaves (numpy arrays, floats, plain objects) with no such method."""
    try:
        import jax
    except Exception:  # pragma: no cover - jax-less environment
        return value
    try:
        return jax.block_until_ready(value)
    except Exception:
        # unregistered containers (dataclasses...) are opaque leaves to the
        # pytree walk — best-effort sync their array attributes instead
        for attr in getattr(value, "__dict__", {}).values():
            if hasattr(attr, "block_until_ready"):
                attr.block_until_ready()
        return value


class _NullSpan:
    """The shared do-nothing span handed out while tracing is disabled.

    Identity is the no-op contract: ``span(...) is span(...)`` whenever the
    recorder is off (tested), so the disabled path allocates nothing.
    """

    __slots__ = ()
    duration_s = 0.0
    name = phase = ""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync(self, value):
        return value

    def annotate(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """One live timed region. Use via :func:`span`, not directly."""

    __slots__ = ("name", "phase", "attrs", "t0", "t1", "depth", "_outputs",
                 "_recorder")

    def __init__(self, recorder: Optional["Recorder"], name: str,
                 phase: Optional[str], sync_value, attrs: Dict[str, Any]):
        self.name = name
        self.phase = phase
        self.attrs = attrs
        self._outputs: List[Any] = [] if sync_value is None else [sync_value]
        self._recorder = recorder    # None: timed-only, nothing recorded
        self.t0 = self.t1 = 0.0
        self.depth = 0

    @property
    def duration_s(self) -> float:
        """Wall seconds (valid after ``__exit__``; includes device sync)."""
        return self.t1 - self.t0

    def sync(self, value):
        """Declare ``value`` (any pytree of arrays) as an output of this
        span: ``block_until_ready`` runs on it at span exit, so the device
        work it represents lands inside the span. Returns ``value``."""
        self._outputs.append(value)
        return value

    def annotate(self, **attrs) -> "Span":
        """Attach extra key/values to the span's Chrome-trace ``args``."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = _STACK.spans
        if self.phase is None:
            self.phase = stack[-1].phase if stack else "other"
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        try:
            if self._outputs:
                for out in self._outputs:
                    _block_until_ready(out)
        finally:
            self.t1 = time.perf_counter()
            stack = _STACK.spans
            if stack and stack[-1] is self:
                stack.pop()
            if self._recorder is not None:
                self._recorder._add(self)
            for fn in _SPAN_LISTENERS:
                try:
                    fn(self)
                except Exception:   # noqa: BLE001 — observers must not break
                    pass            # the observed workload
        return False


class _SpanStack(threading.local):
    def __init__(self):
        self.spans: List[Span] = []


_STACK = _SpanStack()

#: Completion listeners: called with every *real* span (recorded or
#: ``timed=True``) right after its ``__exit__`` timestamps settle. This is
#: the flight recorder's tap — it sees measuring spans even while the main
#: recorder is off. Null spans never reach listeners, so the
#: tracing-disabled fast path stays allocation-free.
_SPAN_LISTENERS: List = []


def add_span_listener(fn) -> None:
    """Register ``fn(span)`` to run at every real span completion. Listeners
    must be cheap and must not raise (exceptions are swallowed — a broken
    observer must never break the observed workload)."""
    if fn not in _SPAN_LISTENERS:
        _SPAN_LISTENERS.append(fn)


def remove_span_listener(fn) -> None:
    if fn in _SPAN_LISTENERS:
        _SPAN_LISTENERS.remove(fn)


class Recorder:
    """Process-global span sink. Disabled by default; ``start()`` clears and
    begins collecting, ``stop()`` freezes. Thread-safe appends."""

    def __init__(self):
        self.enabled = False
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "Recorder":
        with self._lock:
            self._events.clear()
            self._epoch = time.perf_counter()
            self.enabled = True
        return self

    def stop(self) -> "Recorder":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def _add(self, sp: Span) -> None:
        ev = {"name": sp.name, "phase": sp.phase or "other",
              "ts_s": sp.t0 - self._epoch, "dur_s": sp.t1 - sp.t0,
              "depth": sp.depth, "attrs": sp.attrs}
        with self._lock:
            self._events.append(ev)

    # -- inspection --------------------------------------------------------

    def events(self) -> List[dict]:
        """Recorded span dicts (name/phase/ts_s/dur_s/depth/attrs), in
        completion order (children complete before parents)."""
        with self._lock:
            return list(self._events)

    def phases_seen(self) -> set:
        return {ev["phase"] for ev in self.events()}

    def top_level_seconds(self) -> float:
        """Total seconds inside depth-0 spans — the numerator of the
        "spans account for >= X% of wall time" acceptance check."""
        return sum(ev["dur_s"] for ev in self.events() if ev["depth"] == 0)

    # -- export ------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable): one
        complete ("ph": "X") event per span, one thread lane per phase."""
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "repro"}},
        ]
        used = sorted(self.phases_seen(), key=lambda p: _PHASE_TID.get(p, 99))
        for p in used:
            tid = _PHASE_TID.get(p, len(PHASES))
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": p}})
            events.append({"ph": "M", "name": "thread_sort_index", "pid": 0,
                           "tid": tid, "args": {"sort_index": tid}})
        for ev in self.events():
            args = {k: _jsonable(v) for k, v in ev["attrs"].items()}
            args["depth"] = ev["depth"]
            events.append({
                "ph": "X", "name": ev["name"], "pid": 0,
                "tid": _PHASE_TID.get(ev["phase"], len(PHASES)),
                "ts": round(ev["ts_s"] * 1e6, 3),
                "dur": round(ev["dur_s"] * 1e6, 3),
                "cat": ev["phase"], "args": args})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str) -> int:
        """Write the Chrome-trace JSON; returns the span count written."""
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return sum(1 for e in trace["traceEvents"] if e["ph"] == "X")


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)          # numpy ints
    except (TypeError, ValueError):
        try:
            return float(v)    # numpy floats
        except (TypeError, ValueError):
            return str(v)


_RECORDER = Recorder()


def get_recorder() -> Recorder:
    """The process-global recorder every :func:`span` reports to."""
    return _RECORDER


def tracing_enabled() -> bool:
    return _RECORDER.enabled


def span(name: str, *, phase: Optional[str] = None, sync=None,
         timed: bool = False, **attrs):
    """Open a traced region (context manager).

    ``phase`` picks the Perfetto lane (:data:`PHASES`; ``None`` inherits
    the enclosing span's). ``sync`` declares an output pytree up front;
    ``sp.sync(value)`` declares more at runtime — all get
    ``block_until_ready`` at span exit. ``timed=True`` forces a real
    measuring span (``sp.duration_s`` valid, outputs synced) even while the
    recorder is disabled — for callers whose latency accounting must not
    depend on tracing; everyone else gets the free ``_NULL_SPAN``."""
    if not _RECORDER.enabled:
        if not timed:
            return _NULL_SPAN
        return Span(None, name, phase, sync, attrs)
    return Span(_RECORDER, name, phase, sync, attrs)


def traced(name: Optional[str] = None, *, phase: Optional[str] = None):
    """Decorator form of :func:`span` for whole-function regions::

        @traced("partition.build_buckets", phase="plan")
        def build_partition_2d(...): ...

    Same no-op-when-disabled contract as :func:`span`."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with span(label, phase=phase):
                return fn(*args, **kwargs)
        return wrapper
    return deco
