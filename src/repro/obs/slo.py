"""SLO watchdog: per-query-class latency budgets with rolling-window p99.

The serving north star ("millions of users") needs more than latency
histograms — it needs the process to *know*, while running, that a query
class is out of budget, count it, and trigger capture. This module is that
loop: the engine feeds every batch latency into :class:`SLOWatchdog`;
the watchdog keeps a small rolling window per class, evaluates the
nearest-rank p99 against the class budget once the window has enough
samples, publishes ``slo.window_p99_ms`` gauges and ``slo.breaches``
counters, and fires a breach callback on the *rising edge* (ok -> breached)
— by default the flight recorder's dump, so a breach leaves behind an
openable Perfetto file of the offending window.

Budgets come from engine config or ``RunSpec.slo`` (a tuple of
``(query_class, p99_ms)`` pairs — tuple-of-tuples so the spec stays
hashable/frozen). Classes with no budget are observed but never breach.

Dependency-free (stdlib only), like the rest of ``repro.obs``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

from repro.obs import metrics

#: Budgets accepted anywhere: mapping, RunSpec-style tuple pairs, or config.
BudgetsLike = Union[Mapping[str, float], Sequence[Tuple[str, float]],
                    "SLOConfig", None]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Latency objectives for the engine.

    ``budgets`` maps query class -> p99 budget in **milliseconds** (ms is
    the unit operators quote; the engine's histograms stay in seconds).
    ``window`` bounds the rolling sample window per class; ``min_samples``
    gates evaluation so a cold class can't breach off two slow warmup
    batches.
    """

    budgets: Tuple[Tuple[str, float], ...] = ()
    window: int = 256
    min_samples: int = 20

    @classmethod
    def coerce(cls, obj: BudgetsLike) -> Optional["SLOConfig"]:
        """Normalize any budget spelling to an ``SLOConfig`` (None -> None,
        empty budgets -> None: no objectives, no watchdog)."""
        if obj is None or isinstance(obj, SLOConfig):
            return obj if (obj is None or obj.budgets) else None
        if isinstance(obj, Mapping):
            pairs = tuple(sorted((str(k), float(v)) for k, v in obj.items()))
        else:
            pairs = tuple(sorted((str(k), float(v)) for k, v in obj))
        return cls(budgets=pairs) if pairs else None

    def budget_ms(self, qclass: str) -> Optional[float]:
        for name, ms in self.budgets:
            if name == qclass:
                return ms
        return None


class SLOWatchdog:
    """Rolling-window p99 evaluation against per-class budgets.

    ``observe(qclass, latency_s)`` is the engine's single entry point; it is
    O(window) only at evaluation (a sort of <= ``window`` floats), which is
    noise next to the device work each sample represents.

    ``on_breach(qclass, p99_ms, budget_ms, watchdog)`` fires on the rising
    edge per class — once per excursion, not per sample — and again only
    after the class recovers (p99 back under budget). Callback exceptions
    are swallowed: an observer must never take down the serving path.
    """

    def __init__(self, config: BudgetsLike,
                 on_breach: Optional[Callable] = None):
        cfg = SLOConfig.coerce(config)
        self.config = cfg if cfg is not None else SLOConfig()
        self.on_breach = on_breach
        self._windows: Dict[str, deque] = {}
        self._breached: Dict[str, bool] = {}
        self.breach_count = 0

    def observe(self, qclass: str, latency_s: float) -> bool:
        """Record one batch latency; returns True when this sample put the
        class into breach (the rising edge)."""
        budget_ms = self.config.budget_ms(qclass)
        win = self._windows.get(qclass)
        if win is None:
            win = self._windows[qclass] = deque(maxlen=self.config.window)
        win.append(float(latency_s))
        if len(win) < self.config.min_samples:
            return False
        p99_ms = self.window_p99_ms(qclass)
        metrics.gauge("slo.window_p99_ms", qclass=qclass).set(p99_ms)
        if budget_ms is None:
            return False
        breached = p99_ms > budget_ms
        rising = breached and not self._breached.get(qclass, False)
        self._breached[qclass] = breached
        if rising:
            self.breach_count += 1
            metrics.counter("slo.breaches", qclass=qclass).inc()
            metrics.gauge("slo.breach_excess_ms", qclass=qclass).set(
                p99_ms - budget_ms)
            if self.on_breach is not None:
                try:
                    self.on_breach(qclass, p99_ms, budget_ms, self)
                except Exception:  # noqa: BLE001 — observers must not break
                    pass           # the serving path
        return rising

    def window_p99_ms(self, qclass: str) -> float:
        """Nearest-rank p99 (in ms) over the class's current window."""
        win = self._windows.get(qclass)
        if not win:
            return 0.0
        ordered = sorted(win)
        rank = max(int(0.99 * len(ordered) + 0.999999) - 1, 0)
        return ordered[min(rank, len(ordered) - 1)] * 1e3

    def in_breach(self, qclass: str) -> bool:
        return self._breached.get(qclass, False)

    def summary(self) -> dict:
        """Per-class state for the perf report / engine stats."""
        out = {}
        for qclass, win in self._windows.items():
            budget = self.config.budget_ms(qclass)
            out[qclass] = {
                "samples": len(win),
                "window_p99_ms": self.window_p99_ms(qclass),
                "budget_ms": budget,
                "in_breach": self._breached.get(qclass, False),
            }
        out["_breach_count"] = self.breach_count
        return out
