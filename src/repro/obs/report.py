"""Self-contained HTML perf report — one file, no external assets.

Stitches the run's four evidence streams into a single page CI can upload
next to the BENCH artifacts:

  * headline stat tiles (seeds/sec per backend, serving qps, p99, SLO
    breaches) from ``BENCH_runtime.json`` / ``BENCH_service.json`` records;
  * a phase breakdown (bars) from the trace recorder's spans — where the
    wall time of the run actually went, by Perfetto lane;
  * predicted-vs-measured shard skew from :mod:`repro.obs.shardprof` —
    per-shard relative load bars for the latest profile plus an
    imbalance table over every captured profile;
  * the async admission pipeline's health (queue depth over time,
    deadline-miss rate, eviction churn, swap latency) from the service
    record's ``async`` blob + the metrics registry;
  * the SLO watchdog summary (per-class window p99 vs budget, status);
  * the kernel-tuning table from the :mod:`repro.tune` cache — per
    workload key, the config that measured fastest, default vs tuned
    time, achieved GB/s and fraction of the bandwidth roof.

Everything renders as inline SVG/CSS (system sans, no scripts, no network),
so the report opens anywhere — including the CI artifact viewer. Charts
follow the repo-wide viz conventions: single-hue marks with values at the
bar tips, text in ink tokens (never the series color), native ``<title>``
tooltips on every mark, light/dark via ``prefers-color-scheme``.

Entry points: :func:`write_report` (explicit data), and
:func:`write_report_from_artifacts` (reads the ``BENCH_*`` files
``benchmarks/run.py --fast`` just wrote, plus the live recorder/registry/
profile ring — what the harness calls).
"""
from __future__ import annotations

import html
import json
import os
from typing import Iterable, List, Optional

# Reference data-viz palette (validated: see docs/observability.md). Light
# and dark values swap via CSS custom properties; marks use series slots,
# text always uses ink tokens.
_CSS = """
:root {
  color-scheme: light;
  --surface: #fcfcfb; --page: #f9f9f7;
  --ink: #0b0b0b; --ink-2: #52514e; --ink-muted: #898781;
  --grid: #e1e0d9; --axis: #c3c2b7;
  --border: rgba(11,11,11,0.10);
  --s1: #2a78d6; --s2: #eb6834; --s3: #1baf7a;
  --good: #0ca30c; --warning: #fab219; --critical: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  :root {
    color-scheme: dark;
    --surface: #1a1a19; --page: #0d0d0d;
    --ink: #ffffff; --ink-2: #c3c2b7; --ink-muted: #898781;
    --grid: #2c2c2a; --axis: #383835;
    --border: rgba(255,255,255,0.10);
    --s1: #3987e5; --s2: #d95926; --s3: #199e70;
  }
}
* { box-sizing: border-box; }
body { margin: 0; padding: 24px; background: var(--page); color: var(--ink);
       font: 14px/1.45 system-ui, -apple-system, "Segoe UI", sans-serif; }
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 15px; margin: 0 0 8px; }
.sub { color: var(--ink-2); margin: 0 0 20px; }
.card { background: var(--surface); border: 1px solid var(--border);
        border-radius: 8px; padding: 16px 18px; margin-bottom: 16px; }
.tiles { display: flex; flex-wrap: wrap; gap: 16px; margin-bottom: 16px; }
.tile { background: var(--surface); border: 1px solid var(--border);
        border-radius: 8px; padding: 14px 18px; min-width: 150px; }
.tile .label { color: var(--ink-2); font-size: 12px; }
.tile .value { font-size: 26px; font-weight: 600; margin-top: 2px; }
.tile .hint { color: var(--ink-muted); font-size: 11px; margin-top: 2px; }
table { border-collapse: collapse; width: 100%; font-size: 13px; }
th { text-align: left; color: var(--ink-2); font-weight: 500;
     border-bottom: 1px solid var(--axis); padding: 4px 10px 4px 0; }
td { border-bottom: 1px solid var(--grid); padding: 4px 10px 4px 0;
     font-variant-numeric: tabular-nums; }
.status { display: inline-flex; align-items: center; gap: 6px; }
.status .dot { width: 9px; height: 9px; border-radius: 50%; }
svg text { font: 12px system-ui, -apple-system, "Segoe UI", sans-serif;
           fill: var(--ink-2); }
svg .val { fill: var(--ink); }
svg .muted { fill: var(--ink-muted); font-size: 11px; }
.empty { color: var(--ink-muted); font-style: italic; }
"""


def _esc(s) -> str:
    return html.escape(str(s), quote=True)


def _fmt(v, digits: int = 2) -> str:
    """Compact numeric formatting for labels (1,284 / 12.9K / 4.2M)."""
    try:
        v = float(v)
    except (TypeError, ValueError):
        return _esc(v)
    a = abs(v)
    if a >= 1e9:
        return f"{v / 1e9:.1f}G"
    if a >= 1e6:
        return f"{v / 1e6:.1f}M"
    if a >= 1e4:
        return f"{v / 1e3:.1f}K"
    if a >= 100 or v == int(v):
        return f"{v:,.0f}"
    return f"{v:.{digits}f}"


def _bar_path(x: float, y: float, w: float, h: float, r: float = 4.0) -> str:
    """Horizontal bar: square at the baseline (left), 4px rounded data end
    (right). Degrades to square ends when the bar is shorter than the
    radius."""
    r = min(r, w / 2, h / 2)
    if r <= 0.5:
        return (f"M{x:.1f},{y:.1f} h{w:.1f} v{h:.1f} h{-w:.1f} Z")
    return (f"M{x:.1f},{y:.1f} h{w - r:.1f} "
            f"a{r:.1f},{r:.1f} 0 0 1 {r:.1f},{r:.1f} "
            f"v{h - 2 * r:.1f} "
            f"a{r:.1f},{r:.1f} 0 0 1 {-r:.1f},{r:.1f} "
            f"h{-(w - r):.1f} Z")


def _hbar_chart(rows, *, unit: str = "", color: str = "var(--s1)",
                width: int = 720) -> str:
    """Horizontal bar chart: rows = [(label, value, tooltip)]. Single
    series (no legend — the section title names it); value at each bar tip,
    ink-colored; native <title> tooltip per mark."""
    rows = [(str(l), max(float(v), 0.0), t) for l, v, t in rows]
    if not rows or all(v == 0 for _, v, _ in rows):
        return '<p class="empty">no data captured</p>'
    vmax = max(v for _, v, _ in rows)
    bar_h, gap, label_w, val_w = 18, 8, 150, 80
    plot_w = width - label_w - val_w
    height = len(rows) * (bar_h + gap) + 6
    parts = [f'<svg viewBox="0 0 {width} {height}" width="100%" '
             f'role="img" aria-label="bar chart">']
    # hairline baseline the bars grow from
    parts.append(f'<line x1="{label_w}" y1="0" x2="{label_w}" '
                 f'y2="{height - 4}" stroke="var(--axis)" stroke-width="1"/>')
    y = 3.0
    for label, v, tip in rows:
        w = plot_w * (v / vmax) if vmax > 0 else 0.0
        parts.append(f'<text x="{label_w - 8}" y="{y + bar_h - 5}" '
                     f'text-anchor="end">{_esc(label)}</text>')
        parts.append(f'<path d="{_bar_path(label_w + 1, y, max(w, 1.5), bar_h)}" '
                     f'fill="{color}"><title>{_esc(tip)}</title></path>')
        parts.append(f'<text class="val" x="{label_w + max(w, 1.5) + 7}" '
                     f'y="{y + bar_h - 5}">{_fmt(v)}{_esc(unit)}</text>')
        y += bar_h + gap
    parts.append("</svg>")
    return "".join(parts)


def _grouped_shard_chart(shard_rel: List[float], *, width: int = 720) -> str:
    """Per-shard relative-load columns (load / mean) with the 1.0x line —
    the straggler view. Single series; the mean line is chart chrome."""
    if not shard_rel:
        return '<p class="empty">no shard profile captured</p>'
    n = len(shard_rel)
    vmax = max(max(shard_rel), 1.25)
    plot_h, base_y, top = 120, 150, 10
    slot = min((width - 60) / n, 64)
    bar_w = min(slot * 0.7, 24)
    parts = [f'<svg viewBox="0 0 {width} 172" width="100%" role="img" '
             f'aria-label="per-shard relative load">']
    scale = plot_h / vmax
    mean_y = base_y - 1.0 * scale
    parts.append(f'<line x1="40" y1="{base_y}" x2="{40 + slot * n}" '
                 f'y2="{base_y}" stroke="var(--axis)" stroke-width="1"/>')
    parts.append(f'<line x1="40" y1="{mean_y:.1f}" x2="{40 + slot * n}" '
                 f'y2="{mean_y:.1f}" stroke="var(--grid)" stroke-width="1"/>')
    parts.append(f'<text class="muted" x="{44 + slot * n}" '
                 f'y="{mean_y + 4:.1f}">mean</text>')
    for i, rel in enumerate(shard_rel):
        h = max(rel, 0.0) * scale
        x = 40 + i * slot + (slot - bar_w) / 2
        y = base_y - h
        # vertical column: square baseline, rounded cap (rotate the path)
        r = min(4.0, bar_w / 2, h / 2)
        d = (f"M{x:.1f},{base_y:.1f} v{-(h - r):.1f} "
             f"a{r:.1f},{r:.1f} 0 0 1 {r:.1f},{-r:.1f} "
             f"h{bar_w - 2 * r:.1f} "
             f"a{r:.1f},{r:.1f} 0 0 1 {r:.1f},{r:.1f} "
             f"v{h - r:.1f} Z") if h > 1 else \
            (f"M{x:.1f},{base_y:.1f} h{bar_w:.1f} v-1 h{-bar_w:.1f} Z")
        parts.append(f'<path d="{d}" fill="var(--s1)">'
                     f'<title>shard {i}: {rel:.2f}x mean load</title></path>')
        parts.append(f'<text class="val" x="{x + bar_w / 2:.1f}" '
                     f'y="{y - 5:.1f}" text-anchor="middle">{rel:.2f}x</text>')
        parts.append(f'<text class="muted" x="{x + bar_w / 2:.1f}" '
                     f'y="{base_y + 14}" text-anchor="middle">{i}</text>')
    parts.append(f'<text class="muted" x="40" y="{top}">'
                 f'relative load (per-shard bytes / mean)</text>')
    parts.append("</svg>")
    return "".join(parts)


def _tile(label: str, value: str, hint: str = "") -> str:
    h = f'<div class="hint">{_esc(hint)}</div>' if hint else ""
    return (f'<div class="tile"><div class="label">{_esc(label)}</div>'
            f'<div class="value">{value}</div>{h}</div>')


def _status(ok: Optional[bool], text: str) -> str:
    """Status chip: colored dot + label (never color alone)."""
    color = "var(--ink-muted)" if ok is None else (
        "var(--good)" if ok else "var(--critical)")
    mark = "–" if ok is None else ("✓" if ok else "✗")
    return (f'<span class="status"><span class="dot" '
            f'style="background:{color}"></span>{mark} {_esc(text)}</span>')


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def _section_tiles(runtime, service, slo) -> str:
    tiles = []
    if runtime:
        backs = runtime.get("backends", {})
        avail = {k: v for k, v in backs.items() if v.get("available")}
        if avail:
            best = max(avail.items(),
                       key=lambda kv: kv[1].get("seeds_per_s_warm", 0.0))
            tiles.append(_tile(
                "seeds/sec (warm)", _fmt(best[1].get("seeds_per_s_warm", 0)),
                f"{best[0]} · {runtime.get('graph', '?')}"))
    if service:
        qps = service.get("qps") or (service.get("host") or {}).get("qps")
        p99 = service.get("p99_ms") or (service.get("host") or {}).get("p99_ms")
        if qps:
            tiles.append(_tile("serving qps", _fmt(qps),
                               f"n={_fmt(service.get('n', 0))}"))
        if p99:
            tiles.append(_tile("query p99", f"{float(p99):.2f}<small>ms</small>"))
        if service.get("device_vs_host"):
            tiles.append(_tile("device vs host",
                               f"{float(service['device_vs_host']):.2f}x",
                               "amortized latency ratio"))
    breaches = (slo or {}).get("_breach_count", 0)
    tiles.append(_tile("SLO breaches", str(breaches),
                       "rising-edge count" if breaches else "within budget"))
    return f'<div class="tiles">{"".join(tiles)}</div>'


def _section_phases(events) -> str:
    totals: dict = {}
    counts: dict = {}
    for ev in events or []:
        if ev.get("depth", 0) == 0:
            p = ev.get("phase", "other")
            totals[p] = totals.get(p, 0.0) + float(ev.get("dur_s", 0.0))
            counts[p] = counts.get(p, 0) + 1
    rows = [(p, t, f"{p}: {t:.3f}s across {counts[p]} top-level spans")
            for p, t in sorted(totals.items(), key=lambda kv: -kv[1])]
    chart = _hbar_chart([(p, t * 1e3, tip) for p, t, tip in rows], unit="ms")
    return (f'<div class="card"><h2>Phase breakdown</h2>'
            f'<p class="sub">top-level span seconds per trace lane '
            f'({len(events or [])} spans recorded)</p>{chart}</div>')


def _section_skew(profiles, metrics_rows) -> str:
    body = []
    prof_dicts = []
    for p in profiles or []:
        prof_dicts.append(p.summary() if hasattr(p, "summary") else dict(p))
    if prof_dicts:
        last = prof_dicts[-1]
        byts = last.get("shard_bytes") or []
        mean = (sum(byts) / len(byts)) if byts else 0.0
        rel = [b / mean if mean else 1.0 for b in byts]
        body.append(f'<p class="sub">latest profile: '
                    f'{_esc(last.get("backend"))} backend, '
                    f'{_esc(last.get("strategy"))} plan, phase '
                    f'{_esc(last.get("phase"))}, {last.get("sweeps")} sweeps, '
                    f'wall {float(last.get("wall_s", 0)):.3f}s</p>')
        body.append(_grouped_shard_chart(rel))
        hdr = ("<tr><th>backend</th><th>strategy</th><th>phase</th>"
               "<th>time imb</th><th>bytes imb</th><th>step imb</th>"
               "<th>GB/s</th><th>wall s</th></tr>")
        trs = []
        for d in prof_dicts:
            trs.append(
                "<tr>"
                f"<td>{_esc(d.get('backend'))}</td>"
                f"<td>{_esc(d.get('strategy'))}</td>"
                f"<td>{_esc(d.get('phase'))}</td>"
                f"<td>{float(d.get('time_imbalance', 0)):.2f}x</td>"
                f"<td>{float(d.get('bytes_imbalance', 0)):.2f}x</td>"
                f"<td>{float(d.get('step_imbalance', 0)):.2f}x</td>"
                f"<td>{float(d.get('achieved_gbps', 0)):.2f}</td>"
                f"<td>{float(d.get('wall_s', 0)):.3f}</td></tr>")
        body.append(f'<table>{hdr}{"".join(trs)}</table>')
    ratio_rows = [r for r in (metrics_rows or [])
                  if str(r.get("name", "")).startswith(
                      "partition.predicted_vs_measured")]
    if ratio_rows:
        hdr = ("<tr><th>gauge</th><th>strategy</th><th>backend</th>"
               "<th>measured / predicted</th><th>verdict</th></tr>")
        trs = []
        for r in ratio_rows:
            ratio = float(r.get("value", 0.0))
            tags = r.get("tags", {})
            ok = 0.5 <= ratio <= 2.0 if ratio else None
            trs.append(
                "<tr>"
                f"<td>{_esc(r['name'].split('.')[-1])}</td>"
                f"<td>{_esc(tags.get('strategy', '?'))}</td>"
                f"<td>{_esc(tags.get('backend', '?'))}</td>"
                f"<td>{ratio:.2f}</td>"
                f"<td>{_status(ok, 'model held' if ok else 'mispredicted')}"
                f"</td></tr>")
        body.append(f'<h2 style="margin-top:14px">Predicted vs measured'
                    f'</h2><table>{hdr}{"".join(trs)}</table>')
    if not body:
        body.append('<p class="empty">no shard profiles captured '
                    '(run a serial/mesh build or fixpoint)</p>')
    return (f'<div class="card"><h2>Shard skew — measured</h2>'
            f'{"".join(body)}</div>')


def _section_slo(slo) -> str:
    if not slo or not any(k for k in slo if not k.startswith("_")):
        return ('<div class="card"><h2>SLO</h2><p class="empty">no SLO '
                'budgets configured</p></div>')
    hdr = ("<tr><th>query class</th><th>samples</th><th>window p99</th>"
           "<th>budget</th><th>status</th></tr>")
    trs = []
    for qclass, st in sorted(slo.items()):
        if qclass.startswith("_"):
            continue
        budget = st.get("budget_ms")
        breach = st.get("in_breach", False)
        status = (_status(None, "no budget") if budget is None
                  else _status(not breach, "breached" if breach else "ok"))
        trs.append(
            "<tr>"
            f"<td>{_esc(qclass)}</td><td>{st.get('samples', 0)}</td>"
            f"<td>{float(st.get('window_p99_ms', 0)):.2f} ms</td>"
            f"<td>{'—' if budget is None else f'{budget:.2f} ms'}</td>"
            f"<td>{status}</td></tr>")
    return (f'<div class="card"><h2>SLO</h2>'
            f'<table>{hdr}{"".join(trs)}</table></div>')


def _depth_sparkline(timeline, *, width: int = 720) -> str:
    """Queue depth over time as a filled step line — the admission view.
    ``timeline`` is [(seconds since engine start, depth), ...]."""
    pts = [(float(t), float(d)) for t, d in timeline or []]
    if not pts:
        return '<p class="empty">no queue-depth timeline captured</p>'
    t0, t1 = pts[0][0], pts[-1][0]
    span = max(t1 - t0, 1e-9)
    dmax = max(max(d for _, d in pts), 1.0)
    plot_h, base_y, left = 90, 110, 46
    plot_w = width - left - 10
    xy = [(left + (t - t0) / span * plot_w,
           base_y - d / dmax * plot_h) for t, d in pts]
    line = " ".join(f"{x:.1f},{y:.1f}" for x, y in xy)
    area = (f"{left:.1f},{base_y} " + line
            + f" {left + plot_w:.1f},{base_y}")
    parts = [f'<svg viewBox="0 0 {width} 132" width="100%" role="img" '
             f'aria-label="queue depth over time">',
             f'<line x1="{left}" y1="{base_y}" x2="{left + plot_w}" '
             f'y2="{base_y}" stroke="var(--axis)" stroke-width="1"/>',
             f'<polygon points="{area}" fill="var(--s1)" opacity="0.15"/>',
             f'<polyline points="{line}" fill="none" stroke="var(--s1)" '
             f'stroke-width="1.5"><title>queue depth, {len(pts)} samples '
             f'over {span:.2f}s (peak {dmax:.0f})</title></polyline>',
             f'<text class="val" x="{left - 6}" '
             f'y="{base_y - plot_h + 4}" text-anchor="end">{dmax:.0f}</text>',
             f'<text class="muted" x="{left - 6}" y="{base_y + 4}" '
             f'text-anchor="end">0</text>',
             f'<text class="muted" x="{left}" y="{base_y + 16}">'
             f'{t0:.2f}s</text>',
             f'<text class="muted" x="{left + plot_w}" y="{base_y + 16}" '
             f'text-anchor="end">{t1:.2f}s</text>',
             "</svg>"]
    return "".join(parts)


def _metric_value(metrics_rows, name: str) -> float:
    """Sum of a counter/gauge across its tag series (0.0 when absent)."""
    return sum(float(r.get("value", 0.0)) for r in metrics_rows or []
               if r.get("name") == name)


def _section_admission(service, metrics_rows) -> str:
    """The async serving pipeline's admission health: queue depth over
    time, deadline misses, eviction churn, and double-buffered swap
    latency. Fed by the benchmark's ``async`` blob (admission_summary())
    plus the live metrics registry."""
    adm = (service or {}).get("async") or (service or {}).get("admission")
    if not adm:
        return ('<div class="card"><h2>Admission</h2><p class="empty">no '
                'async admission stats captured (serve with --async or run '
                'the service benchmark)</p></div>')
    body = []
    miss_rate = float(adm.get("deadline_miss_rate", 0.0))
    tiles = [
        _tile("sustained qps", _fmt(adm.get("sustained_qps", 0.0)),
              "open-loop completed / wall") if adm.get("sustained_qps")
        else "",
        _tile("e2e p99", f"{float(adm.get('e2e_p99_ms', adm.get('p99_ms', 0))):.1f}"
              f"<small>ms</small>",
              f"deadline {float(adm.get('deadline_ms', 0)):.0f}ms"),
        _tile("deadline misses", _fmt(adm.get("deadline_misses", 0)),
              f"{miss_rate:.1%} of {_fmt(adm.get('completed', 0))} served"),
        _tile("flushes", _fmt(adm.get("flushes", 0)),
              f"{_fmt(adm.get('cross_entry_batches', 0))} cross-entry"),
    ]
    body.append(f'<div class="tiles">{"".join(t for t in tiles if t)}</div>')
    body.append(_depth_sparkline(adm.get("queue_depth_timeline")))

    evictions = _metric_value(metrics_rows, "store.evictions")
    rebuilds = _metric_value(metrics_rows, "store.evicted_rebuilds")
    swaps = _metric_value(metrics_rows, "store.swaps")
    stalls = float(adm.get("admission_stalls", 0) or 0)
    swap_hist = next((r for r in metrics_rows or []
                      if r.get("name") == "store.swap_s"), None)
    rows = [("evictions", f"{evictions:.0f}",
             f"{rebuilds:.0f} transparent rebuilds on touch"),
            ("swaps", f"{swaps:.0f}",
             "double-buffered delta/rebuild installs"),
            ("admission stalls", f"{stalls:.0f}",
             "flight-ring dumps on oldest-wait blowout")]
    if swap_hist:
        rows.append(("swap latency",
                     f"{float(swap_hist.get('p99', 0)) * 1e3:.2f} ms p99",
                     f"mean {float(swap_hist.get('mean', 0)) * 1e3:.2f} ms "
                     f"over {int(swap_hist.get('count', 0))} swaps"))
    if adm.get("budget_bytes"):
        rows.append(("resident bytes",
                     f"{_fmt(adm.get('resident_bytes', 0))} "
                     f"/ {_fmt(adm['budget_bytes'])}",
                     "store banks vs eviction budget"))
    hdr = "<tr><th>signal</th><th>value</th><th>detail</th></tr>"
    trs = ["<tr>" f"<td>{_esc(n)}</td><td>{v}</td>"
           f'<td class="sub">{_esc(d)}</td></tr>' for n, v, d in rows]
    body.append(f'<table>{hdr}{"".join(trs)}</table>')
    return (f'<div class="card"><h2>Admission</h2>'
            f'<p class="sub">async serving pipeline: micro-batch queue '
            f'depth, deadline misses, tenancy eviction, swap latency</p>'
            f'{"".join(body)}</div>')


def _cfg_label(cfg: dict) -> str:
    """Compact KernelConfig rendering: only the knobs that differ from the
    all-defaults config ('defaults' when none do)."""
    parts = []
    if cfg.get("edge_block"):
        parts.append(f"eb={cfg['edge_block']}")
    if cfg.get("reg_tile"):
        parts.append(f"rt={cfg['reg_tile']}")
    if cfg.get("local_sweeps"):
        parts.append(f"ls={cfg['local_sweeps']}")
    if cfg.get("pad_mode", "step") != "step":
        parts.append(f"pad={cfg['pad_mode']}")
    if cfg.get("fuse_sweeps"):
        parts.append("fused")
    if cfg.get("lane_fill"):
        parts.append(f"lf={cfg['lane_fill']}")
    return " ".join(parts) if parts else "defaults"


def _section_tuning(tuning) -> str:
    """Measured kernel winners (the repro.tune cache): what config was
    chosen per workload key, and the evidence — default vs tuned time,
    achieved GB/s, fraction of the HBM roof."""
    if not tuning:
        return ('<div class="card"><h2>Kernel tuning</h2><p class="empty">'
                'no tuning cache captured (run with --tuning auto or seed '
                'TUNE_cache.json)</p></div>')
    hdr = ("<tr><th>workload key</th><th>chosen config</th>"
           "<th>default</th><th>tuned</th><th>speedup</th>"
           "<th>GB/s</th><th>roof</th></tr>")
    trs = []
    for key, entry in sorted(tuning.items()):
        cfg = _cfg_label(entry.get("config", {}))
        m = entry.get("measurement") or {}
        if m:
            speedup = float(m.get("speedup", 1.0))
            trs.append(
                "<tr>"
                f"<td>{_esc(key)}</td><td>{_esc(cfg)}</td>"
                f"<td>{float(m.get('default_us', 0)):,.0f} µs</td>"
                f"<td>{float(m.get('tuned_us', 0)):,.0f} µs</td>"
                f"<td>{_status(speedup >= 0.999, f'{speedup:.2f}x')}</td>"
                f"<td>{float(m.get('tuned_gbps', 0)):.2f}</td>"
                f"<td>{float(m.get('frac_of_roof', 0)) * 100:.1f}%</td>"
                "</tr>")
        else:
            trs.append(
                "<tr>"
                f"<td>{_esc(key)}</td><td>{_esc(cfg)}</td>"
                f"<td colspan=5>{_status(None, 'no measurement recorded')}"
                f"</td></tr>")
    return (f'<div class="card"><h2>Kernel tuning</h2>'
            f'<p class="sub">measured winners per workload key '
            f'(family|backend|impl|model|edge-bucket) from the repro.tune '
            f'cache; speedup = default time / tuned time on the same '
            f'operands</p><table>{hdr}{"".join(trs)}</table></div>')


def _section_backends(runtime) -> str:
    if not runtime or not runtime.get("backends"):
        return ""
    rows = []
    for name, b in runtime["backends"].items():
        if not b.get("available"):
            continue
        rows.append((name, b.get("seeds_per_s_warm", 0.0),
                     f"{name}: warm {b.get('warm_s', 0):.3f}s, "
                     f"cold {b.get('cold_s', 0):.3f}s, "
                     f"build {b.get('store_build_s', 0):.3f}s"))
    chart = _hbar_chart(rows, unit=" seeds/s")
    return (f'<div class="card"><h2>Runtime backends</h2>'
            f'<p class="sub">warm seed-selection throughput, '
            f'{_esc(runtime.get("graph", "?"))} '
            f'(n={_fmt(runtime.get("n", 0))}, m={_fmt(runtime.get("m", 0))})'
            f'</p>{chart}</div>')


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def write_report(path: str, *, title: str = "repro perf report",
                 runtime: Optional[dict] = None,
                 service: Optional[dict] = None,
                 events: Optional[Iterable[dict]] = None,
                 metrics_rows: Optional[Iterable[dict]] = None,
                 profiles: Optional[Iterable] = None,
                 slo: Optional[dict] = None,
                 tuning: Optional[dict] = None,
                 generated: str = "") -> str:
    """Render the report to ``path`` and return the path. Every section is
    optional — missing streams render as labelled empty states, never
    errors, so the report is safe to emit from any driver."""
    events = list(events or [])
    metrics_rows = list(metrics_rows or [])
    doc = [
        "<!doctype html>",
        '<html><head><meta charset="utf-8">',
        f"<title>{_esc(title)}</title>",
        f"<style>{_CSS}</style></head><body>",
        f"<h1>{_esc(title)}</h1>",
        f'<p class="sub">{_esc(generated) if generated else ""}'
        f'{" · " if generated else ""}sections render empty when their '
        f"stream wasn't captured</p>",
        _section_tiles(runtime, service, slo),
        _section_backends(runtime),
        _section_phases(events),
        _section_skew(profiles, metrics_rows),
        _section_admission(service, metrics_rows),
        _section_tuning(tuning),
        _section_slo(slo),
        "</body></html>",
    ]
    with open(path, "w") as f:
        f.write("\n".join(doc))
    return path


def write_report_from_artifacts(path: str = "BENCH_report.html", *,
                                runtime_json: str = "BENCH_runtime.json",
                                service_json: str = "BENCH_service.json",
                                tuning_json: str = "TUNE_cache.json",
                                recorder=None, slo: Optional[dict] = None,
                                generated: str = "") -> str:
    """The harness entry point: stitch whatever the run left behind — the
    ``BENCH_*`` JSON records on disk, the tuning cache, the live trace
    recorder's spans, the global metrics registry, and the shard-profile
    ring."""
    from repro.obs import metrics, shardprof, trace

    def _load(p):
        try:
            with open(p) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    tuning = None
    if os.path.exists(tuning_json):
        from repro.tune.cache import TuningCache

        tuning = TuningCache(tuning_json).records() or None

    rec = recorder if recorder is not None else trace.get_recorder()
    return write_report(
        path,
        runtime=_load(runtime_json) if os.path.exists(runtime_json) else None,
        service=_load(service_json) if os.path.exists(service_json) else None,
        events=rec.events(),
        metrics_rows=metrics.registry().snapshot(),
        profiles=shardprof.profiles(),
        slo=slo,
        tuning=tuning,
        generated=generated)
