"""Flight recorder: always-on bounded ring of recent spans, dumped on fault.

"p99 regressed" is only actionable if the window around the regression is
still inspectable after the fact. The flight recorder keeps a bounded ring
of the most recent *completed* spans — fed by the span-listener tap in
:mod:`repro.obs.trace`, so it captures every real span whether or not the
main recorder is on (with tracing disabled that's the always-``timed=True``
population: engine query batches, benchmark timings; with tracing enabled,
everything). On an engine exception or an SLO breach it dumps the ring as a
Chrome-trace JSON (Perfetto-loadable), stamped with the dump reason and the
counter deltas since the previous dump.

Cost model: one dict append into a ``deque(maxlen=N)`` per real span. The
tracing-disabled fast path is untouched — null spans never reach listeners.

The module-level recorder installs itself at import (``repro.obs`` imports
this module), so the ring is warm in every process that touches the obs
package. ``configure(dir=...)`` or ``REPRO_FLIGHT_DIR`` picks the dump
directory (default: a gitignored ``flight/`` under the CWD)."""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import List, Optional

from repro.obs import metrics, trace

#: Ring capacity: ~2k spans is minutes of engine traffic and a handful of
#: full builds — enough context either side of a fault, small enough that
#: the ring never matters for memory.
DEFAULT_CAPACITY = 2048


class FlightRecorder:
    """Bounded ring of completed spans with fault-triggered Chrome dumps."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, *,
                 out_dir: Optional[str] = None, max_dumps: int = 8):
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._counter_basis: dict = {}
        self.out_dir = out_dir
        self.max_dumps = max_dumps      # rate limit: a breach storm must not
        self.dump_count = 0             # fill the disk with identical dumps
        self.dumps: List[str] = []
        self.enabled = True

    # -- capture -----------------------------------------------------------

    def on_span(self, sp) -> None:
        """Span-listener entry point (every real span's ``__exit__``)."""
        if not self.enabled:
            return
        ev = {"name": sp.name, "phase": sp.phase or "other",
              "ts_s": sp.t0 - self._epoch, "dur_s": sp.t1 - sp.t0,
              "depth": sp.depth, "attrs": dict(sp.attrs)}
        with self._lock:
            self._ring.append(ev)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        return len(self._ring)

    # -- dump --------------------------------------------------------------

    def _counter_deltas(self) -> dict:
        """Counter movement since the previous dump — the 'what happened in
        this window' ledger embedded in the dump metadata."""
        now = {}
        for rec in metrics.registry().snapshot():
            if rec["kind"] != "counter":
                continue
            key = rec["name"] + "".join(
                f"|{k}={v}" for k, v in sorted(rec["tags"].items()))
            now[key] = rec["value"]
        deltas = {k: v - self._counter_basis.get(k, 0)
                  for k, v in now.items()
                  if v != self._counter_basis.get(k, 0)}
        self._counter_basis = now
        return deltas

    def chrome_trace(self, reason: str = "") -> dict:
        """Chrome trace-event JSON of the ring (same lane-per-phase layout
        as the main recorder) plus a metadata event carrying the dump
        reason and counter deltas."""
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": f"repro-flight ({reason})" if reason
                      else "repro-flight"}},
        ]
        ring = self.events()
        used = sorted({ev["phase"] for ev in ring},
                      key=lambda p: trace._PHASE_TID.get(p, 99))
        for p in used:
            tid = trace._PHASE_TID.get(p, len(trace.PHASES))
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": p}})
            events.append({"ph": "M", "name": "thread_sort_index", "pid": 0,
                           "tid": tid, "args": {"sort_index": tid}})
        for ev in ring:
            args = {k: trace._jsonable(v) for k, v in ev["attrs"].items()}
            args["depth"] = ev["depth"]
            events.append({
                "ph": "X", "name": ev["name"], "pid": 0,
                "tid": trace._PHASE_TID.get(ev["phase"], len(trace.PHASES)),
                "ts": round(ev["ts_s"] * 1e6, 3),
                "dur": round(ev["dur_s"] * 1e6, 3),
                "cat": ev["phase"], "args": args})
        meta = {"reason": reason, "spans": len(ring),
                "wall_s": time.perf_counter() - self._epoch,
                "counter_deltas": self._counter_deltas()}
        # an instant event makes the dump reason visible on the Perfetto
        # timeline itself, not only in the JSON
        events.append({"ph": "i", "name": f"flight-dump: {reason}", "pid": 0,
                       "tid": 0, "ts": round(meta["wall_s"] * 1e6, 3),
                       "s": "g", "args": meta})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "metadata": meta}

    def dump(self, path: Optional[str] = None, *,
             reason: str = "manual") -> Optional[str]:
        """Write the ring as Chrome-trace JSON; returns the path written, or
        None when rate-limited / disabled. Never raises — the recorder runs
        inside exception handlers on the serving path."""
        if not self.enabled or self.dump_count >= self.max_dumps:
            return None
        try:
            if path is None:
                # default: a gitignored flight/ subdirectory — dumps are
                # debugging artifacts and must never land in the worktree
                # root (where they read as committable files)
                base = (self.out_dir or os.environ.get("REPRO_FLIGHT_DIR")
                        or os.path.join(os.getcwd(), "flight"))
                os.makedirs(base, exist_ok=True)
                slug = "".join(c if c.isalnum() or c in "-_" else "-"
                               for c in reason)[:48] or "dump"
                path = os.path.join(
                    base, f"flight_{self.dump_count:02d}_{slug}.json")
            with open(path, "w") as f:
                json.dump(self.chrome_trace(reason), f)
            self.dump_count += 1
            self.dumps.append(path)
            metrics.counter("flight.dumps").inc()
            return path
        except Exception:  # noqa: BLE001 — must not mask the original fault
            return None


_FLIGHT = FlightRecorder()
trace.add_span_listener(_FLIGHT.on_span)


def get_flight_recorder() -> FlightRecorder:
    """The process-global always-on flight recorder."""
    return _FLIGHT


def configure(*, out_dir: Optional[str] = None,
              capacity: Optional[int] = None,
              max_dumps: Optional[int] = None,
              enabled: Optional[bool] = None) -> FlightRecorder:
    """Adjust the global recorder in place (tests and drivers)."""
    if out_dir is not None:
        _FLIGHT.out_dir = out_dir
    if capacity is not None:
        with _FLIGHT._lock:
            _FLIGHT._ring = deque(_FLIGHT._ring, maxlen=capacity)
    if max_dumps is not None:
        _FLIGHT.max_dumps = max_dumps
    if enabled is not None:
        _FLIGHT.enabled = enabled
    return _FLIGHT


def dump(reason: str, path: Optional[str] = None) -> Optional[str]:
    """Module-level convenience: dump the global ring."""
    return _FLIGHT.dump(path, reason=reason)
