"""Measured per-shard execution profiles — the planner's reality check.

The partition planner predicts, at plan time, how work will spread over the
``(mu_v, mu_s)`` shard grid (``PlanStats`` in :mod:`repro.partition.cost`).
DiFuseR's multi-GPU scaling claim rests on those predictions being right:
the busiest shard bounds every sweep. This module captures what *actually*
happened — per-shard, per-ring-step wall seconds and bucket bytes during
builds and fixpoints — and folds it into a :class:`MeasuredProfile` that is
directly comparable to the predicted stats, closing the loop the ROADMAP's
kernel-autotuning item rides on (measured profiles are the training data a
block-shape/schedule autotuner consumes).

Two capture modes, matching what each backend can physically measure:

  * **serial ring** (``partition/serial.py``) — executes shard-by-shard on
    the host, so every ``(shard, ring step)`` bucket merge gets its own
    measured wall time (``per_step_timed=True``). This is the ground truth
    for "does the degree planner actually beat block on a skewed graph".
  * **mesh** (``core/distributed.py``) — SPMD shards run in lockstep inside
    one XLA program, so per-shard time is not separable host-side; the
    profile carries exact per-(shard, step) *bytes* (off the built
    partition's bucket counts) plus the fixpoint wall time
    (``per_step_timed=False``).

Publication: :func:`publish` registers the profile in a bounded process
ring (:func:`profiles` — the HTML perf report reads it) and, when the
partition carries a plan with predicted stats, emits the
``partition.predicted_vs_measured_edge_imb`` / ``_bucket_imb`` gauges —
measured / predicted imbalance ratios, tagged by strategy and backend. A
ratio well above 1.0 is a misprediction visible the moment the plan runs.

Dependency: numpy only (imported lazily by callers that already hold it);
no jax at module load, same contract as the rest of ``repro.obs``.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from time import perf_counter
from typing import Optional

import numpy as np

from repro.obs import metrics

#: Approximate bytes a bucket edge costs per sweep: 20 B of operand reads
#: (h, w, r, t, l — uint32/int32 each) plus one int8 register-row read and
#: one int8 max-merge write per register lane.
_EDGE_OPERAND_BYTES = 20


def bucket_bytes(edge_count: int, j_loc: int) -> int:
    """Bytes one bucket of ``edge_count`` real edges moves in one sweep."""
    return int(edge_count) * (_EDGE_OPERAND_BYTES + 2 * int(j_loc))


def _imbalance(loads: np.ndarray) -> float:
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    mean = loads.mean() if loads.size else 0.0
    return float(loads.max(initial=0.0) / mean) if mean > 0 else 1.0


@dataclasses.dataclass
class MeasuredProfile:
    """What one build/fixpoint actually cost, per shard and per ring step.

    ``step_seconds[v, k]`` / ``step_bytes[v, k]`` aggregate vertex-shard
    ``v``'s ring-step-``k`` bucket merges over all sim shards and all
    sweeps. ``per_step_timed`` is False when the backend cannot separate
    per-shard time (mesh SPMD) — bytes are still exact there.
    """

    backend: str                   # "serial" | "mesh" | ...
    phase: str                     # "build" | "fixpoint" | "select" ...
    strategy: str
    mu_v: int
    mu_s: int
    sweeps: int
    step_seconds: np.ndarray       # float64[mu_v, mu_v]
    step_bytes: np.ndarray         # int64[mu_v, mu_v]
    wall_s: float
    per_step_timed: bool

    # -- reductions --------------------------------------------------------

    def shard_seconds(self) -> np.ndarray:
        return self.step_seconds.sum(axis=1)

    def shard_bytes(self) -> np.ndarray:
        return self.step_bytes.sum(axis=1)

    def time_imbalance(self) -> float:
        """max/mean of per-shard measured seconds (1.0 = perfectly even).
        Falls back to the bytes imbalance when time is not separable."""
        if not self.per_step_timed:
            return self.bytes_imbalance()
        return _imbalance(self.shard_seconds())

    def bytes_imbalance(self) -> float:
        """max/mean of per-shard measured bucket bytes — the measured twin
        of the planner's predicted edge imbalance."""
        return _imbalance(self.shard_bytes())

    def step_imbalance(self) -> float:
        """max/mean over the full (shard, ring step) grid — the measured
        twin of the predicted bucket imbalance (per-step padding means the
        widest bucket of a step stalls every shard at that step)."""
        grid = self.step_seconds if self.per_step_timed else self.step_bytes
        return _imbalance(grid)

    def achieved_gbps(self) -> float:
        """Aggregate bucket bytes / wall — the bandwidth this build actually
        sustained (compare against ``utils.roofline.HBM_BW``)."""
        total = float(self.step_bytes.sum())
        return total / self.wall_s / 1e9 if self.wall_s > 0 else 0.0

    # -- presentation ------------------------------------------------------

    def skew_table(self) -> str:
        """Human-readable per-shard table: seconds, bytes, and each shard's
        load relative to the mean (the straggler column)."""
        secs, byts = self.shard_seconds(), self.shard_bytes()
        mean_b = byts.mean() if byts.size else 0.0
        lines = [f"[{self.backend}:{self.strategy}] {self.phase} "
                 f"mu_v={self.mu_v} mu_s={self.mu_s} sweeps={self.sweeps} "
                 f"wall={self.wall_s:.3f}s "
                 f"time_imb={self.time_imbalance():.2f} "
                 f"bytes_imb={self.bytes_imbalance():.2f}",
                 "shard      seconds         bytes   rel_load"]
        for v in range(self.mu_v):
            rel = byts[v] / mean_b if mean_b > 0 else 1.0
            sec = f"{secs[v]:.4f}" if self.per_step_timed else "   n/a"
            lines.append(f"{v:5d}  {sec:>10s}  {int(byts[v]):12d}   "
                         f"{rel:7.2f}x")
        return "\n".join(lines)

    def summary(self) -> dict:
        """JSON-ready summary (the perf report's row format)."""
        return {
            "backend": self.backend, "phase": self.phase,
            "strategy": self.strategy, "mu_v": self.mu_v, "mu_s": self.mu_s,
            "sweeps": self.sweeps, "wall_s": self.wall_s,
            "per_step_timed": self.per_step_timed,
            "time_imbalance": self.time_imbalance(),
            "bytes_imbalance": self.bytes_imbalance(),
            "step_imbalance": self.step_imbalance(),
            "achieved_gbps": self.achieved_gbps(),
            "shard_seconds": [float(s) for s in self.shard_seconds()],
            "shard_bytes": [int(b) for b in self.shard_bytes()],
        }


class ShardProfiler:
    """Accumulates per-(shard, ring step) measurements during one
    build/fixpoint. The serial ring calls :meth:`record` around every bucket
    merge; the mesh path calls :meth:`add_partition_bytes` once (counts are
    known host-side) and leaves time unseparated."""

    def __init__(self, mu_v: int, mu_s: int, *, backend: str, phase: str,
                 strategy: str = "block"):
        self.mu_v, self.mu_s = mu_v, mu_s
        self.backend, self.phase, self.strategy = backend, phase, strategy
        self.step_seconds = np.zeros((mu_v, mu_v), dtype=np.float64)
        self.step_bytes = np.zeros((mu_v, mu_v), dtype=np.int64)
        self.sweeps = 0
        self.per_step_timed = False
        self._t0 = perf_counter()

    def record(self, v: int, kk: int, seconds: float, nbytes: int) -> None:
        """One measured bucket merge of shard ``v`` at ring step ``kk``."""
        self.step_seconds[v, kk] += seconds
        self.step_bytes[v, kk] += nbytes
        self.per_step_timed = True

    def count_sweep(self) -> None:
        self.sweeps += 1

    def add_partition_bytes(self, counts: np.ndarray, j_loc: int,
                            sweeps: int) -> None:
        """Fold per-bucket real-edge ``counts`` (``int64[mu_v, mu_s, mu_v]``
        — the builder's ``p_counts``) in as bytes, scaled by the sweep count
        the fixpoint actually ran."""
        per_edge = _EDGE_OPERAND_BYTES + 2 * int(j_loc)
        self.step_bytes += counts.sum(axis=1).astype(np.int64) * per_edge * max(sweeps, 1)
        self.sweeps += sweeps

    def finish(self, wall_s: Optional[float] = None) -> MeasuredProfile:
        return MeasuredProfile(
            backend=self.backend, phase=self.phase, strategy=self.strategy,
            mu_v=self.mu_v, mu_s=self.mu_s, sweeps=self.sweeps,
            step_seconds=self.step_seconds, step_bytes=self.step_bytes,
            wall_s=wall_s if wall_s is not None else perf_counter() - self._t0,
            per_step_timed=self.per_step_timed)


# ---------------------------------------------------------------------------
# process-level publication (bounded ring + predicted-vs-measured gauges)
# ---------------------------------------------------------------------------

_PROFILES: deque = deque(maxlen=64)
_LOCK = threading.Lock()
_ENABLED = True


def set_enabled(flag: bool) -> None:
    """Master switch for profile capture (on by default — the numpy-side
    bookkeeping is negligible next to the sweeps it measures)."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def profiles() -> list:
    """Recent :class:`MeasuredProfile`\\ s, oldest first (bounded ring)."""
    with _LOCK:
        return list(_PROFILES)


def last_profile() -> Optional[MeasuredProfile]:
    with _LOCK:
        return _PROFILES[-1] if _PROFILES else None


def clear() -> None:
    with _LOCK:
        _PROFILES.clear()


def publish(profile: MeasuredProfile, predicted=None) -> MeasuredProfile:
    """Register a finished profile and, when the plan's predicted
    ``PlanStats`` is available, emit the closed-loop gauges:

      * ``partition.measured_edge_imb`` / ``partition.measured_time_imb`` —
        the profile's own imbalances;
      * ``partition.predicted_vs_measured_edge_imb`` — measured bytes
        imbalance / predicted edge imbalance (1.0 = the planner's cost
        model was right about shard skew);
      * ``partition.predicted_vs_measured_bucket_imb`` — measured
        (shard, step) imbalance / predicted bucket imbalance.

    All gauges are tagged ``strategy=<plan strategy> backend=<backend>`` so
    planners stay comparable side by side in one snapshot."""
    if not _ENABLED:
        return profile
    with _LOCK:
        _PROFILES.append(profile)
    tags = {"strategy": profile.strategy, "backend": profile.backend}
    metrics.gauge("partition.measured_edge_imb",
                  **tags).set(profile.bytes_imbalance())
    metrics.gauge("partition.measured_time_imb",
                  **tags).set(profile.time_imbalance())
    metrics.gauge("partition.achieved_gbps", **tags).set(profile.achieved_gbps())
    if predicted is not None:
        if predicted.edge_imbalance > 0:
            metrics.gauge("partition.predicted_vs_measured_edge_imb", **tags).set(
                profile.bytes_imbalance() / predicted.edge_imbalance)
        if predicted.bucket_imbalance > 0:
            metrics.gauge("partition.predicted_vs_measured_bucket_imb", **tags).set(
                profile.step_imbalance() / predicted.bucket_imbalance)
    return profile


def profile_for_partition(part, *, backend: str, phase: str) -> ShardProfiler:
    """A profiler pre-shaped for a built ``Partition2D`` (strategy read off
    its plan; ``block`` when the partition was built planless)."""
    strategy = part.plan.strategy if part.plan is not None else "block"
    return ShardProfiler(part.mu_v, part.mu_s, backend=backend, phase=phase,
                         strategy=strategy)
