"""Graph containers used by the DiFuseR core.

Everything downstream (kernels, shard_map bodies) consumes fixed-shape int32
arrays, so the containers here do the padding/sorting once on host:

- ``Graph``: immutable COO edge list + per-edge weights, with vertices in
  ``[0, n)``. Edges are directed; undirected inputs are symmetrized by the
  loaders/generators before they get here.
- ``CSR``: row-pointer form derived from a Graph, used by reference BFS code.

Padding convention: edge arrays are padded to a multiple of the kernel edge
block with sentinel edges ``(src=n_pad-1, dst=n_pad-1, w=0)``.  Weight zero
means the edge can never be sampled (P < w is strict), so sentinel edges are
inert by construction — no masks needed downstream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INT = np.int32


def edge_pair_keys(src: np.ndarray, dst: np.ndarray, n_pad: int) -> np.ndarray:
    """Collision-free int64 key for (u, v) pairs with u, v < n_pad — the one
    encoding shared by removal matching and delta repair."""
    return src.astype(np.int64) * np.int64(n_pad) + dst.astype(np.int64)


def pad_to_multiple(x: np.ndarray, multiple: int, fill) -> np.ndarray:
    """Pad 1-D array ``x`` up to a multiple of ``multiple`` with ``fill``."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    return np.concatenate([x, np.full((rem,), fill, dtype=x.dtype)])


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in COO form with per-edge diffusion probabilities.

    Attributes:
      n: number of real vertices.
      src, dst: int32[m] edge endpoints (may include padding sentinels).
      weight: float32[m] diffusion probability w_uv in [0, 1]; 0 for padding.
      n_pad: padded vertex count (>= n + 1; the sentinel vertex is n_pad - 1).
      m_real: number of real (non-padding) edges.
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    n_pad: int
    m_real: int

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @staticmethod
    def from_edges(
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        weight: Optional[np.ndarray] = None,
        *,
        edge_block: int = 256,
        vertex_multiple: int = 8,
        dedup: bool = True,
    ) -> "Graph":
        """Build a padded Graph from raw COO arrays.

        Parallel (u, v) duplicates are merged with compound probability
        ``1 - prod(1 - w_i)`` (paper §2.1). Self loops are dropped.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if weight is None:
            weight = np.full(src.shape, 0.1, dtype=np.float32)
        weight = np.asarray(weight, dtype=np.float32)
        keep = src != dst
        src, dst, weight = src[keep], dst[keep], weight[keep]

        if dedup and src.size:
            key = src * np.int64(n) + dst
            order = np.argsort(key, kind="stable")
            key, src, dst, weight = key[order], src[order], dst[order], weight[order]
            uniq, start = np.unique(key, return_index=True)
            if uniq.size != key.size:
                # compound probability across duplicate runs: 1 - prod(1 - w)
                log1m = np.log1p(-np.clip(weight, 0.0, 0.999999))
                csum = np.concatenate([[0.0], np.cumsum(log1m)])
                ends = np.concatenate([start[1:], [key.size]])
                merged_w = 1.0 - np.exp(csum[ends] - csum[start])
                src, dst = src[start], dst[start]
                weight = merged_w.astype(np.float32)

        m_real = int(src.size)
        # sentinel vertex: one extra padded row so sentinel edges are harmless
        n_pad = n + 1
        rem = (-n_pad) % vertex_multiple
        n_pad += rem
        sentinel = n_pad - 1

        src = pad_to_multiple(src.astype(INT), edge_block, INT(sentinel))
        dst = pad_to_multiple(dst.astype(INT), edge_block, INT(sentinel))
        weight = pad_to_multiple(weight, edge_block, np.float32(0.0))
        return Graph(n=n, src=src, dst=dst, weight=weight, n_pad=n_pad, m_real=m_real)

    def with_weights(self, weight: np.ndarray) -> "Graph":
        """Replace real-edge weights (padding stays 0)."""
        w = np.zeros_like(self.weight)
        w[: self.m_real] = np.asarray(weight, dtype=np.float32)[: self.m_real]
        return dataclasses.replace(self, weight=w)

    def sorted_by_dst(self) -> "Graph":
        """Edges sorted by (dst, src) — the layout the pull-based propagate
        kernel wants (destination runs are contiguous)."""
        order = np.lexsort((self.src[: self.m_real], self.dst[: self.m_real]))
        src = np.concatenate([self.src[: self.m_real][order], self.src[self.m_real :]])
        dst = np.concatenate([self.dst[: self.m_real][order], self.dst[self.m_real :]])
        w = np.concatenate([self.weight[: self.m_real][order], self.weight[self.m_real :]])
        return dataclasses.replace(self, src=src, dst=dst, weight=w)

    def reverse(self) -> "Graph":
        """Transpose graph (for cascade: activation flows src->dst along
        forward edges; the pull form of cascade pulls along incoming edges)."""
        return dataclasses.replace(self, src=self.dst.copy(), dst=self.src.copy())

    def csr(self) -> "CSR":
        return CSR.from_graph(self)

    def content_key(self) -> str:
        """Stable content hash of the real edge set (order-insensitive) —
        the graph component of a service.SketchStore key."""
        import hashlib

        src = self.src[: self.m_real].astype(np.int64)
        dst = self.dst[: self.m_real].astype(np.int64)
        w = self.weight[: self.m_real].astype(np.float32)
        order = np.lexsort((dst, src))
        h = hashlib.blake2b(digest_size=12)
        h.update(np.int64(self.n).tobytes())
        h.update(src[order].tobytes())
        h.update(dst[order].tobytes())
        h.update(w[order].tobytes())
        return h.hexdigest()

    def apply_delta(self, delta: "GraphDelta", *, edge_block: int = 256) -> "Graph":
        """Updated graph: drop every (u, v) pair named in ``delta`` removals,
        append the added edges, re-pad. Added edges that duplicate surviving
        ones merge with compound probability (``from_edges`` dedup)."""
        src = self.src[: self.m_real].astype(np.int64)
        dst = self.dst[: self.m_real].astype(np.int64)
        w = self.weight[: self.m_real]
        if delta.rem_src.size:
            keep = ~np.isin(edge_pair_keys(src, dst, self.n_pad),
                            edge_pair_keys(delta.rem_src, delta.rem_dst, self.n_pad))
            src, dst, w = src[keep], dst[keep], w[keep]
        if delta.add_src.size:
            src = np.concatenate([src, delta.add_src.astype(np.int64)])
            dst = np.concatenate([dst, delta.add_dst.astype(np.int64)])
            w = np.concatenate([w, delta.add_weight.astype(np.float32)])
        return Graph.from_edges(self.n, src, dst, w, edge_block=edge_block)


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A batch of edge insertions/removals against an existing Graph.

    Vertex ids must already live in ``[0, n)`` of the target graph (the delta
    path repairs sketches in place, so the vertex set is fixed). Removals
    match every parallel (u, v) edge regardless of weight.
    """

    add_src: np.ndarray     # int64[a]
    add_dst: np.ndarray     # int64[a]
    add_weight: np.ndarray  # float32[a]
    rem_src: np.ndarray     # int64[r]
    rem_dst: np.ndarray     # int64[r]

    @staticmethod
    def make(add=None, remove=None, default_weight: float = 0.1) -> "GraphDelta":
        """``add``: (src, dst[, weight]) arrays; ``remove``: (src, dst)."""
        empty_i = np.zeros(0, dtype=np.int64)
        if add is None:
            a_src, a_dst, a_w = empty_i, empty_i, np.zeros(0, dtype=np.float32)
        else:
            a_src = np.asarray(add[0], dtype=np.int64)
            a_dst = np.asarray(add[1], dtype=np.int64)
            a_w = (np.asarray(add[2], dtype=np.float32) if len(add) > 2
                   else np.full(a_src.shape, default_weight, dtype=np.float32))
        if remove is None:
            r_src, r_dst = empty_i, empty_i
        else:
            r_src = np.asarray(remove[0], dtype=np.int64)
            r_dst = np.asarray(remove[1], dtype=np.int64)
        return GraphDelta(add_src=a_src, add_dst=a_dst, add_weight=a_w,
                          rem_src=r_src, rem_dst=r_dst)

    @property
    def num_added(self) -> int:
        return int(self.add_src.size)

    @property
    def num_removed(self) -> int:
        return int(self.rem_src.size)


@dataclasses.dataclass(frozen=True)
class CSR:
    """Row-pointer adjacency over *real* edges only (host-side reference use)."""

    n: int
    indptr: np.ndarray  # int64[n + 1]
    indices: np.ndarray  # int32[m_real]
    weight: np.ndarray  # float32[m_real]
    # permutation from the source Graph's real-edge order to CSR order —
    # per-edge data sampled in graph order maps over via data[order]
    # (baselines.mc_oracle relies on this staying in lockstep with indices)
    order: Optional[np.ndarray] = None  # int64[m_real]

    @staticmethod
    def from_graph(g: Graph) -> "CSR":
        src = g.src[: g.m_real]
        dst = g.dst[: g.m_real]
        w = g.weight[: g.m_real]
        order = np.argsort(src, kind="stable")
        src_s, dst_s, w_s = src[order], dst[order], w[order]
        counts = np.bincount(src_s, minlength=g.n)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return CSR(n=g.n, indptr=indptr, indices=dst_s.astype(INT), weight=w_s,
                   order=order)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def neighbor_weights(self, u: int) -> np.ndarray:
        return self.weight[self.indptr[u] : self.indptr[u + 1]]
