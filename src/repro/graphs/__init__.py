"""Graph substrate: containers, generators, IO, partitioning."""
from repro.graphs.structs import Graph, CSR, pad_to_multiple
from repro.graphs.generators import rmat_graph, erdos_renyi_graph, barabasi_albert_graph

__all__ = [
    "Graph",
    "CSR",
    "pad_to_multiple",
    "rmat_graph",
    "erdos_renyi_graph",
    "barabasi_albert_graph",
]
