"""Synthetic graph generators (deterministic, numpy host-side).

The paper evaluates on SNAP social networks (power-law degree). Offline we
generate structurally similar graphs:

- ``rmat_graph``: R-MAT/Kronecker power-law generator (the standard stand-in
  for social networks, Graph500 parameters by default).
- ``erdos_renyi_graph``: ER for sanity/regression tests.
- ``barabasi_albert_graph``: preferential attachment (undirected, symmetrized).

Weight settings mirror the paper's five influence settings (§5):
const 0.005 / 0.01 / 0.1, N(0.05, 0.025), U(0, 0.1).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.structs import Graph

PAPER_SETTINGS = ("w005", "w01", "w1", "n005", "u01")


def edge_weights(setting: str, m: int, seed: int = 0) -> np.ndarray:
    """The paper's five influence settings (§5)."""
    rng = np.random.default_rng(seed)
    if setting in ("w005", "0.005"):
        return np.full(m, 0.005, dtype=np.float32)
    if setting in ("w01", "0.01"):
        return np.full(m, 0.01, dtype=np.float32)
    if setting in ("w1", "0.1"):
        return np.full(m, 0.1, dtype=np.float32)
    if setting in ("n005", "N0.05"):
        return np.clip(rng.normal(0.05, 0.025, m), 0.0, 1.0).astype(np.float32)
    if setting in ("u01", "U0.1"):
        return rng.uniform(0.0, 0.1, m).astype(np.float32)
    if setting == "wc":  # weighted-cascade: w_uv = 1/indeg(v), filled by caller
        raise ValueError("weighted-cascade weights are derived from the graph; use make_wc_weights")
    raise ValueError(f"unknown influence setting: {setting}")


def make_wc_weights(n: int, dst: np.ndarray) -> np.ndarray:
    """Weighted-cascade model: w_uv = 1 / indegree(v) (paper Fig. 1b)."""
    indeg = np.bincount(dst, minlength=n).astype(np.float32)
    return (1.0 / np.maximum(indeg, 1.0))[dst]


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    setting: str = "w1",
    directed: bool = True,
    edge_block: int = 256,
    permute_ids: bool = True,
) -> Graph:
    """R-MAT generator (Graph500 parameters). n = 2**scale vertices.

    ``permute_ids=False`` keeps the raw Kronecker ids: degree correlates
    with the id bit pattern (hubs cluster at low ids), the adversarial
    regime for contiguous block vertex partitions — real crawls share this
    id/degree locality, which is what the partition planners are for."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for level in range(scale):
        r = rng.random(m)
        right = r >= ab  # quadrants c|d (row bit = 1)
        r2 = rng.random(m)
        # column bit: within top half P(col=1) = b/(a+b); bottom half d/(c+d)
        col_top = r2 >= (a / ab)
        col_bot = r2 >= (c / (1.0 - ab)) if abc < 1.0 else np.zeros(m, bool)
        col = np.where(right, col_bot, col_top)
        src = (src << 1) | right.astype(np.int64)
        dst = (dst << 1) | col.astype(np.int64)
    # permute vertex ids to break the Kronecker correlation with id bits
    # (advance the rng either way so both variants share an edge topology)
    perm = rng.permutation(n)
    if permute_ids:
        src, dst = perm[src], perm[dst]
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = edge_weights(setting, src.shape[0], seed=seed + 1)
    return Graph.from_edges(n, src, dst, w, edge_block=edge_block)


def erdos_renyi_graph(
    n: int,
    avg_degree: float = 8.0,
    *,
    seed: int = 0,
    setting: str = "w1",
    directed: bool = True,
    edge_block: int = 256,
) -> Graph:
    m = int(n * avg_degree)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = edge_weights(setting, src.shape[0], seed=seed + 1)
    return Graph.from_edges(n, src, dst, w, edge_block=edge_block)


def barabasi_albert_graph(
    n: int,
    m_attach: int = 4,
    *,
    seed: int = 0,
    setting: str = "w1",
    edge_block: int = 256,
) -> Graph:
    """Preferential attachment; symmetrized (undirected, like Orkut/Friendster)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = list(range(m_attach))
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m_attach, n):
        for t in targets:
            src_l.append(v)
            dst_l.append(t)
        repeated.extend(targets)
        repeated.extend([v] * m_attach)
        idx = rng.integers(0, len(repeated), m_attach)
        targets = [repeated[i] for i in idx]
    src = np.array(src_l + dst_l, dtype=np.int64)
    dst = np.array(dst_l + src_l, dtype=np.int64)
    w = edge_weights(setting, src.shape[0], seed=seed + 1)
    return Graph.from_edges(n, src, dst, w, edge_block=edge_block)
