"""Graph IO: SNAP edge-list format (the paper's datasets) + npz caching."""
from __future__ import annotations

import os

import numpy as np

from repro.graphs.structs import Graph
from repro.graphs.generators import edge_weights, make_wc_weights


def load_snap_edgelist(
    path: str,
    *,
    setting: str = "w1",
    directed: bool = True,
    seed: int = 0,
    edge_block: int = 256,
) -> Graph:
    """Parse a SNAP-style whitespace edge list (# comments allowed).

    Vertex ids are compacted to [0, n). Undirected graphs are symmetrized.
    ``setting`` follows the paper's five influence settings, plus "wc".
    """
    src_l: list[int] = []
    dst_l: list[int] = []
    with open(path) as f:
        for line in f:
            if line.startswith(("#", "%")):
                continue
            parts = line.split()
            if len(parts) < 2:
                continue
            src_l.append(int(parts[0]))
            dst_l.append(int(parts[1]))
    src = np.asarray(src_l, dtype=np.int64)
    dst = np.asarray(dst_l, dtype=np.int64)
    # compact ids to [0, n): np.unique returns sorted ids, so searchsorted is
    # an exact vectorized inverse (the per-edge dict loop dominated load time
    # on the paper's larger SNAP graphs)
    ids = np.unique(np.concatenate([src, dst]))
    src = np.searchsorted(ids, src).astype(np.int64)
    dst = np.searchsorted(ids, dst).astype(np.int64)
    n = int(ids.size)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if setting == "wc":
        w = make_wc_weights(n, dst)
    else:
        w = edge_weights(setting, src.shape[0], seed=seed)
    return Graph.from_edges(n, src, dst, w, edge_block=edge_block)


def save_npz(path: str, g: Graph) -> None:
    np.savez_compressed(
        path, n=g.n, n_pad=g.n_pad, m_real=g.m_real, src=g.src, dst=g.dst, weight=g.weight
    )


def load_npz(path: str) -> Graph:
    z = np.load(path)
    return Graph(
        n=int(z["n"]),
        src=z["src"],
        dst=z["dst"],
        weight=z["weight"],
        n_pad=int(z["n_pad"]),
        m_real=int(z["m_real"]),
    )


def cached(path: str, builder) -> Graph:
    """Build-or-load helper used by benchmarks/examples."""
    if os.path.exists(path):
        return load_npz(path)
    g = builder()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    save_npz(path, g)
    return g
