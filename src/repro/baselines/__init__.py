"""Baselines the paper compares against, plus the independent scoring oracle."""
from repro.baselines.mc_oracle import influence_score, exact_greedy
from repro.baselines.ris import ris_find_seeds

__all__ = ["influence_score", "exact_greedy", "ris_find_seeds"]
