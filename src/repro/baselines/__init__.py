"""Baselines the paper compares against, plus the independent scoring oracle."""
from repro.baselines.mc_oracle import (exact_greedy, influence_score,
                                       make_live_sampler, sample_live_mask)
from repro.baselines.ris import ris_find_seeds

__all__ = ["influence_score", "exact_greedy", "ris_find_seeds",
           "make_live_sampler", "sample_live_mask"]
