"""Independent Monte-Carlo oracle (paper §5.1: "we implemented a separate
oracle ... that does not have any optimizations and uses a large number of
samples employing standard RNGs to verify the validity of the results").

Deliberately decoupled from DiFuseR's machinery: numpy PRNG (not the XOR
hash scheme), explicit per-simulation BFS over freshly sampled edges. Slow
and boring on purpose — it is the referee for every quality claim in the
benchmarks, plus an exact-greedy reference for small graphs.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.structs import CSR, Graph


def _bfs_reach(csr: CSR, sampled: np.ndarray, seeds: np.ndarray) -> int:
    """|vertices reachable from seeds via sampled edges| (sampled: bool[m])."""
    visited = np.zeros(csr.n, dtype=bool)
    visited[seeds] = True
    frontier = list(int(s) for s in np.unique(seeds))
    while frontier:
        new_frontier = []
        for u in frontier:
            lo, hi = csr.indptr[u], csr.indptr[u + 1]
            nbrs = csr.indices[lo:hi][sampled[lo:hi]]
            for v in nbrs:
                if not visited[v]:
                    visited[v] = True
                    new_frontier.append(int(v))
        frontier = new_frontier
    return int(visited.sum())


def influence_score(g: Graph, seeds: np.ndarray, *, num_sims: int = 200,
                    rng_seed: int = 12345) -> float:
    """Expected influence of ``seeds`` under IC, by plain Monte-Carlo."""
    csr = g.csr()
    rng = np.random.default_rng(rng_seed)
    seeds = np.asarray(seeds, dtype=np.int64)
    total = 0
    for _ in range(num_sims):
        sampled = rng.random(csr.weight.shape[0]) < csr.weight
        total += _bfs_reach(csr, sampled, seeds)
    return total / num_sims


def exact_greedy(g: Graph, k: int, *, num_sims: int = 200, rng_seed: int = 999) -> tuple[np.ndarray, float]:
    """CELF-free exact greedy with shared samples (the classic Kempe et al.
    randomized-greedy reference, feasible only for small graphs).

    Pre-samples ``num_sims`` graphs once, then per round picks the vertex
    with the largest exact marginal coverage.
    """
    csr = g.csr()
    rng = np.random.default_rng(rng_seed)
    n = csr.n
    sampled = [rng.random(csr.weight.shape[0]) < csr.weight for _ in range(num_sims)]
    covered = [np.zeros(n, dtype=bool) for _ in range(num_sims)]
    seeds = []
    # cache per (sim, vertex) reach sets lazily as frozensets of indices
    for _ in range(k):
        best_v, best_gain = -1, -1.0
        for v in range(n):
            if v in seeds:
                continue
            gain = 0
            for r in range(num_sims):
                if covered[r][v]:
                    continue
                vis = covered[r].copy()
                before = int(vis.sum())
                stack = [v]
                vis[v] = True
                while stack:
                    u = stack.pop()
                    lo, hi = csr.indptr[u], csr.indptr[u + 1]
                    for w_idx in range(lo, hi):
                        if sampled[r][w_idx]:
                            w = csr.indices[w_idx]
                            if not vis[w]:
                                vis[w] = True
                                stack.append(int(w))
                gain += int(vis.sum()) - before
            if gain > best_gain:
                best_gain, best_v = gain, v
        seeds.append(best_v)
        for r in range(num_sims):
            if not covered[r][best_v]:
                stack = [best_v]
                covered[r][best_v] = True
                while stack:
                    u = stack.pop()
                    lo, hi = csr.indptr[u], csr.indptr[u + 1]
                    for w_idx in range(lo, hi):
                        if sampled[r][w_idx]:
                            w = csr.indices[w_idx]
                            if not covered[r][w]:
                                covered[r][w] = True
                                stack.append(int(w))
    final = float(np.mean([c.sum() for c in covered]))
    return np.asarray(seeds, dtype=np.int32), final
