"""Independent Monte-Carlo oracle (paper §5.1: "we implemented a separate
oracle ... that does not have any optimizations and uses a large number of
samples employing standard RNGs to verify the validity of the results").

Deliberately decoupled from DiFuseR's machinery: numpy PRNG (not the XOR
hash scheme), explicit per-simulation BFS over freshly sampled edges. Slow
and boring on purpose — it is the referee for every quality claim in the
benchmarks, plus an exact-greedy reference for small graphs.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.structs import CSR, Graph


def make_live_sampler(g: Graph, model: str):
    """Precompute a model's host state once and return a closure drawing
    bool[m_real] live-edge samples of ``g`` in the graph's edge order —
    the per-sim cost inside the oracle loops is just the RNG draw.
    Randomness comes from the numpy PRNG — deliberately independent of the
    fused XOR-hash scheme, so this referees it."""
    from repro.diffusion import resolve

    sampler = resolve(model).mc_sampler(g)
    return lambda rng: sampler(rng)[: g.m_real]


def sample_live_mask(g: Graph, model: str, rng: np.random.Generator) -> np.ndarray:
    """One live-edge sample (one-shot convenience over ``make_live_sampler``)."""
    return make_live_sampler(g, model)(rng)


def _bfs_reach(csr: CSR, sampled: np.ndarray, seeds: np.ndarray) -> int:
    """|vertices reachable from seeds via sampled edges| (sampled: bool[m])."""
    visited = np.zeros(csr.n, dtype=bool)
    visited[seeds] = True
    frontier = list(int(s) for s in np.unique(seeds))
    while frontier:
        new_frontier = []
        for u in frontier:
            lo, hi = csr.indptr[u], csr.indptr[u + 1]
            nbrs = csr.indices[lo:hi][sampled[lo:hi]]
            for v in nbrs:
                if not visited[v]:
                    visited[v] = True
                    new_frontier.append(int(v))
        frontier = new_frontier
    return int(visited.sum())


def influence_score(g: Graph, seeds: np.ndarray, *, num_sims: int = 200,
                    rng_seed: int = 12345, model: str = "wc") -> float:
    """Expected influence of ``seeds`` under a registered diffusion model
    (default ``wc`` — per-edge probabilities from the graph's weights, the
    historical behaviour), by plain Monte-Carlo."""
    csr = g.csr()
    rng = np.random.default_rng(rng_seed)
    seeds = np.asarray(seeds, dtype=np.int64)
    total = 0
    if model in (None, "wc"):
        # legacy draw pattern kept bit-for-bit (same RNG stream as pre-zoo)
        for _ in range(num_sims):
            sampled = rng.random(csr.weight.shape[0]) < csr.weight
            total += _bfs_reach(csr, sampled, seeds)
    else:
        draw = make_live_sampler(g, model)
        for _ in range(num_sims):
            total += _bfs_reach(csr, draw(rng)[csr.order], seeds)
    return total / num_sims


def exact_greedy(g: Graph, k: int, *, num_sims: int = 200, rng_seed: int = 999,
                 model: str = "wc") -> tuple[np.ndarray, float]:
    """CELF-free exact greedy with shared samples (the classic Kempe et al.
    randomized-greedy reference, feasible only for small graphs).

    Pre-samples ``num_sims`` live-edge graphs once under ``model``, then per
    round picks the vertex with the largest exact marginal coverage.
    """
    csr = g.csr()
    rng = np.random.default_rng(rng_seed)
    n = csr.n
    if model in (None, "wc"):
        sampled = [rng.random(csr.weight.shape[0]) < csr.weight for _ in range(num_sims)]
    else:
        draw = make_live_sampler(g, model)
        sampled = [draw(rng)[csr.order] for _ in range(num_sims)]
    covered = [np.zeros(n, dtype=bool) for _ in range(num_sims)]
    seeds = []
    # cache per (sim, vertex) reach sets lazily as frozensets of indices
    for _ in range(k):
        best_v, best_gain = -1, -1.0
        for v in range(n):
            if v in seeds:
                continue
            gain = 0
            for r in range(num_sims):
                if covered[r][v]:
                    continue
                vis = covered[r].copy()
                before = int(vis.sum())
                stack = [v]
                vis[v] = True
                while stack:
                    u = stack.pop()
                    lo, hi = csr.indptr[u], csr.indptr[u + 1]
                    for w_idx in range(lo, hi):
                        if sampled[r][w_idx]:
                            w = csr.indices[w_idx]
                            if not vis[w]:
                                vis[w] = True
                                stack.append(int(w))
                gain += int(vis.sum()) - before
            if gain > best_gain:
                best_gain, best_v = gain, v
        seeds.append(best_v)
        for r in range(num_sims):
            if not covered[r][best_v]:
                stack = [best_v]
                covered[r][best_v] = True
                while stack:
                    u = stack.pop()
                    lo, hi = csr.indptr[u], csr.indptr[u + 1]
                    for w_idx in range(lo, hi):
                        if sampled[r][w_idx]:
                            w = csr.indices[w_idx]
                            if not covered[r][w]:
                                covered[r][w] = True
                                stack.append(int(w))
    final = float(np.mean([c.sum() for c in covered]))
    return np.asarray(seeds, dtype=np.int32), final
