"""RIS/IMM-family baseline (the algorithm behind gIM [19] and cuRipples
[20], the paper's two competitors).

Reverse Influence Sampling (Borgs et al. [28]): sample random reverse-
reachable (RR) sets — pick a uniform random root, BFS *backwards* over
IC-sampled in-edges — then greedily pick K seeds covering the most RR sets
(max-cover). IMM [24] chooses the number of RR sets adaptively from
(epsilon, delta); we expose both the adaptive count (simplified IMM bound)
and a fixed count.

Host-side numpy: the baseline exists for quality/speed comparison in the
paper-table benchmarks, mirroring how gIM/cuRipples are CPU+CUDA codes
external to DiFuseR.
"""
from __future__ import annotations

import math

import numpy as np

from repro.graphs.structs import Graph


def _reverse_csr(g: Graph):
    src = g.src[: g.m_real]
    dst = g.dst[: g.m_real]
    w = g.weight[: g.m_real]
    order = np.argsort(dst, kind="stable")
    dst_s, src_s, w_s = dst[order], src[order], w[order]
    counts = np.bincount(dst_s, minlength=g.n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, src_s.astype(np.int64), w_s


def _sample_rr_set(indptr, indices, weight, root: int, rng) -> np.ndarray:
    """One reverse-reachable set from ``root`` (IC edge re-sampling on the fly)."""
    visited = {root}
    stack = [root]
    while stack:
        v = stack.pop()
        lo, hi = indptr[v], indptr[v + 1]
        if hi == lo:
            continue
        r = rng.random(hi - lo)
        take = r < weight[lo:hi]
        for u in indices[lo:hi][take]:
            if u not in visited:
                visited.add(int(u))
                stack.append(int(u))
    return np.fromiter(visited, dtype=np.int64)


def imm_num_rr_sets(n: int, k: int, epsilon: float = 0.5, ell: float = 1.0) -> int:
    """Simplified IMM theta bound (Tang et al. [24], eq. 9 flavor)."""
    lognk = math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)
    alpha = math.sqrt(ell * math.log(n) + math.log(2))
    beta = math.sqrt((1 - 1 / math.e) * (lognk + ell * math.log(n) + math.log(2)))
    lam = 2 * n * ((1 - 1 / math.e) * alpha + beta) ** 2 / (epsilon ** 2)
    return max(int(lam / n), 256)  # / OPT lower-bounded by n/... keep it sane


def ris_find_seeds(g: Graph, k: int, *, epsilon: float = 0.5, num_rr_sets: int | None = None,
                   rng_seed: int = 7, max_rr_sets: int = 200_000) -> tuple[np.ndarray, float]:
    """Greedy max-cover over RR sets. Returns (seeds, covered_fraction * n =
    unbiased influence estimate)."""
    indptr, indices, weight = _reverse_csr(g)
    rng = np.random.default_rng(rng_seed)
    theta = num_rr_sets if num_rr_sets is not None else min(
        imm_num_rr_sets(g.n, k, epsilon), max_rr_sets)
    rr_sets = []
    member_of: list[list[int]] = [[] for _ in range(g.n)]
    for i in range(theta):
        root = int(rng.integers(0, g.n))
        rr = _sample_rr_set(indptr, indices, weight, root, rng)
        rr_sets.append(rr)
        for u in rr:
            member_of[u].append(i)

    cover_count = np.zeros(g.n, dtype=np.int64)
    for rr in rr_sets:
        cover_count[rr] += 1
    covered = np.zeros(theta, dtype=bool)
    seeds = []
    for _ in range(k):
        s = int(np.argmax(cover_count))
        seeds.append(s)
        for i in member_of[s]:
            if not covered[i]:
                covered[i] = True
                for u in rr_sets[i]:
                    cover_count[u] -= 1
    est_influence = float(covered.sum()) / theta * g.n
    return np.asarray(seeds, dtype=np.int32), est_influence
