"""Shared CLI surface of the IM launchers.

``launch/im.py`` and ``launch/serve_im.py`` historically copy-pasted the
``--graph/--setting/--model/--partition/--seed`` group and let the help
strings drift apart; this module is the single copy. It also owns
``make_graph`` (the graph-spec parser both drivers and the benchmarks use)
and the ``--backend`` flag that selects a :mod:`repro.runtime` backend
instead of hand-rolled mesh setup.
"""
from __future__ import annotations

import argparse

from repro.graphs import barabasi_albert_graph, erdos_renyi_graph, rmat_graph
from repro.graphs.io import load_snap_edgelist


def make_graph(spec: str, setting: str, seed: int):
    """Parse ``--graph`` specs: rmat:<scale> | rmat-skew:<scale> | er:<n> |
    ba:<n> | snap:<path>."""
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg), setting=setting, seed=seed)
    if kind == "rmat-skew":
        # heavier Kronecker tail + raw (unpermuted) ids: hubs cluster at low
        # ids — the regime the partition planners exist for
        return rmat_graph(int(arg), edge_factor=8, a=0.65, b=0.15, c=0.15,
                          setting=setting, seed=seed, permute_ids=False)
    if kind == "er":
        return erdos_renyi_graph(int(arg), setting=setting, seed=seed)
    if kind == "ba":
        return barabasi_albert_graph(int(arg), setting=setting, seed=seed)
    if kind == "snap":
        return load_snap_edgelist(arg, setting=setting, seed=seed)
    raise ValueError(spec)


def add_common_im_args(ap: argparse.ArgumentParser, *,
                       graph_default: str = "rmat:12",
                       registers_default: int = 1024) -> argparse.ArgumentParser:
    """The shared ``--graph/--setting/--model/--partition/--seed`` group
    (plus ``--registers`` and ``--backend``) of every IM driver."""
    grp = ap.add_argument_group("workload (shared IM driver surface)")
    grp.add_argument("--graph", default=graph_default,
                     help="rmat:<scale>|rmat-skew:<scale>|er:<n>|ba:<n>|snap:<path>")
    grp.add_argument("--setting", default="0.1",
                     help="0.005|0.01|0.1|N0.05|U0.1|wc (paper §5)")
    grp.add_argument("--model", default="wc",
                     help="diffusion model spec: wc|ic[:p]|lt|dic[:lambda] "
                          "(repro.diffusion registry; wc = backward-"
                          "compatible default)")
    grp.add_argument("--partition", default="block",
                     help="vertex-assignment strategy for the 2-D partition: "
                          "block|degree|edge|random (repro.partition "
                          "registry; seed sets are identical across "
                          "strategies)")
    grp.add_argument("--registers", type=int, default=registers_default)
    grp.add_argument("--backend", default="auto",
                     help="execution backend: auto|single|serial|mesh "
                          "(repro.runtime registry; 'auto' picks mesh when "
                          "jax + devices allow a sharded run, else serial, "
                          "else single)")
    grp.add_argument("--seed", type=int, default=0)
    return ap
