"""Shared CLI surface of the IM launchers.

``launch/im.py`` and ``launch/serve_im.py`` historically copy-pasted the
``--graph/--setting/--model/--partition/--seed`` group and let the help
strings drift apart; this module is the single copy. It also owns
``make_graph`` (the graph-spec parser both drivers and the benchmarks use)
and the ``--backend`` flag that selects a :mod:`repro.runtime` backend
instead of hand-rolled mesh setup.
"""
from __future__ import annotations

import argparse
import contextlib
import time

from repro.graphs import barabasi_albert_graph, erdos_renyi_graph, rmat_graph
from repro.graphs.io import load_snap_edgelist
from repro.obs import metrics, trace


@trace.traced("launch.make_graph", phase="other")
def make_graph(spec: str, setting: str, seed: int):
    """Parse ``--graph`` specs: rmat:<scale> | rmat-skew:<scale> | er:<n> |
    ba:<n> | snap:<path>."""
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg), setting=setting, seed=seed)
    if kind == "rmat-skew":
        # heavier Kronecker tail + raw (unpermuted) ids: hubs cluster at low
        # ids — the regime the partition planners exist for
        return rmat_graph(int(arg), edge_factor=8, a=0.65, b=0.15, c=0.15,
                          setting=setting, seed=seed, permute_ids=False)
    if kind == "er":
        return erdos_renyi_graph(int(arg), setting=setting, seed=seed)
    if kind == "ba":
        return barabasi_albert_graph(int(arg), setting=setting, seed=seed)
    if kind == "snap":
        return load_snap_edgelist(arg, setting=setting, seed=seed)
    raise ValueError(spec)


def add_common_im_args(ap: argparse.ArgumentParser, *,
                       graph_default: str = "rmat:12",
                       registers_default: int = 1024) -> argparse.ArgumentParser:
    """The shared ``--graph/--setting/--model/--partition/--seed`` group
    (plus ``--registers`` and ``--backend``) of every IM driver."""
    grp = ap.add_argument_group("workload (shared IM driver surface)")
    grp.add_argument("--graph", default=graph_default,
                     help="rmat:<scale>|rmat-skew:<scale>|er:<n>|ba:<n>|snap:<path>")
    grp.add_argument("--setting", default="0.1",
                     help="0.005|0.01|0.1|N0.05|U0.1|wc (paper §5)")
    grp.add_argument("--model", default="wc",
                     help="diffusion model spec: wc|ic[:p]|lt|dic[:lambda] "
                          "(repro.diffusion registry; wc = backward-"
                          "compatible default)")
    grp.add_argument("--partition", default="block",
                     help="vertex-assignment strategy for the 2-D partition: "
                          "block|degree|edge|random (repro.partition "
                          "registry; seed sets are identical across "
                          "strategies)")
    grp.add_argument("--registers", type=int, default=registers_default)
    grp.add_argument("--backend", default="auto",
                     help="execution backend: auto|single|serial|mesh "
                          "(repro.runtime registry; 'auto' picks mesh when "
                          "jax + devices allow a sharded run, else serial, "
                          "else single)")
    add_tuning_arg(grp)
    grp.add_argument("--seed", type=int, default=0)
    add_obs_args(ap)
    return ap


def add_tuning_arg(ap) -> None:
    """The shared ``--tuning`` flag (``RunSpec.tuning`` / :mod:`repro.tune`).

    Accepts an ``ArgumentParser`` or an argument group; drivers that build
    their own workload flags (dryrun, runtime_bench) call this directly."""
    ap.add_argument("--tuning", default="off",
                    choices=("off", "cached", "auto"),
                    help="measured kernel tuning (repro.tune): off = "
                         "hard-coded defaults; cached = apply TUNE_cache."
                         "json winners (a miss falls back to the defaults); "
                         "auto = measure misses on the actual graph and "
                         "persist winners. Performance-only: results are "
                         "bit-identical across modes")


def add_obs_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Just the ``--trace``/``--metrics`` observability group — for drivers
    (benchmarks, dryrun) that have their own workload flags but share the
    :func:`observe` context manager."""
    obs = ap.add_argument_group("observability (repro.obs)")
    obs.add_argument("--trace", default=None, metavar="OUT.json",
                     help="record spans and write Chrome trace-event JSON "
                          "(open in ui.perfetto.dev; one lane per phase)")
    obs.add_argument("--metrics", default=None, metavar="OUT.jsonl",
                     help="write a JSONL metrics snapshot (counters/gauges/"
                          "histograms) at exit")
    return ap


@contextlib.contextmanager
def observe(args):
    """Wrap a driver run in the observability surface ``--trace`` /
    ``--metrics`` request: start the span recorder when a trace path is
    given, and at exit write the Chrome trace + metrics snapshot and print a
    one-line span-coverage summary (top-level span seconds / wall seconds —
    the "spans account for the run" acceptance number). No flags -> exact
    historical behaviour (recorder stays off, nothing written)."""
    trace_path = getattr(args, "trace", None)
    metrics_path = getattr(args, "metrics", None)
    rec = trace.get_recorder()
    if trace_path:
        rec.start()
    t0 = time.perf_counter()
    try:
        yield rec
    finally:
        wall = time.perf_counter() - t0
        if trace_path:
            rec.stop()
            n = rec.save_chrome_trace(trace_path)
            cov = rec.top_level_seconds() / wall if wall > 0 else 0.0
            print(f"trace: {n} spans -> {trace_path} "
                  f"(lanes: {', '.join(sorted(rec.phases_seen()))}; "
                  f"span coverage {cov * 100:.1f}% of {wall:.2f}s wall)")
        if metrics_path:
            n = metrics.registry().write_jsonl(metrics_path)
            print(f"metrics: {n} series -> {metrics_path}")
