"""DiFuseR driver — the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.im --graph rmat:14 --setting 0.1 \
        --k 50 --registers 1024 --devices 8 --validate

--devices > 1 forks the process env with fake XLA devices? No — it expects
the caller to export XLA_FLAGS=--xla_force_host_platform_device_count=N
(or run on a real multi-device backend) and builds a (v, s) mesh over them.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.baselines import influence_score, ris_find_seeds
from repro.core.difuser import DiFuserConfig, find_seeds
from repro.graphs import barabasi_albert_graph, erdos_renyi_graph, rmat_graph
from repro.graphs.io import load_snap_edgelist


def make_graph(spec: str, setting: str, seed: int):
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg), setting=setting, seed=seed)
    if kind == "rmat-skew":
        # heavier Kronecker tail + raw (unpermuted) ids: hubs cluster at low
        # ids — the regime the partition planners exist for
        return rmat_graph(int(arg), edge_factor=8, a=0.65, b=0.15, c=0.15,
                          setting=setting, seed=seed, permute_ids=False)
    if kind == "er":
        return erdos_renyi_graph(int(arg), setting=setting, seed=seed)
    if kind == "ba":
        return barabasi_albert_graph(int(arg), setting=setting, seed=seed)
    if kind == "snap":
        return load_snap_edgelist(arg, setting=setting, seed=seed)
    raise ValueError(spec)


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12", help="rmat:<scale>|er:<n>|ba:<n>|snap:<path>")
    ap.add_argument("--setting", default="0.1",
                    help="0.005|0.01|0.1|N0.05|U0.1|wc (paper §5)")
    ap.add_argument("--model", default="wc",
                    help="diffusion model spec: wc|ic[:p]|lt|dic[:lambda] "
                         "(repro.diffusion registry)")
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--registers", type=int, default=1024)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--schedule", default="ring", choices=["ring", "allgather"])
    ap.add_argument("--partition", default="block",
                    help="vertex-assignment strategy for the 2-D partition: "
                         "block|degree|edge|random (repro.partition registry; "
                         "seed sets are identical across strategies)")
    ap.add_argument("--mu-v", type=int, default=0,
                    help="vertex shards of the (data, model) mesh "
                         "(0 = historical default: 2 when --devices is even)")
    ap.add_argument("--no-fasst", action="store_true")
    ap.add_argument("--validate", action="store_true", help="score seeds with the MC oracle")
    ap.add_argument("--ris", action="store_true", help="also run the RIS/IMM baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = make_graph(args.graph, args.setting, args.seed)
    print(f"graph n={g.n:,} m={g.m_real:,}")
    out = {}

    t0 = time.time()
    if args.devices > 1:
        import jax

        from repro.core.distributed import DistributedConfig, find_seeds_distributed
        from repro.launch.mesh import make_im_mesh

        ndev = len(jax.devices())
        if ndev < args.devices:
            raise SystemExit(
                f"need {args.devices} devices, found {ndev}: export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={args.devices}")
        mesh = make_im_mesh(args.devices, mu_v=args.mu_v)
        cfg = DistributedConfig(num_registers=args.registers, seed=args.seed,
                                schedule=args.schedule, fasst=not args.no_fasst,
                                model=args.model, partition=args.partition)
        res, part = find_seeds_distributed(g, args.k, mesh, cfg)
        out["max_shard_edges"] = int(part.edge_counts.max())
        stats = part.stats()
        out["edge_imbalance"] = stats.edge_imbalance
        print(f"partition: {stats.describe()}")
    else:
        cfg = DiFuserConfig(num_registers=args.registers, seed=args.seed,
                            sort_x=not args.no_fasst, model=args.model)
        res = find_seeds(g, args.k, cfg)
        if args.partition != "block":
            # no mesh on one device, but the planner's cost model still
            # answers "how would this graph shard" — print it for free
            from repro.partition import plan_partition

            plan = plan_partition(g.sorted_by_dst(), 8, mu_s=1,
                                  strategy=args.partition, x=res.x,
                                  seed=args.seed, model=args.model)
            out["predicted_edge_imbalance"] = plan.predicted.edge_imbalance
            print(f"partition plan (hypothetical 8-shard): "
                  f"{plan.predicted.describe()}")
    dt = time.time() - t0
    out.update(time_s=round(dt, 2), seeds=res.seeds.tolist(),
               difuser_score=float(res.scores[-1]), rebuilds=int(res.rebuilds.sum()))
    print(f"difuser: {dt:.2f}s influence(est)={res.scores[-1]:.1f} "
          f"rebuilds={int(res.rebuilds.sum())}/{args.k}")

    if args.validate:
        oracle = influence_score(g, res.seeds, num_sims=100, rng_seed=args.seed + 99,
                                 model=args.model)
        out["oracle_score"] = oracle
        print(f"oracle(difuser seeds) = {oracle:.1f}")
    if args.ris:
        t0 = time.time()
        rs, rest = ris_find_seeds(g, args.k, num_rr_sets=4000, rng_seed=args.seed)
        rt = time.time() - t0
        roracle = influence_score(g, rs, num_sims=100, rng_seed=args.seed + 99)
        out.update(ris_time_s=round(rt, 2), ris_oracle=roracle)
        print(f"ris/imm: {rt:.2f}s oracle={roracle:.1f} "
              f"(quality ratio {out.get('oracle_score', roracle)/max(roracle,1e-9):.3f})")
    return out


if __name__ == "__main__":
    run()
