"""DiFuseR driver — the paper's workload end-to-end.

    PYTHONPATH=src python -m repro im --graph rmat:14 --setting 0.1 \
        --k 50 --registers 1024 --devices 8 --validate

Execution is selected by ``--backend`` (repro.runtime registry):
``auto`` resolves to the jitted single-device driver for an unsharded run,
to the ``shard_map`` mesh runtime when ``--devices > 1`` and jax supports
it (export XLA_FLAGS=--xla_force_host_platform_device_count=N for a host
mesh), and to the serial-ring executor otherwise — all three return
bit-identical seed sets.
"""
from __future__ import annotations

import argparse
import time

from repro.baselines import influence_score, ris_find_seeds
from repro.launch.common import (add_common_im_args, make_graph,  # noqa: F401
                                 observe)
# make_graph is re-exported: serve_im and the benchmarks import it from here


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    add_common_im_args(ap)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--schedule", default="ring", choices=["ring", "allgather"])
    ap.add_argument("--mu-v", type=int, default=0,
                    help="vertex shards of the (data, model) mesh "
                         "(0 = historical default: 2 when --devices is even)")
    ap.add_argument("--no-fasst", action="store_true")
    ap.add_argument("--validate", action="store_true", help="score seeds with the MC oracle")
    ap.add_argument("--ris", action="store_true", help="also run the RIS/IMM baseline")
    args = ap.parse_args(argv)
    with observe(args):
        return _run(args)


def _run(args) -> dict:
    from repro.runtime import RunSpec, run as run_im

    g = make_graph(args.graph, args.setting, args.seed)
    print(f"graph n={g.n:,} m={g.m_real:,}")
    out = {}

    # shard grid: --devices keeps its historical meaning (mesh size); an
    # explicit sharded backend without --devices gets the 2x2 test grid
    if args.devices > 1:
        mu_v = args.mu_v if args.mu_v > 0 else (2 if args.devices % 2 == 0 else 1)
        if args.devices % mu_v != 0:
            raise SystemExit(f"--devices {args.devices} not divisible by mu_v={mu_v}")
        mu_s = args.devices // mu_v
    elif args.backend in ("serial", "mesh"):
        mu_v = args.mu_v if args.mu_v > 0 else 2
        mu_s = 2
    else:
        mu_v = mu_s = 1

    spec = RunSpec(
        num_registers=args.registers, seed=args.seed, model=args.model,
        sort_x=not args.no_fasst, fasst=not args.no_fasst,
        backend=args.backend, mu_v=mu_v, mu_s=mu_s,
        partition=args.partition, schedule=args.schedule,
        tuning=args.tuning)

    t0 = time.time()
    report = run_im(g, args.k, spec)
    res = report.result
    out["backend"] = report.backend
    if report.partition is not None:
        part = report.partition
        out["max_shard_edges"] = int(part.edge_counts.max())
        stats = part.stats()
        out["edge_imbalance"] = stats.edge_imbalance
        print(f"backend={report.backend} partition: {stats.describe()}")
    else:
        print(f"backend={report.backend}")
        if args.partition != "block":
            # no shard grid requested, but the planner's cost model still
            # answers "how would this graph shard" — print it for free
            from repro.partition import plan_partition

            plan = plan_partition(g.sorted_by_dst(), 8, mu_s=1,
                                  strategy=args.partition, x=res.x,
                                  seed=args.seed, model=args.model)
            out["predicted_edge_imbalance"] = plan.predicted.edge_imbalance
            print(f"partition plan (hypothetical 8-shard): "
                  f"{plan.predicted.describe()}")
    dt = time.time() - t0
    out.update(time_s=round(dt, 2), seeds=res.seeds.tolist(),
               difuser_score=float(res.scores[-1]), rebuilds=int(res.rebuilds.sum()))
    print(f"difuser: {dt:.2f}s influence(est)={res.scores[-1]:.1f} "
          f"rebuilds={int(res.rebuilds.sum())}/{args.k}")

    if args.validate:
        oracle = influence_score(g, res.seeds, num_sims=100, rng_seed=args.seed + 99,
                                 model=args.model)
        out["oracle_score"] = oracle
        print(f"oracle(difuser seeds) = {oracle:.1f}")
    if args.ris:
        t0 = time.time()
        rs, rest = ris_find_seeds(g, args.k, num_rr_sets=4000, rng_seed=args.seed)
        rt = time.time() - t0
        roracle = influence_score(g, rs, num_sims=100, rng_seed=args.seed + 99)
        out.update(ris_time_s=round(rt, 2), ris_oracle=roracle)
        print(f"ris/imm: {rt:.2f}s oracle={roracle:.1f} "
              f"(quality ratio {out.get('oracle_score', roracle)/max(roracle,1e-9):.3f})")
    return out


if __name__ == "__main__":
    run()
