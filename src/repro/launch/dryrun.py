"""Production-scale dry-run: .lower().compile() the DiFuseR IM cells on the
production meshes, recording memory_analysis / cost_analysis / collective
wire bytes for the roofline report (benchmarks/roofline_report.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch difuser-twitter \
        --mesh single --schedule allgather
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization. Everything below is ordinary.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_production_mesh
from repro.utils.hlo import collective_stats


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# DiFuseR IM cells (paper workloads at production scale; shapes only)
# ---------------------------------------------------------------------------

IM_CELLS = {
    # name: (n vertices, edges, J registers, duplication factor estimate)
    "difuser-livejournal": (1 << 23, 1 << 27, 2048, 1.6),
    "difuser-twitter": (1 << 26, 1 << 31, 1024, 1.4),
    "difuser-friendster": (1 << 26, 1 << 31, 2048, 1.4),
}


def _tuned_knobs(name: str, tuning: str) -> dict:
    """Cached winners for a cell's edge bucket — the tuned knobs that
    survive a shapes-only lowering: the ``bucket_propagate`` winner's
    ``local_sweeps`` and the ``fused_sweep`` winner's ``fuse_sweeps``
    (whether that prologue lowers as one fused/rolled loop region).
    ``tuning="auto"`` cannot measure here — there is no real graph — so
    both non-off modes read the cache and fall back to today's defaults
    (0 / unfused) on a miss."""
    knobs = {"local_sweeps": 0, "fuse_sweeps": False}
    if tuning == "off":
        return knobs
    from repro.tune import cache_key, default_cache

    _, m, _, _ = IM_CELLS[name]
    cache = default_cache()
    cfg = cache.lookup(cache_key(
        "bucket_propagate", backend="mesh", impl="ref", model="wc",
        num_edges=int(m)))
    if cfg is not None:
        knobs["local_sweeps"] = int(cfg.local_sweeps)
    fused = cache.lookup(cache_key(
        "fused_sweep", backend="mesh", impl="ref", model="wc",
        num_edges=int(m)))
    if fused is not None:
        knobs["fuse_sweeps"] = bool(fused.fuse_sweeps)
    return knobs


def lower_im_cell(name: str, mesh, *, k: int = 4, schedule: str = "ring",
                  local_sweeps: int = 0, fuse_sweeps: bool = False):
    """Lower the full distributed DiFuseR loop with ShapeDtypeStruct inputs
    (no host graph build — bucket sizes come from the duplication model)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import Partition2D, _make_distributed_fn

    n, m, j, dup = IM_CELLS[name]
    axes = mesh.axis_names
    vertex_axis = "data"
    sim_axes = tuple(a for a in axes if a in ("pod", "model"))
    mu_v = mesh.shape[vertex_axis]
    mu_s = _prod(mesh, sim_axes)
    n_pad = n + ((-n) % mu_v)
    n_loc = n_pad // mu_v
    j_loc = j // mu_s
    bucket = int(np.ceil(m * dup / (mu_v * mu_s * mu_v) / 256) * 256)

    dummy = np.zeros((1,), np.int32)
    dummy_steps = (dummy,) * mu_v
    part = Partition2D(
        n=n, n_pad=n_pad, n_loc=n_loc, j_loc=j_loc, mu_v=mu_v, mu_s=mu_s,
        x_shards=dummy, owned_ids=dummy,
        p_h=dummy_steps, p_w=dummy_steps, p_r=dummy_steps, p_t=dummy_steps,
        p_l=dummy_steps,
        c_h=dummy_steps, c_w=dummy_steps, c_r=dummy_steps, c_t=dummy_steps,
        c_l=dummy_steps,
        edge_counts=dummy, p_counts=dummy, c_counts=dummy,
        comm_bytes_per_sweep=(mu_v - 1) * n_loc * j_loc)

    maker = _make_distributed_fn(
        part, k=k, vertex_axis=vertex_axis, sim_axes=sim_axes, estimator="hll",
        rebuild_threshold=0.01, max_prop=24, max_casc=24, seed=0,
        schedule=schedule, local_sweeps=local_sweeps, fuse_sweeps=fuse_sweeps)
    body = maker(mesh)

    sim_spec = sim_axes if len(sim_axes) > 1 else sim_axes[0]
    bucket_spec = P(vertex_axis, sim_spec, None)
    in_specs = ((P(sim_spec, None), P(vertex_axis, None))
                + (bucket_spec,) * (10 * mu_v))
    fn = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=(P(), P(), P(), P(), P()), check_vma=False))
    bshape = (mu_v, mu_s, bucket)
    args = [_sds((mu_s, j_loc), jnp.uint32), _sds((mu_v, n_loc), jnp.int32)]
    for dt in (jnp.uint32, jnp.int32, jnp.int32, jnp.uint32, jnp.uint32) * 2:
        for _ in range(mu_v):
            args.append(_sds(bshape, dt))
    return fn.lower(*args), part


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _cell_metrics(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return compiled, {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll.wire_bytes,
        "coll": coll,
    }


def run_cell(name, mesh, mesh_name, *, out_dir=None, tag="", schedule="ring",
             local_sweeps=0, fuse_sweeps=False):
    """Lower + compile one IM cell, recording cost/memory/collective stats."""
    from repro.obs import trace

    t0 = time.time()
    rec = {"arch": name, "shape": "im_step", "mesh": mesh_name, "ok": False}
    try:
        with trace.span("dryrun.cell", phase="plan", arch=name,
                        mesh=mesh_name, schedule=schedule):
            lowered, part = lower_im_cell(name, mesh, schedule=schedule,
                                          local_sweeps=local_sweeps,
                                          fuse_sweeps=fuse_sweeps)
            compiled, m = _cell_metrics(lowered)
        mem = compiled.memory_analysis()
        chips = len(mesh.devices.flatten())
        rec.update(
            ok=True,
            compile_s=round(time.time() - t0, 1),
            flops=m["flops"],
            bytes_accessed=m["bytes_accessed"],
            wire_bytes=m["wire_bytes"],
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            collectives=m["coll"].to_dict(),
            chips=chips,
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{name}__im_step__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="IM cell name (IM_CELLS)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--im", action="store_true",
                    help="deprecated no-op: the IM cells are the only cells "
                         "since the LM seed templates were removed")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--schedule", default="ring", choices=["ring", "allgather"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    from repro.launch.common import add_obs_args, add_tuning_arg, observe

    add_tuning_arg(ap)
    add_obs_args(ap)
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    failures = 0
    names = list(IM_CELLS) if args.arch == "all" else [args.arch]
    with observe(args):
        for mesh_name, mesh in meshes:
            for name in names:
                rec = run_cell(name, mesh, mesh_name, out_dir=args.out,
                               schedule=args.schedule, tag=args.tag,
                               **_tuned_knobs(name, args.tuning))
                status = "OK " if rec["ok"] else "FAIL"
                print(f"[{status}] {name:24s} im_step      {mesh_name:12s} "
                      f"{rec.get('compile_s', '-'):>6}s  {rec.get('error', '')}")
                failures += 0 if rec["ok"] else 1
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
