"""Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh)
cell on the production meshes, plus the DiFuseR IM cells, recording
memory_analysis / cost_analysis / collective wire bytes for the roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --im            # IM cells only
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization. Everything below is ordinary.

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, cell_is_valid, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import (activation_mesh, batch_specs, cache_specs,
                                   param_specs, to_shardings)
from repro.models.transformer import prefill
from repro.serve.engine import make_serve_step
from repro.train.optimizer import make_optimizer, specs_for_state
from repro.train.train_step import TrainConfig, make_train_step
from repro.utils.hlo import collective_stats
from repro.utils.roofline import Roofline, model_flops

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _batch_axis(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def lower_lm_cell(arch: str, shape_name: str, mesh, *, accum: int = 1, cfg=None):
    """Returns the lowered computation for one LM cell."""
    with activation_mesh(mesh):
        return _lower_lm_cell(arch, shape_name, mesh, accum=accum, cfg=cfg)


def _lower_lm_cell(arch: str, shape_name: str, mesh, *, accum: int = 1, cfg=None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    pspecs = param_specs(cfg, mesh)
    pshapes = S.param_shapes(cfg)
    psh = to_shardings(pspecs, mesh)
    b_ax = _batch_axis(mesh)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        oshapes = S.opt_state_shapes(cfg, opt)
        ospecs = specs_for_state(oshapes, pspecs)
        step = make_train_step(cfg, opt, TrainConfig(accum_steps=accum), mesh=mesh)
        bspecs = batch_specs(cfg, mesh, batch=shape.global_batch)
        fn = jax.jit(
            step,
            in_shardings=(psh, to_shardings(ospecs, mesh), to_shardings(bspecs, mesh)),
            out_shardings=(psh, to_shardings(ospecs, mesh), NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        lowered = fn.lower(pshapes, oshapes, S.train_batch_specs(cfg, shape))

    elif shape.kind == "prefill":
        inp = S.prefill_specs(cfg, shape)
        in_shardings = [psh] + [NamedSharding(mesh, P(b_ax, *(None,) * (len(v.shape) - 1)))
                                for v in inp.values()]
        cspecs = cache_specs(cfg, mesh, batch=shape.global_batch)
        logits_sh = NamedSharding(mesh, P(b_ax, None, "model"))
        keys = list(inp.keys())

        def pf(params, *vals):
            kw = dict(zip(keys, vals))
            return prefill(params, kw.pop("tokens"), cfg, **kw)

        fn = jax.jit(pf, in_shardings=tuple(in_shardings),
                     out_shardings=(logits_sh, to_shardings(cspecs, mesh)))
        lowered = fn.lower(pshapes, *inp.values())

    elif shape.kind == "decode":
        inp = S.decode_specs(cfg, shape)
        seq_shard = shape.name == "long_500k"
        cspecs = cache_specs(cfg, mesh, batch=shape.global_batch, seq_shard=seq_shard)
        tok_spec = P(b_ax) if shape.global_batch % _prod(mesh, b_ax) == 0 else P()
        step = make_serve_step(cfg)
        fn = jax.jit(
            step,
            in_shardings=(psh, NamedSharding(mesh, tok_spec),
                          to_shardings(cspecs, mesh), NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, P(tok_spec[0] if tok_spec else None, "model")),
                           to_shardings(cspecs, mesh)),
        )
        lowered = fn.lower(pshapes, inp["token"], inp["cache"], inp["position"])
    else:
        raise ValueError(shape.kind)
    return lowered


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# DiFuseR IM cells (paper workloads at production scale; shapes only)
# ---------------------------------------------------------------------------

IM_CELLS = {
    # name: (n vertices, edges, J registers, duplication factor estimate)
    "difuser-livejournal": (1 << 23, 1 << 27, 2048, 1.6),
    "difuser-twitter": (1 << 26, 1 << 31, 1024, 1.4),
    "difuser-friendster": (1 << 26, 1 << 31, 2048, 1.4),
}


def lower_im_cell(name: str, mesh, *, k: int = 4, schedule: str = "ring"):
    """Lower the full distributed DiFuseR loop with ShapeDtypeStruct inputs
    (no host graph build — bucket sizes come from the duplication model)."""
    from repro.core.distributed import Partition2D, _make_distributed_fn

    n, m, j, dup = IM_CELLS[name]
    axes = mesh.axis_names
    vertex_axis = "data"
    sim_axes = tuple(a for a in axes if a in ("pod", "model"))
    mu_v = mesh.shape[vertex_axis]
    mu_s = _prod(mesh, sim_axes)
    n_pad = n + ((-n) % mu_v)
    n_loc = n_pad // mu_v
    j_loc = j // mu_s
    bucket = int(np.ceil(m * dup / (mu_v * mu_s * mu_v) / 256) * 256)

    dummy = np.zeros((1,), np.int32)
    dummy_steps = (dummy,) * mu_v
    part = Partition2D(
        n=n, n_pad=n_pad, n_loc=n_loc, j_loc=j_loc, mu_v=mu_v, mu_s=mu_s,
        x_shards=dummy, owned_ids=dummy,
        p_h=dummy_steps, p_w=dummy_steps, p_r=dummy_steps, p_t=dummy_steps,
        p_l=dummy_steps,
        c_h=dummy_steps, c_w=dummy_steps, c_r=dummy_steps, c_t=dummy_steps,
        c_l=dummy_steps,
        edge_counts=dummy, p_counts=dummy, c_counts=dummy,
        comm_bytes_per_sweep=(mu_v - 1) * n_loc * j_loc)

    maker = _make_distributed_fn(
        part, k=k, vertex_axis=vertex_axis, sim_axes=sim_axes, estimator="hll",
        rebuild_threshold=0.01, max_prop=24, max_casc=24, seed=0, schedule=schedule)
    body = maker(mesh)

    sim_spec = sim_axes if len(sim_axes) > 1 else sim_axes[0]
    bucket_spec = P(vertex_axis, sim_spec, None)
    in_specs = ((P(sim_spec, None), P(vertex_axis, None))
                + (bucket_spec,) * (10 * mu_v))
    fn = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=(P(), P(), P(), P(), P()), check_vma=False))
    bshape = (mu_v, mu_s, bucket)
    args = [S.sds((mu_s, j_loc), jnp.uint32), S.sds((mu_v, n_loc), jnp.int32)]
    for dt in (jnp.uint32, jnp.int32, jnp.int32, jnp.uint32, jnp.uint32) * 2:
        for _ in range(mu_v):
            args.append(S.sds(bshape, dt))
    return fn.lower(*args), part


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _cell_metrics(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return compiled, {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll.wire_bytes,
        "coll": coll,
    }


def run_cell(arch, shape_name, mesh, mesh_name, *, im=False, out_dir=None,
             probes=True, accum=1, overrides=None, tag="", schedule="ring"):
    """Lower + compile one cell. For LM cells, two tiny unrolled probes
    (1 and 2 layers) correct for XLA HloCostAnalysis counting while-loop
    (scan-over-layers) bodies once:
        corrected = full + (L - 1) * (probe2 - probe1).
    The memory analysis always comes from the full production compile."""
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    try:
        import dataclasses as _dc
        if im:
            lowered, part = lower_im_cell(arch, mesh, schedule=schedule)
            compiled, m = _cell_metrics(lowered)
        else:
            cfg = get_config(arch)
            if overrides:
                cfg = _dc.replace(cfg, **overrides)
            lowered = lower_lm_cell(arch, shape_name, mesh, cfg=cfg, accum=accum)
            compiled, m = _cell_metrics(lowered)
            if probes:
                pcfgs = [
                    _dc.replace(cfg, num_layers=n, enc_layers=min(cfg.enc_layers, n),
                                scan_layers=False) for n in (1, 2)
                ]
                p1 = _cell_metrics(lower_lm_cell(arch, shape_name, mesh, cfg=pcfgs[0], accum=accum))[1]
                p2 = _cell_metrics(lower_lm_cell(arch, shape_name, mesh, cfg=pcfgs[1], accum=accum))[1]
                scale = cfg.num_layers - 1
                for k in ("flops", "bytes_accessed", "wire_bytes"):
                    m[k] = m[k] + scale * max(p2[k] - p1[k], 0.0)
            if accum > 1:
                # the accumulation lax.scan body is also counted once by
                # HloCostAnalysis: scale to the full optimizer step
                for k in ("flops", "bytes_accessed", "wire_bytes"):
                    m[k] = m[k] * accum
        mem = compiled.memory_analysis()
        chips = len(mesh.devices.flatten())
        rec.update(
            ok=True,
            compile_s=round(time.time() - t0, 1),
            flops=m["flops"],
            bytes_accessed=m["bytes_accessed"],
            wire_bytes=m["wire_bytes"],
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            collectives=m["coll"].to_dict(),
            chips=chips,
        )
        if not im:
            shape = SHAPES[shape_name]
            mf = model_flops(cfg, shape)
            roof = Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                            flops_per_device=rec["flops"],
                            bytes_per_device=rec["bytes_accessed"],
                            wire_bytes_per_device=rec["wire_bytes"],
                            model_flops_total=mf)
            rec["roofline"] = roof.to_dict()
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--im", action="store_true", help="run the DiFuseR IM cells")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--accum", type=int, default=1, help="grad-accum microbatches")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (hillclimb), e.g. attn_chunk=1024")
    ap.add_argument("--schedule", default="ring", choices=["ring", "allgather"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = int(v) if v.lstrip("-").isdigit() else v

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    failures = 0
    if args.im:
        names = list(IM_CELLS) if args.arch == "all" else [args.arch]
        for mesh_name, mesh in meshes:
            for name in names:
                rec = run_cell(name, "im_step", mesh, mesh_name, im=True, out_dir=args.out,
                               schedule=args.schedule, tag=args.tag)
                status = "OK " if rec["ok"] else "FAIL"
                print(f"[{status}] {name:24s} im_step      {mesh_name:12s} "
                      f"{rec.get('compile_s', '-'):>6}s  {rec.get('error', '')}")
                failures += 0 if rec["ok"] else 1
        raise SystemExit(1 if failures else 0)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                ok, why = cell_is_valid(get_config(arch), SHAPES[shape_name])
                if not ok:
                    print(f"[SKIP] {arch:20s} {shape_name:12s} {mesh_name:12s} {why}")
                    continue
                rec = run_cell(arch, shape_name, mesh, mesh_name, out_dir=args.out,
                               accum=args.accum, overrides=overrides, tag=args.tag)
                status = "OK " if rec["ok"] else "FAIL"
                extra = ""
                if rec["ok"]:
                    r = rec.get("roofline", {})
                    extra = (f"flops/dev={rec['flops']:.3g} "
                             f"bottleneck={r.get('bottleneck', '-')}")
                print(f"[{status}] {arch:20s} {shape_name:12s} {mesh_name:12s} "
                      f"{rec.get('compile_s', '-'):>6}s  {extra}{rec.get('error', '')}")
                failures += 0 if rec["ok"] else 1
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
