"""Influence serving driver: one sketch build amortized over a query stream.

    PYTHONPATH=src python -m repro serve --graph rmat:12 \
        --registers 512 --queries 1000 --topk 10

Builds the SketchStore index once (the cold cost) through the ``--backend``
of choice (repro.runtime — any registered backend can build the banks),
then pushes a mixed workload of TopKSeeds / SpreadEstimate / MarginalGain /
CoverageProbe requests through the batched InfluenceEngine and reports qps,
p50/p99, and the amortized per-query latency against the cold
``find_seeds`` cost.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.launch.common import add_common_im_args, make_graph, observe
from repro.service import (CoverageProbe, InfluenceEngine, MarginalGain,
                           SketchStore, SpreadEstimate, TopKSeeds,
                           summarize_latencies)


def make_workload(n: int, num_queries: int, *, k: int, seed: int,
                  mix=(0.05, 0.45, 0.35, 0.15)) -> list:
    """A mixed query stream: (topk, spread, marginal, probe) fractions."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(4, size=num_queries, p=np.asarray(mix) / sum(mix))
    out = []
    for kind in kinds:
        if kind == 0:
            out.append(TopKSeeds(k))
        elif kind == 1:
            size = int(rng.integers(1, 9))
            out.append(SpreadEstimate(rng.integers(0, n, size)))
        elif kind == 2:
            size = int(rng.integers(0, 6))
            out.append(MarginalGain(int(rng.integers(0, n)),
                                    rng.integers(0, n, size)))
        else:
            out.append(CoverageProbe(rng.integers(0, n, int(rng.integers(1, 5)))))
    return out


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    add_common_im_args(ap, registers_default=512)
    ap.add_argument("--banks", type=int, default=1)
    ap.add_argument("--attach-plan", action="store_true",
                    help="attach a vertex-shard plan of the --partition "
                         "strategy to the index even for the default "
                         "'block' (a non-block --partition always attaches "
                         "one); the store then serves planned_matrix() row "
                         "blocks and deltas report the plan shards they "
                         "touch")
    ap.add_argument("--plan-shards", type=int, default=8,
                    help="vertex shards of the attached plan (and the row "
                         "blocks of a device-resident placement)")
    ap.add_argument("--residency", default="auto",
                    choices=["auto", "host", "device"],
                    help="where the index banks live for serving: 'device' "
                         "pins plan-order row blocks on a mesh "
                         "(shard-local query reductions); 'auto' follows "
                         "the resolved --backend (mesh -> device)")
    ap.add_argument("--queries", type=int, default=1000)
    ap.add_argument("--topk", type=int, default=10, help="k for TopKSeeds queries")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--async", dest="serve_async", action="store_true",
                    help="serve through AsyncInfluenceEngine: futures + "
                         "deadline-driven micro-batching, builds/repairs "
                         "off the serving path (results bit-identical to "
                         "the synchronous engine)")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-query end-to-end SLO for --async (flush "
                         "window = deadline/4; misses are counted and "
                         "watchdogged)")
    ap.add_argument("--max-resident", type=float, default=0.0,
                    help="device budget in MB for --async multi-graph "
                         "tenancy (0 = unbounded); cost-aware eviction "
                         "keeps resident store bytes under it")
    ap.add_argument("--save", default="", help="persist the index npz here")
    args = ap.parse_args(argv)
    # --trace/--metrics wrap the whole serve run: build + query spans land
    # in the Chrome trace, the registry snapshot is written at exit
    with observe(args):
        return _run(args)


def _run(args) -> dict:
    from repro.runtime import InfluenceSession, RunSpec

    g = make_graph(args.graph, args.setting, args.seed)
    print(f"graph n={g.n:,} m={g.m_real:,} model={args.model}")
    # a sharded spec (mu_v = --plan-shards) only when device serving was
    # asked for — the default stays the historical single-device cold path
    wants_device = args.backend == "mesh" or args.residency == "device"
    spec = RunSpec(num_registers=args.registers, seed=args.seed,
                   model=args.model, backend=args.backend,
                   residency=args.residency,
                   mu_v=args.plan_shards if wants_device else 1, mu_s=1,
                   partition=args.partition if args.partition else "block",
                   tuning=args.tuning)
    sess = InfluenceSession(g, spec,
                            store=SketchStore(num_banks=args.banks, spec=spec))

    # cold reference: what every query would pay without the store
    t0 = time.perf_counter()
    cold = sess.find_seeds(args.topk)
    cold_s = time.perf_counter() - t0
    print(f"cold find_seeds [{sess.last_report.backend}]: {cold_s:.2f}s "
          f"(build fixpoint {cold.propagate_iters} sweeps)")

    store = sess.store
    engine = InfluenceEngine(store, max_batch=args.max_batch)
    key = engine.register(g, spec.difuser_config())
    entry = sess.entry()   # routes spec.residency: mesh serving pins blocks
    print(f"store build: {entry.build_time_s:.2f}s "
          f"({entry.num_banks} bank(s), {entry.build_iters} sweeps)")

    if entry.residency == "device":
        pm = entry.planned_matrix()
        shard_bytes = pm.shape[0] // entry.plan.mu_v * pm.shape[1]
        print(f"device-resident: {entry.plan.mu_v} row blocks x "
              f"{shard_bytes} B on mesh {dict(entry.mesh.shape)} "
              f"(serving {entry.serving_backend})")
    elif args.attach_plan or args.partition != "block":
        from repro.partition import plan_partition

        plan = plan_partition(entry.graph, args.plan_shards, mu_s=1,
                              strategy=args.partition, x=entry.x,
                              seed=args.seed, model=args.model)
        store.attach_plan(key, plan)
        pm = entry.planned_matrix()
        shard_bytes = pm.shape[0] // plan.mu_v * pm.shape[1]
        print(f"plan attached: {plan.predicted.describe()} "
              f"({plan.mu_v} row blocks x {shard_bytes} B resident)")

    workload = make_workload(g.n, args.queries, k=args.topk, seed=args.seed + 7)
    admission = {}
    if getattr(args, "serve_async", False):
        from repro.service import AsyncInfluenceEngine

        import dataclasses as _dc
        spec = _dc.replace(spec, serve_async=True,
                           deadline_ms=args.deadline_ms,
                           max_resident_mb=args.max_resident)
        aeng = AsyncInfluenceEngine(engine, deadline_ms=args.deadline_ms,
                                    max_resident_mb=args.max_resident,
                                    spec=spec)
        t0 = time.perf_counter()
        futures = [aeng.submit(key, q) for q in workload]
        aeng.drain()
        wall_s = time.perf_counter() - t0
        results = [f.result() for f in futures]
        admission = aeng.admission_summary()
        print(f"async: deadline {args.deadline_ms:.0f}ms  "
              f"e2e p99 {admission['e2e_p99_ms']:.2f}ms  "
              f"miss rate {admission['deadline_miss_rate']:.1%}  "
              f"flushes {admission['flushes']}")
        aeng.close()
    else:
        for q in workload:
            engine.submit(key, q)
        t0 = time.perf_counter()
        results = engine.run()
        wall_s = time.perf_counter() - t0
    stats = summarize_latencies(results)

    amortized = wall_s / max(args.queries, 1)
    speedup = cold_s / amortized if amortized > 0 else float("inf")
    print(f"served {args.queries} queries in {wall_s:.2f}s "
          f"({args.queries / wall_s:.0f} qps)")
    print(f"p50 {stats['p50_ms']:.2f}ms  p99 {stats['p99_ms']:.2f}ms  "
          f"topk cache hits {stats['cache_hits']}")
    print(f"amortized {amortized * 1e3:.2f}ms/query vs cold {cold_s:.2f}s "
          f"-> {speedup:.0f}x")

    if args.save:
        store.save(args.save, key)
        print(f"index saved to {args.save}")
    # **stats first: its amortized-based "qps" (memo hits cost 0s) must not
    # clobber the wall-clock qps reported here and printed above
    out = {**stats, "cold_s": cold_s, "build_s": entry.build_time_s,
           "wall_s": wall_s, "qps": args.queries / wall_s,
           "amortized_s": amortized, "speedup": speedup,
           "backend": sess.last_report.backend,
           "residency": entry.residency,
           "serving": entry.serving_backend}
    if admission:
        admission.pop("queue_depth_timeline", None)
        out["admission"] = admission
    return out


if __name__ == "__main__":
    run()
