"""Influence serving driver: one sketch build amortized over a query stream.

    PYTHONPATH=src python -m repro.launch.serve_im --graph rmat:12 \
        --registers 512 --queries 1000 --topk 10

Builds the SketchStore index once (the cold cost), then pushes a mixed
workload of TopKSeeds / SpreadEstimate / MarginalGain / CoverageProbe
requests through the batched InfluenceEngine and reports qps, p50/p99, and
the amortized per-query latency against the cold ``find_seeds`` cost.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.difuser import DiFuserConfig, find_seeds
from repro.launch.im import make_graph
from repro.service import (CoverageProbe, InfluenceEngine, MarginalGain,
                           SketchStore, SpreadEstimate, TopKSeeds,
                           summarize_latencies)


def make_workload(n: int, num_queries: int, *, k: int, seed: int,
                  mix=(0.05, 0.45, 0.35, 0.15)) -> list:
    """A mixed query stream: (topk, spread, marginal, probe) fractions."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(4, size=num_queries, p=np.asarray(mix) / sum(mix))
    out = []
    for kind in kinds:
        if kind == 0:
            out.append(TopKSeeds(k))
        elif kind == 1:
            size = int(rng.integers(1, 9))
            out.append(SpreadEstimate(rng.integers(0, n, size)))
        elif kind == 2:
            size = int(rng.integers(0, 6))
            out.append(MarginalGain(int(rng.integers(0, n)),
                                    rng.integers(0, n, size)))
        else:
            out.append(CoverageProbe(rng.integers(0, n, int(rng.integers(1, 5)))))
    return out


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12",
                    help="rmat:<scale>|er:<n>|ba:<n>|snap:<path>")
    ap.add_argument("--setting", default="0.1")
    ap.add_argument("--model", default="wc",
                    help="diffusion model spec: wc|ic[:p]|lt|dic[:lambda] "
                         "(wc = backward-compatible default; store keys "
                         "include the model id)")
    ap.add_argument("--registers", type=int, default=512)
    ap.add_argument("--banks", type=int, default=1)
    ap.add_argument("--partition", default="",
                    help="attach a vertex-shard plan to the index: "
                         "block|degree|edge|random (empty = none); the store "
                         "then serves planned_matrix() row blocks and deltas "
                         "report the plan shards they touch")
    ap.add_argument("--plan-shards", type=int, default=8,
                    help="vertex shards of the attached plan")
    ap.add_argument("--queries", type=int, default=1000)
    ap.add_argument("--topk", type=int, default=10, help="k for TopKSeeds queries")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--save", default="", help="persist the index npz here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = make_graph(args.graph, args.setting, args.seed)
    print(f"graph n={g.n:,} m={g.m_real:,} model={args.model}")
    cfg = DiFuserConfig(num_registers=args.registers, seed=args.seed,
                        model=args.model)

    # cold reference: what every query would pay without the store
    t0 = time.perf_counter()
    cold = find_seeds(g, args.topk, cfg)
    cold_s = time.perf_counter() - t0
    print(f"cold find_seeds: {cold_s:.2f}s (build fixpoint {cold.propagate_iters} sweeps)")

    store = SketchStore(num_banks=args.banks)
    engine = InfluenceEngine(store, max_batch=args.max_batch)
    key = engine.register(g, cfg)
    entry = store.entry(key)
    print(f"store build: {entry.build_time_s:.2f}s "
          f"({entry.num_banks} bank(s), {entry.build_iters} sweeps)")

    if args.partition:
        from repro.partition import plan_partition

        plan = plan_partition(entry.graph, args.plan_shards, mu_s=1,
                              strategy=args.partition, x=entry.x,
                              seed=args.seed, model=args.model)
        store.attach_plan(key, plan)
        pm = entry.planned_matrix()
        shard_bytes = pm.shape[0] // plan.mu_v * pm.shape[1]
        print(f"plan attached: {plan.predicted.describe()} "
              f"({plan.mu_v} row blocks x {shard_bytes} B resident)")

    for q in make_workload(g.n, args.queries, k=args.topk, seed=args.seed + 7):
        engine.submit(key, q)
    t0 = time.perf_counter()
    results = engine.run()
    wall_s = time.perf_counter() - t0
    stats = summarize_latencies(results)

    amortized = wall_s / max(args.queries, 1)
    speedup = cold_s / amortized if amortized > 0 else float("inf")
    print(f"served {args.queries} queries in {wall_s:.2f}s "
          f"({args.queries / wall_s:.0f} qps)")
    print(f"p50 {stats['p50_ms']:.2f}ms  p99 {stats['p99_ms']:.2f}ms  "
          f"topk cache hits {stats['cache_hits']}")
    print(f"amortized {amortized * 1e3:.2f}ms/query vs cold {cold_s:.2f}s "
          f"-> {speedup:.0f}x")

    if args.save:
        store.save(args.save, key)
        print(f"index saved to {args.save}")
    # **stats first: its amortized-based "qps" (memo hits cost 0s) must not
    # clobber the wall-clock qps reported here and printed above
    return {**stats, "cold_s": cold_s, "build_s": entry.build_time_s,
            "wall_s": wall_s, "qps": args.queries / wall_s,
            "amortized_s": amortized, "speedup": speedup}


if __name__ == "__main__":
    run()
