"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Nothing here allocates: params/optimizer shapes come from jax.eval_shape
over the real init; batches/caches are explicit ShapeDtypeStructs. The
modality frontends are stubs per the assignment — ``input_specs`` supplies
precomputed frame/patch embeddings for [audio]/[vlm] archs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models.config import ModelConfig
from repro.models.transformer import init_cache, init_params
from repro.train.optimizer import Optimizer


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def param_shapes(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def opt_state_shapes(cfg: ModelConfig, optimizer: Optimizer) -> Any:
    return jax.eval_shape(optimizer.init, param_shapes(cfg))


def train_batch_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = sds((b, s, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = sds((b, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        out["enc_embeds"] = sds((b, s, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["prefix_embeds"] = sds((b, cfg.num_patches, cfg.d_model), jnp.float32)
    return out


def decode_cache_shapes(cfg: ModelConfig, shape: ShapeCell) -> Any:
    """Cache ShapeDtypeStructs for a decode cell: one new token against a
    KV/SSM cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: init_cache(cfg, b, s, enc_len=s))


def decode_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    b = shape.global_batch
    return {
        "token": sds((b,), jnp.int32),
        "cache": decode_cache_shapes(cfg, shape),
        "position": sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """The generic entry point: stand-ins for every model input of the cell."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    raise ValueError(shape.kind)
