"""Fault tolerance: supervised relaunch + health checking for the IM
drivers (``python -m repro im`` / ``serve``).

  * **Build/restart** — the SketchStore index is persisted as an npz
    snapshot (``serve_im.py --save`` / ``SketchStore.load``), so a
    relaunched server skips the cold fixpoint; this module supervises the
    process: on a non-zero exit (preempted host, OOM-killed worker, ICI
    link flap surfacing as a crash) it relaunches, bounded by
    --max-restarts.
  * **Elastic scaling** — snapshots are topology-free (canonical row
    order; a device-resident layout re-places on load via
    ``SketchStore.load(mesh=...)``). Changing the mesh between launches
    re-shards: FASST repartitions the sample space for the new device
    count in O(R log R) host time (core/fasst.partition_samples) and the
    partition planner re-plans the row blocks.
  * **Straggler mitigation** — SPMD steps are lockstep, so stragglers are
    structural, not scheduled: FASST minimizes the max device-local edge
    count (the paper's Table 7 *is* a straggler bound), the partition
    planner balances per-shard bucket work, and the heartbeat below
    converts a hung host into a crash+relaunch instead of an indefinite
    stall.

On real clusters the supervisor integrates with the cluster manager
(GKE/SLURM restarts); this reference implementation supervises a local
subprocess so the restart logic itself is testable in CI.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def supervise(cmd: list[str], *, max_restarts: int = 5, heartbeat_file: str | None = None,
              heartbeat_timeout_s: float = 600.0) -> int:
    """Run ``cmd``, relaunching on failure. A stale heartbeat file (not
    touched within the timeout) is treated as a hang: kill + relaunch."""
    restarts = 0
    while True:
        proc = subprocess.Popen(cmd)
        while True:
            try:
                rc = proc.wait(timeout=30)
                break
            except subprocess.TimeoutExpired:
                if heartbeat_file and os.path.exists(heartbeat_file):
                    age = time.time() - os.path.getmtime(heartbeat_file)
                    if age > heartbeat_timeout_s:
                        print(f"[ft] heartbeat stale ({age:.0f}s) — killing straggler",
                              file=sys.stderr)
                        proc.kill()
                        rc = -9
                        break
        if rc == 0:
            return 0
        restarts += 1
        if restarts > max_restarts:
            print(f"[ft] giving up after {max_restarts} restarts", file=sys.stderr)
            return rc
        backoff = min(2.0 ** restarts, 60.0)
        print(f"[ft] exit={rc}; restart {restarts}/{max_restarts} in {backoff:.0f}s",
              file=sys.stderr)
        time.sleep(backoff)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="supervise a long-running launch: ft.py [opts] -- <cmd...>")
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--heartbeat-file", default=None)
    ap.add_argument("--heartbeat-timeout", type=float, default=600.0)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        raise SystemExit("usage: ft.py [opts] -- <command ...>")
    raise SystemExit(supervise(cmd, max_restarts=args.max_restarts,
                               heartbeat_file=args.heartbeat_file,
                               heartbeat_timeout_s=args.heartbeat_timeout))


if __name__ == "__main__":
    main()
