"""Serving driver: batched generation with prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.transformer import init_params
from repro.serve import Engine, ServeConfig
from repro.train.checkpoint import latest_step, restore


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        _, tree = restore(args.ckpt_dir)
        params = tree["params"]
        print("loaded checkpoint params")
    else:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        kw["prefix_embeds"] = rng.standard_normal(
            (args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)

    eng = Engine(cfg, params, ServeConfig(temperature=args.temperature, seed=args.seed))
    t0 = time.time()
    out = eng.generate(prompts, args.gen, **kw)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("sample:", out[0][:12])
    return {"tokens": out, "tok_per_s": tok_s}


if __name__ == "__main__":
    run()
