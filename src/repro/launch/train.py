"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir ckpt/

On the CPU container you run --reduced configs; on a real cluster the same
driver jits against the production mesh. Checkpoint/restart: re-running
with the same --ckpt-dir resumes from the latest step (see launch/ft.py
for the supervised relaunch loop).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.sharding import activation_mesh
from repro.models.transformer import init_params
from repro.train.checkpoint import latest_step, restore, save
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optimizer import make_optimizer
from repro.train.train_step import TrainConfig, make_train_step


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="smoke-sized config")
    ap.add_argument("--width", type=int, default=0, help="override d_model (reduced)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.reduced:
        over = {}
        if args.width:
            over.update(d_model=args.width, head_dim=max(args.width // 4, 8))
        if args.layers:
            over["num_layers"] = args.layers
        cfg = get_reduced(args.arch, **over)
    else:
        cfg = get_config(args.arch)

    opt = make_optimizer(cfg.optimizer, lr=args.lr, warmup=args.warmup)
    step_fn = jax.jit(make_train_step(cfg, opt, TrainConfig(accum_steps=args.accum)),
                      donate_argnums=(0, 1))
    dcfg = DataConfig(batch=args.batch, seq=args.seq, seed=args.seed)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, tree = restore(args.ckpt_dir)
        params, opt_state = tree["params"], tree["opt_state"]
        print(f"resumed from step {start}")
    else:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, dcfg, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {tok_s:,.0f} tok/s")
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, {"params": params, "opt_state": opt_state})
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, {"params": params, "opt_state": opt_state})
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan")}


if __name__ == "__main__":
    run()
