"""Production mesh construction.

Axes: ``data`` (DP+FSDP / IM vertex partition), ``model`` (TP/EP / IM
sample-space partition), ``pod`` (multi-pod data parallelism / IM ensemble).
Functions, not module constants — importing this module never touches jax
device state.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

# jax API drift: AxisType landed after the 0.4.x line (single source of
# truth for the guard: utils/jax_compat.py)
if JAX_HAS_AXIS_TYPE:
    from jax.sharding import AxisType

    _MESH_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
else:  # pragma: no cover - exercised only on old jax
    _MESH_KW = lambda n: {}  # noqa: E731


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = math.prod(shape)
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, found {len(devices)} — "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:ndev],
                         **_MESH_KW(len(axes)))


def make_mesh(shape: tuple, axes: tuple) -> Mesh:
    """Arbitrary mesh for tests/benchmarks (uses the first prod(shape) devices)."""
    ndev = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev],
                         **_MESH_KW(len(axes)))


def make_serving_mesh(mu_v: int, *, vertex_axis: str = "data",
                      sim_axis: str = "model") -> Mesh:
    """``(mu_v, 1)`` mesh for device-resident serving: ``mu_v`` plan-order
    row blocks, one per device, sample space kept whole per device (the
    store's column split is *banks*, not mesh columns — docs/service.md,
    "Sharded serving")."""
    return make_mesh((mu_v, 1), (vertex_axis, sim_axis))


def make_im_mesh(devices: int, *, mu_v: int = 0) -> Mesh:
    """(data, model) mesh for the IM drivers: ``mu_v`` vertex shards x
    ``devices/mu_v`` sample-space shards. ``mu_v=0`` picks the historical
    default (2-way vertex split when the device count is even) — raise it
    when the graph outgrows per-device HBM and the partition planner keeps
    the wider vertex split balanced."""
    if mu_v <= 0:
        mu_v = 2 if devices % 2 == 0 else 1
    if devices % mu_v != 0:
        raise ValueError(f"--devices {devices} not divisible by mu_v={mu_v}")
    return make_mesh((mu_v, devices // mu_v), ("data", "model"))
