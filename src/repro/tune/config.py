"""Kernel tuning search space: one :class:`KernelConfig` per kernel family.

The hot-path kernel families (``fused_sample``, ``sketch_propagate``,
``cascade_step``, ``bucket_propagate``, ``fused_sweep``) historically ran
with one hard-coded
tiling (``kernels.common.EDGE_BLOCK/REG_TILE``, ``edge_chunk=2048`` for the
jnp oracles) and ``local_sweeps=0``, regardless of backend, diffusion model,
or problem size. A :class:`KernelConfig` names the knobs the autotuner may
move; all of them are performance-only — seed sets and sketch matrices are
bit-identical across every config by the kernel contract (Jacobi max-merge
is shape/chunk/schedule-invariant; bucket padding and extra comm-free
sweeps are result-invariant), which tests/test_property.py holds as a
tier-1 property.

Candidate generation is *seeded from measurements* rather than brute-forced:
``schedule_candidates`` reads the planner's :class:`PlanStats` (ring bytes
per sweep, pad waste) and the last published
:class:`~repro.obs.shardprof.MeasuredProfile` (measured per-bucket bytes) to
decide which ``local_sweeps``/``pad_mode`` values are even worth timing —
the PR-7 observability loop closed back into execution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: kernel families the tuner knows how to time and thread
KERNEL_FAMILIES = ("fused_sample", "sketch_propagate", "cascade_step",
                   "bucket_propagate", "fused_sweep")

#: families whose knob is the single-device sweep tiling
SWEEP_FAMILIES = ("fused_sample", "sketch_propagate", "cascade_step")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the per-family search space.

    ``edge_block`` — edges per tile: the ``lax.scan`` chunk for the jnp
    oracle sweeps (``edge_chunk``), the Pallas BlockSpec edge tile for the
    kernel bodies. 0 = library default (2048 / ``kernels.common.EDGE_BLOCK``).
    ``reg_tile`` — registers per lane tile (Pallas impl only; 0 = default).
    ``local_sweeps`` — comm-free block-Jacobi sweeps per ring exchange
    (``bucket_propagate`` family; consumed by the ring fixpoints).
    ``pad_mode`` — bucket padding policy of the 2-D partition
    (``bucket_propagate`` family; "step" | "global").
    ``fuse_sweeps`` — run the ``local_sweeps`` prologue through the fused
    multi-sweep kernel (``fused_sweep`` family): all sweeps inside one
    launch, the register block staying resident between them instead of
    round-tripping through HBM per re-launch.
    ``lane_fill`` — fused-kernel register-lane slab width (``fused_sweep``
    family; 0 = full register width). Per-register-column independence of
    the Jacobi max-merge makes register-axis slabbing result-invariant;
    the knob trades lane occupancy against the per-slab working set.
    """

    edge_block: int = 0
    reg_tile: int = 0
    local_sweeps: int = 0
    pad_mode: str = "step"
    fuse_sweeps: bool = False
    lane_fill: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


#: today's hard-coded defaults, per family — what ``tuning="off"`` runs and
#: what every measured speedup is reported against
DEFAULT_CONFIGS = {
    "fused_sample": KernelConfig(),
    "sketch_propagate": KernelConfig(),
    "cascade_step": KernelConfig(),
    "bucket_propagate": KernelConfig(),
    "fused_sweep": KernelConfig(),
}


def sweep_candidates(num_edges: int, *, impl: str = "ref",
                     default_chunk: int = 2048) -> Tuple[KernelConfig, ...]:
    """Tile candidates for the single-device sweep families.

    ref impl: the knob is the scan chunk — powers of two below and above
    the default plus the full edge count (no scan at all). The small end
    matters most: a chunk's working set is ``chunk x num_registers``
    intermediates, so at high register counts the 2048 default falls out of
    cache and 128/256 measure 1.2-1.3x faster. pallas impl: a small
    (edge_block, reg_tile) grid around the library defaults.
    """
    if impl == "pallas":
        cands = []
        for eb in (256, 512, 1024):
            for rt in (128, 256):
                cands.append(KernelConfig(edge_block=min(eb, num_edges),
                                          reg_tile=rt))
        return tuple(dict.fromkeys(cands))
    chunks = {c for c in (128, 256, 512, 2048, 8192)
              if c <= max(num_edges, 128)}
    chunks.add(default_chunk)
    chunks.add(num_edges)            # full sweep: no scan at all
    return tuple(KernelConfig(edge_block=int(c)) for c in sorted(chunks))


def _comm_fraction(stats=None, profile=None) -> Optional[float]:
    """Measured exchange share of sweep traffic: the planner's (predicted or
    measured) ring bytes per sweep against the per-sweep local bucket bytes
    of the last published :class:`MeasuredProfile`. ``None`` when either
    signal is missing — callers fall back to a conservative probe."""
    if stats is None or not getattr(stats, "ring_bytes_per_sweep", 0):
        return None
    ring = float(stats.ring_bytes_per_sweep)
    local = None
    if profile is not None:
        try:
            import numpy as np

            per_sweep = max(int(getattr(profile, "sweeps", 0)), 1)
            local = float(np.asarray(profile.step_bytes).sum()) / per_sweep
        except Exception:
            local = None
    if local and local > 0:
        return ring / (ring + local)
    return None


def schedule_candidates(stats=None, profile=None, *,
                        pad_mode: str = "step",
                        max_local_sweeps: int = 2) -> Tuple[KernelConfig, ...]:
    """``(local_sweeps, pad_mode)`` candidates for ``bucket_propagate``,
    seeded from measured signals instead of the full grid:

    * ``local_sweeps`` > 0 is only worth timing when exchanges are a
      non-trivial share of sweep traffic. ``stats.ring_bytes_per_sweep``
      (planner-predicted or measured :class:`PlanStats`) against the
      measured per-bucket bytes of the last published
      :class:`MeasuredProfile` gives that comm fraction; without a profile
      the conservative (0, 1) pair is explored.
    * ``pad_mode="global"`` re-pads every bucket to the global max — only a
      candidate when the measured step-mode pad waste is already small
      (< 10%), otherwise global padding strictly inflates it.
    """
    sweeps = [0]
    comm_frac = _comm_fraction(stats, profile)
    if comm_frac is None:
        sweeps.append(1)                      # no measurement: probe one step
    else:
        if comm_frac > 0.05:
            sweeps.append(1)
        if comm_frac > 0.20 and max_local_sweeps >= 2:
            sweeps.append(2)
    pads = [pad_mode]
    waste = getattr(stats, "pad_waste_frac", None) if stats is not None else None
    if pad_mode == "step" and waste is not None and waste < 0.10:
        pads.append("global")
    out = []
    for pm in pads:
        for ls in sweeps:
            out.append(KernelConfig(local_sweeps=int(ls), pad_mode=pm))
    return tuple(dict.fromkeys(out))


def _remixed_lanes(model) -> bool:
    """True when ``model``'s predicate remixes the per-(vertex, sample)
    uniform (``lt``'s extra fmix32 avalanche): the remix decorrelates which
    lanes fire per edge, so lane-fill density is a live knob for it."""
    try:
        from repro.core.difuser import resolve_model
        from repro.core.sampling import remix_interval_predicate

        return resolve_model(model).predicate is remix_interval_predicate
    except Exception:
        return False


def fused_candidates(stats=None, profile=None, *, model: str = "wc",
                     num_regs: int = 0) -> Tuple[KernelConfig, ...]:
    """``(fuse_sweeps, lane_fill)`` candidates for the ``fused_sweep``
    family, seeded like :func:`schedule_candidates` from measured signals:

    * the unfused sweep loop (``fuse_sweeps=False``) is always the
      measurement baseline — callers prepend the family default;
    * lane fills come from the register width: the full-width sweep's
      per-chunk working set is ``edge_chunk x num_regs`` intermediates, so
      high register counts are exactly where narrower slabs (256/512) stay
      cache-resident and pay off;
    * model-aware FASST lane fill: ``lt``'s remixed vertex hash changes
      which lanes are live per edge, so for remixed-predicate models the
      denser 128-lane fill is also worth timing;
    * when the measured comm fraction says exchanges are nearly free
      (< 5%), the ``local_sweeps`` prologue the fusion amortizes rarely
      runs — only the conservative full-width fused candidate is probed.
    """
    fills = [0]
    if num_regs > 512:
        fills += [256, 512]
    elif num_regs > 256:
        fills.append(256)
    if _remixed_lanes(model) and num_regs > 128:
        fills.append(128)
    comm_frac = _comm_fraction(stats, profile)
    if comm_frac is not None and comm_frac < 0.05:
        fills = fills[:1]
    return tuple(KernelConfig(fuse_sweeps=True, lane_fill=int(f))
                 for f in fills)


def spec_overrides(family: str, cfg: KernelConfig, spec) -> dict:
    """Translate a family's winning :class:`KernelConfig` into
    :class:`~repro.runtime.spec.RunSpec` field overrides.

    ref impl: ``edge_block`` is the scan chunk — ``edge_chunk`` for the
    propagate/build sweeps, ``cascade_chunk`` for the cascade sweeps.
    pallas impl: the (edge_block, reg_tile) tile pair is shared by all
    single-device kernels (one pair per traced program), tuned by the
    ``sketch_propagate`` winner. ``bucket_propagate`` owns the ring
    schedule knobs.
    """
    if family == "sketch_propagate":
        if spec.impl == "pallas":
            return {"edge_block": cfg.edge_block or 0,
                    "reg_tile": cfg.reg_tile or 0}
        return {"edge_chunk": cfg.edge_block or spec.edge_chunk}
    if family == "cascade_step":
        if spec.impl == "pallas":
            return {}                  # tiles follow the propagate winner
        return {"cascade_chunk": cfg.edge_block or 0}
    if family == "bucket_propagate":
        return {"local_sweeps": int(cfg.local_sweeps),
                "pad_mode": cfg.pad_mode}
    if family == "fused_sweep":
        return {"fuse_sweeps": bool(cfg.fuse_sweeps),
                "lane_fill": int(cfg.lane_fill)}
    return {}                          # fused_sample: no spec-level knob (ref)


def default_config(family: str) -> KernelConfig:
    """Deterministic fallback on a cache miss: today's hard-coded defaults."""
    return DEFAULT_CONFIGS.get(family, KernelConfig())
