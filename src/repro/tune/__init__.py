"""Measurement-driven kernel autotuning (the PR-7 observability loop closed).

``repro.tune`` turns the repo's hard-coded tile shapes and sweep schedules
into measured decisions: a per-kernel-family :class:`KernelConfig` search
space, an autotuner that times candidates through the ``obs.trace`` timed
spans (device-synced, roofline-annotated), and a persistent
:class:`TuningCache` keyed by kernel × backend × diffusion model ×
size-bucket. The runtime backends consult :func:`resolve_spec` behind the
``RunSpec.tuning`` knob ("off" | "cached" | "auto"); tuning is
performance-only by the kernel contract — seed sets and sketch matrices are
bit-identical across every config (tier-1 property-tested).

See docs/tuning.md for the search space, cache schema, and how measured
shard profiles / planner stats seed the candidates.
"""
from repro.tune.autotuner import (autotune, families_for,
                                  measure_fused_family,
                                  measure_schedule_family,
                                  measure_sweep_family, resolve_spec)
from repro.tune.cache import (CACHE_ENV, DEFAULT_CACHE_PATH, TuningCache,
                              cache_key, default_cache, reset_default_cache,
                              size_bucket)
from repro.tune.config import (DEFAULT_CONFIGS, KERNEL_FAMILIES,
                               SWEEP_FAMILIES, KernelConfig, default_config,
                               fused_candidates, schedule_candidates,
                               spec_overrides, sweep_candidates)

__all__ = [
    "KernelConfig", "KERNEL_FAMILIES", "SWEEP_FAMILIES", "DEFAULT_CONFIGS",
    "sweep_candidates", "schedule_candidates", "fused_candidates",
    "spec_overrides", "default_config",
    "TuningCache", "cache_key", "size_bucket", "default_cache",
    "reset_default_cache", "CACHE_ENV", "DEFAULT_CACHE_PATH",
    "autotune", "resolve_spec", "families_for",
    "measure_sweep_family", "measure_schedule_family", "measure_fused_family",
]
