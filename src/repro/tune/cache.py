"""Persistent tuning cache: measured kernel-config winners keyed by workload.

One JSON file maps ``kernel-family × backend × impl × diffusion-model ×
size-bucket`` to the :class:`~repro.tune.config.KernelConfig` that measured
fastest, together with the measurement record that justified it (default vs
tuned seconds, achieved GB/s, fraction of the bandwidth roof). Sizes are
bucketed to the next power of two so a cache tuned on one RMAT scale serves
its neighbors; a lookup miss falls back deterministically to today's
hard-coded defaults (``tuning="cached"`` on a cold cache is bit- and
schedule-identical to ``tuning="off"``).

The file lives at ``TUNE_cache.json`` in the working directory by default
(override with ``REPRO_TUNE_CACHE``); CI uploads it next to the BENCH_*
artifacts so fast-mode bench runs reuse the measured winners instead of
re-timing.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.tune.config import KernelConfig

#: schema version of the on-disk JSON
CACHE_VERSION = 1

#: default on-disk location (cwd-relative, like the BENCH_* artifacts)
DEFAULT_CACHE_PATH = "TUNE_cache.json"

#: environment override for the cache path ("" disables persistence)
CACHE_ENV = "REPRO_TUNE_CACHE"


def size_bucket(num_edges: int) -> int:
    """Round an edge count up to the next power of two (min 256).

    Buckets keep the key space small and let a cache measured at one graph
    scale serve nearby scales; the kernels themselves clamp any tile to the
    actual operand size, so an over-sized winner degrades gracefully.
    """
    n = max(int(num_edges), 1)
    b = 256
    while b < n:
        b <<= 1
    return b


def cache_key(family: str, *, backend: str, impl: str, model: str,
              num_edges: int) -> str:
    """The canonical lookup key: ``family|backend|impl|model|e<bucket>``."""
    return "|".join((family, backend, impl, model,
                     f"e{size_bucket(num_edges)}"))


class TuningCache:
    """JSON-backed map of cache key → (winning config, measurement record)."""

    def __init__(self, path: Optional[str] = DEFAULT_CACHE_PATH):
        self.path = path or None
        self._entries: Dict[str, dict] = {}
        self._loaded = False

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------

    def load(self) -> "TuningCache":
        """Read the JSON file if present; silently empty on any problem."""
        self._loaded = True
        if not self.path or not os.path.exists(self.path):
            return self
        try:
            with open(self.path, "r", encoding="utf-8") as fh:
                doc = json.load(fh)
            if int(doc.get("version", 0)) == CACHE_VERSION:
                entries = doc.get("entries", {})
                if isinstance(entries, dict):
                    self._entries = {str(k): dict(v)
                                     for k, v in entries.items()}
        except (OSError, ValueError):
            self._entries = {}
        return self

    def save(self) -> None:
        """Write back to ``self.path`` (no-op when persistence is disabled)."""
        if not self.path:
            return
        doc = {"version": CACHE_VERSION, "entries": self._entries}
        tmp = f"{self.path}.tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    def _ensure_loaded(self) -> None:
        if not self._loaded:
            self.load()

    # ------------------------------------------------------------------
    # lookup / record
    # ------------------------------------------------------------------

    def lookup(self, key: str) -> Optional[KernelConfig]:
        """The winning config for ``key``, or None on a miss."""
        self._ensure_loaded()
        entry = self._entries.get(key)
        if entry is None:
            return None
        try:
            return KernelConfig.from_dict(entry.get("config", {}))
        except (TypeError, ValueError):
            return None

    def record(self, key: str) -> Optional[dict]:
        """The full measurement record for ``key`` (config + timings)."""
        self._ensure_loaded()
        entry = self._entries.get(key)
        return dict(entry) if entry is not None else None

    def put(self, key: str, config: KernelConfig, *,
            measurement: Optional[dict] = None) -> None:
        """Store a winner (and its evidence) under ``key``."""
        self._ensure_loaded()
        entry = {"config": config.to_dict()}
        if measurement:
            entry["measurement"] = dict(measurement)
        self._entries[key] = entry

    def records(self) -> Dict[str, dict]:
        """All entries, keyed by cache key (copies; for reporting)."""
        self._ensure_loaded()
        return {k: dict(v) for k, v in self._entries.items()}

    def __len__(self) -> int:
        self._ensure_loaded()
        return len(self._entries)


_default: Optional[TuningCache] = None


def default_cache() -> TuningCache:
    """Process-wide cache at ``$REPRO_TUNE_CACHE`` or ``TUNE_cache.json``.

    Setting ``REPRO_TUNE_CACHE=""`` disables persistence (in-memory only).
    """
    global _default
    path = os.environ.get(CACHE_ENV, DEFAULT_CACHE_PATH)
    if _default is None or _default.path != (path or None):
        _default = TuningCache(path)
    return _default


def reset_default_cache() -> None:
    """Drop the process-wide cache singleton (tests)."""
    global _default
    _default = None
