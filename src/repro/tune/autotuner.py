"""The measuring half of :mod:`repro.tune`: time candidates, pick winners.

Measurement goes through the same instruments the rest of the repo trusts:
each candidate runs inside a ``trace.span(..., timed=True)`` (device output
synced *inside* the span, so queueing is not mistaken for execution) and is
annotated with achieved GB/s and fraction-of-roof via
``utils.roofline.annotate_bandwidth``. Winners are the candidate with the
best min-of-N wall time; every trial also lands in the ``tune.*`` metric
namespace so the perf report can show what the tuner saw.

``resolve_spec`` is the one hook the runtime backends call: with
``spec.tuning="off"`` it returns the spec untouched (zero overhead, exact
historical behaviour); ``"cached"`` applies persisted winners and falls
back deterministically to the spec's own values on a miss; ``"auto"``
measures on a miss against the *actual* graph, persists the winner, then
applies it. All of it is performance-only — the kernels are
chunk/tile/schedule-invariant by contract, so seeds and matrices are
bit-identical across every mode (tier-1 property-tested).

The ring-schedule family (``bucket_propagate``) closes the PR-7 loop:
candidates come from :func:`repro.tune.config.schedule_candidates`, which
reads the planner's :class:`PlanStats` and the last published
:class:`MeasuredProfile` instead of brute-forcing the grid, and the probe
run itself publishes a fresh measured profile.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import metrics, shardprof, trace
from repro.tune.cache import TuningCache, cache_key, default_cache
from repro.tune.config import (KernelConfig, default_config,
                               fused_candidates, schedule_candidates,
                               spec_overrides, sweep_candidates)
from repro.utils import roofline

#: timing repetitions per candidate (min-of-N; first call also warms jit)
TRIALS = 3

#: canonical prologue depth the fused_sweep family is timed at: the
#: candidate grid compares "this many back-to-back sweeps" fused vs looped,
#: matching the local_sweeps values schedule_candidates ever offers (1-2)
FUSED_PROBE_SWEEPS = 2


def _time_grid(fns, labels, *, family: str, nbytes: int,
               trials: int = TRIALS, warmup: int = 1):
    """min-of-N wall seconds per candidate, trials interleaved round-robin.

    Every trial runs inside a ``trace.span(..., timed=True)`` with the
    candidate's output declared via ``sp.sync`` — device work lands inside
    the measurement — and is roofline-annotated with achieved GB/s, so
    tuning trials show up as their own Perfetto lanes next to the workload
    they tuned. Interleaving matters: warm-up drift (allocator, caches,
    CPU frequency) is monotone within a process, so timing candidates
    back-to-back in blocks would systematically favor whichever ran last.
    Round-robin rounds spread the drift evenly; min-per-candidate then
    compares like with like. Returns ``[(seconds, gbps), ...]``.
    """
    for fn in fns:
        for _ in range(max(warmup, 0)):
            fn()
    best = [math.inf] * len(fns)
    for _ in range(max(trials, 1)):
        for i, fn in enumerate(fns):
            with trace.span("tune.trial", phase="other", timed=True,
                            family=family, candidate=labels[i]) as sp:
                sp.sync(fn())
            best[i] = min(best[i], sp.duration_s)
            roofline.annotate_bandwidth(sp, nbytes, sp.duration_s)
    return [(s, (nbytes / s / 1e9) if s > 0 and nbytes > 0 else 0.0)
            for s in best]


def _publish(family: str, backend: str, label: str, seconds: float,
             gbps: float) -> None:
    metrics.counter("tune.trials", family=family, backend=backend).inc()
    metrics.gauge("tune.candidate_us", family=family, backend=backend,
                  candidate=label).set(seconds * 1e6)
    if gbps:
        metrics.gauge("tune.candidate_gbps", family=family, backend=backend,
                      candidate=label).set(round(gbps, 3))


def _measurement_record(family: str, backend: str, results) -> dict:
    """The cache-persisted evidence: per-candidate timings + the default/
    winner comparison the report surfaces. ``results`` is a list of
    ``(config, label, seconds, gbps)`` with the *first* entry the default."""
    default_s = results[0][2]
    best = min(results, key=lambda r: r[2])
    return {
        "family": family, "backend": backend,
        "default_us": round(default_s * 1e6, 3),
        "tuned_us": round(best[2] * 1e6, 3),
        "tuned_gbps": round(best[3], 3),
        "frac_of_roof": round(best[3] * 1e9 / roofline.HBM_BW, 6),
        "speedup": round(default_s / best[2], 4) if best[2] > 0 else 1.0,
        "candidates": [
            {"label": lab, "config": cfg.to_dict(),
             "us": round(s * 1e6, 3), "gbps": round(g, 3)}
            for cfg, lab, s, g in results],
    }


# ---------------------------------------------------------------------------
# Family measurement: single-device sweeps
# ---------------------------------------------------------------------------


def _sweep_operands(g, spec):
    """Device operands + a filled register matrix for the sweep families."""
    import jax.numpy as jnp

    from repro.core import difuser as _difuser
    from repro.kernels import ops

    cfg = spec.difuser_config()
    g2, x = _difuser.normalize_inputs(g, cfg)
    src, dst, h, lo, thr = _difuser.edge_operands(g2, cfg)
    xj = jnp.asarray(np.asarray(x, np.uint32))
    m = ops.sketch_fill(jnp.zeros((g2.n_pad, xj.shape[0]), jnp.int8),
                        seed=cfg.seed)
    pred = _difuser.resolve_model(cfg.model).predicate
    return cfg, (src, dst, h, lo, thr), xj, m, pred


def measure_sweep_family(g, spec, family: str, *,
                         backend: str = "single",
                         candidates=None) -> Tuple[KernelConfig, dict]:
    """Time one sweep of ``family`` per candidate on the actual graph.

    Returns ``(winning config, measurement record)``. The default config is
    always candidate 0, so the record's ``speedup`` is tuned-vs-today.
    """
    import jax

    from repro.kernels import ops

    cfg, (src, dst, h, lo, thr), xj, m, pred = _sweep_operands(g, spec)
    num_edges = int(src.shape[0])
    if candidates is None:
        if family == "fused_sample" and cfg.impl == "ref":
            candidates = ()          # ref fused_sample has no tiling knob
        else:
            candidates = sweep_candidates(num_edges, impl=cfg.impl,
                                          default_chunk=cfg.edge_chunk)
    cands = [default_config(family)] + [c for c in candidates
                                        if c != default_config(family)]
    nbytes = shardprof.bucket_bytes(num_edges, int(xj.shape[0]))
    if family == "cascade_step":
        m = m.at[0].set(-1)          # a visited row so the sweep has work

    def make_fn(c: KernelConfig):
        # jit each candidate closure (chunk/tiles baked in as statics) —
        # the production drivers run these sweeps jitted, so un-jitted
        # timings would rank dispatch overhead, not kernels
        chunk = c.edge_block or cfg.edge_chunk
        kw = dict(seed=cfg.seed, impl=cfg.impl, predicate=pred,
                  edge_chunk=chunk, edge_block=c.edge_block,
                  reg_tile=c.reg_tile)
        if family == "sketch_propagate":
            call = jax.jit(lambda m_, h_, lo_: ops.propagate_sweep(
                m_, src, dst, thr, xj, h=h_, lo=lo_, **kw))
        elif family == "cascade_step":
            call = jax.jit(lambda m_, h_, lo_: ops.cascade_sweep(
                m_, src, dst, thr, xj, h=h_, lo=lo_, **kw))
        elif family == "fused_sample":   # no scan chunk — tiles only
            kw.pop("edge_chunk")
            call = jax.jit(lambda m_, h_, lo_: ops.fused_sample(
                src, dst, thr, xj, h=h_, lo=lo_, **kw))
        else:
            raise ValueError(f"unknown sweep family {family!r}")
        return lambda: jax.block_until_ready(call(m, h, lo))

    labels = [f"eb{c.edge_block or 0}.rt{c.reg_tile or 0}" for c in cands]
    timings = _time_grid([make_fn(c) for c in cands], labels,
                         family=family, nbytes=nbytes)
    results = []
    for c, label, (sec, gbps) in zip(cands, labels, timings):
        _publish(family, backend, label, sec, gbps)
        results.append((c, label, sec, gbps))
    record = _measurement_record(family, backend, results)
    winner = min(results, key=lambda r: r[2])[0]
    metrics.gauge("tune.speedup", family=family,
                  backend=backend).set(record["speedup"])
    return winner, record


# ---------------------------------------------------------------------------
# Family measurement: fused multi-sweep kernel (fused_sweep)
# ---------------------------------------------------------------------------


def measure_fused_family(g, spec, *, backend: str = "serial",
                         candidates=None) -> Tuple[KernelConfig, dict]:
    """Time ``FUSED_PROBE_SWEEPS`` back-to-back propagate sweeps per
    candidate on the actual graph.

    Candidate 0 is today's behaviour — one jitted ``propagate_sweep`` launch
    per sweep, the register matrix materialized between launches (the
    ``sweep_local()`` / mesh-prologue re-launch pattern). Fused candidates
    run the same sweeps through :func:`ops.fused_sweep` in one launch at a
    given lane fill; candidates are seeded model-aware from the register
    width and the last measured profile (:func:`fused_candidates`).
    """
    import jax

    from repro.kernels import ops

    cfg, (src, dst, h, lo, thr), xj, m, pred = _sweep_operands(g, spec)
    num_regs = int(xj.shape[0])
    if candidates is None:
        candidates = fused_candidates(None, shardprof.last_profile(),
                                      model=cfg.model, num_regs=num_regs)
    base = default_config("fused_sweep")         # fuse_sweeps=False: the loop
    cands = [base] + [c for c in candidates if c != base]
    sweeps = FUSED_PROBE_SWEEPS
    nbytes = shardprof.bucket_bytes(int(src.shape[0]), num_regs) * sweeps
    kw = dict(seed=cfg.seed, impl=cfg.impl, predicate=pred,
              edge_chunk=cfg.edge_chunk)

    def make_fn(c: KernelConfig):
        if not c.fuse_sweeps:
            step = jax.jit(lambda m_, h_, lo_: ops.propagate_sweep(
                m_, src, dst, thr, xj, h=h_, lo=lo_, **kw))

            def loop():
                mm = m
                for _ in range(sweeps):
                    mm = step(mm, h, lo)
                return jax.block_until_ready(mm)

            return loop
        call = jax.jit(lambda m_, h_, lo_: ops.fused_sweep(
            m_, src, dst, thr, xj, h=h_, lo=lo_, num_sweeps=sweeps,
            lane_fill=c.lane_fill, **kw))
        return lambda: jax.block_until_ready(call(m, h, lo))

    labels = [f"fused.lf{c.lane_fill or 0}" if c.fuse_sweeps else "loop"
              for c in cands]
    timings = _time_grid([make_fn(c) for c in cands], labels,
                         family="fused_sweep", nbytes=nbytes)
    results = []
    for c, label, (sec, gbps) in zip(cands, labels, timings):
        _publish("fused_sweep", backend, label, sec, gbps)
        results.append((c, label, sec, gbps))
    record = _measurement_record("fused_sweep", backend, results)
    winner = min(results, key=lambda r: r[2])[0]
    metrics.gauge("tune.speedup", family="fused_sweep",
                  backend=backend).set(record["speedup"])
    return winner, record


# ---------------------------------------------------------------------------
# Family measurement: ring schedule (bucket_propagate)
# ---------------------------------------------------------------------------


def measure_schedule_family(g, spec, *, backend: str = "serial",
                            candidates=None) -> Tuple[KernelConfig, dict]:
    """Time the ring build per ``(local_sweeps, pad_mode)`` candidate.

    The probe is the serial-ring executor — the one place ring-step time is
    physically separable (its shard_map device twin runs the identical
    bucket schedule, so the ranking transfers). Candidates are seeded from
    the planner's predicted :class:`PlanStats` and the last published
    measured profile (:func:`schedule_candidates`); the default
    ``(local_sweeps=0, spec.pad_mode)`` is always candidate 0.
    """
    from repro.core.sampling import make_x_vector
    from repro.partition.plan import plan_partition
    from repro.partition.serial import build_matrix_ring_serial

    cfg = spec.difuser_config()
    g2 = g.sorted_by_dst()
    mu_v, mu_s = max(spec.mu_v, 1), max(spec.mu_s, 1)
    x = np.sort(np.asarray(make_x_vector(cfg.num_registers, seed=cfg.seed),
                           dtype=np.uint32))
    plan = plan_partition(g2, mu_v, mu_s=mu_s, strategy=spec.partition,
                          seed=cfg.seed, model=cfg.model)
    if candidates is None:
        candidates = schedule_candidates(plan.predicted,
                                         shardprof.last_profile(),
                                         pad_mode=spec.pad_mode)
    base = KernelConfig(local_sweeps=0, pad_mode=spec.pad_mode)
    cands = [base] + [c for c in candidates if c != base]
    nbytes = shardprof.bucket_bytes(int(g2.m), int(cfg.num_registers))

    def make_fn(c: KernelConfig):
        # pad_mode changes the bucket layout, so each candidate re-buckets;
        # the plan (and therefore results) is shared across candidates
        return lambda: build_matrix_ring_serial(
            g2, cfg, x, mu_v=mu_v, mu_s=mu_s, strategy=spec.partition,
            plan=plan, pad_mode=c.pad_mode, local_sweeps=c.local_sweeps)

    labels = [f"ls{c.local_sweeps}.{c.pad_mode}" for c in cands]
    timings = _time_grid([make_fn(c) for c in cands], labels,
                         family="bucket_propagate", nbytes=nbytes,
                         trials=2, warmup=0)
    results = []
    for c, label, (sec, gbps) in zip(cands, labels, timings):
        _publish("bucket_propagate", backend, label, sec, gbps)
        results.append((c, label, sec, gbps))
    record = _measurement_record("bucket_propagate", backend, results)
    winner = min(results, key=lambda r: r[2])[0]
    metrics.gauge("tune.speedup", family="bucket_propagate",
                  backend=backend).set(record["speedup"])
    return winner, record


# ---------------------------------------------------------------------------
# The runtime hook
# ---------------------------------------------------------------------------


def families_for(spec, backend: str) -> Tuple[str, ...]:
    """Which kernel families a backend's execution actually dispatches."""
    if backend == "single":
        return ("sketch_propagate", "cascade_step")
    if backend in ("serial", "mesh") and spec.num_shards > 1:
        # bucket_propagate picks (local_sweeps, pad_mode); fused_sweep then
        # decides whether those prologue sweeps run fused and at what lane
        # fill (disjoint spec fields, so the override merge is order-free)
        return ("bucket_propagate", "fused_sweep")
    return ()


def _measure_family(family: str, g, spec, backend: str):
    if family in ("sketch_propagate", "cascade_step", "fused_sample"):
        return measure_sweep_family(g, spec, family, backend=backend)
    if family == "bucket_propagate":
        return measure_schedule_family(g, spec, backend=backend)
    if family == "fused_sweep":
        return measure_fused_family(g, spec, backend=backend)
    raise ValueError(f"unknown kernel family {family!r}")


def resolve_spec(g, spec, *, backend: str,
                 cache: Optional[TuningCache] = None):
    """Apply the spec's ``tuning`` mode: return a spec whose tile/schedule
    fields carry the measured winners for this (graph, backend) workload.

    ``"off"`` (default) returns ``spec`` unchanged. ``"cached"`` overlays
    cache winners; a miss deterministically keeps the spec's own values.
    ``"auto"`` measures misses on the actual graph, persists the winners,
    then overlays. Results are invariant either way — only wall time moves.
    """
    mode = getattr(spec, "tuning", "off")
    if mode == "off" or g is None:
        return spec
    if mode not in ("cached", "auto"):
        raise ValueError(f"unknown tuning mode {mode!r} "
                         "(expected 'off' | 'cached' | 'auto')")
    cache = cache if cache is not None else default_cache()
    overrides: Dict[str, object] = {}
    for family in families_for(spec, backend):
        key = cache_key(family, backend=backend, impl=spec.impl,
                        model=spec.model, num_edges=int(g.m))
        cfg = cache.lookup(key)
        if cfg is None:
            metrics.counter("tune.cache_miss", family=family,
                            backend=backend).inc()
            if mode != "auto":
                continue                       # deterministic fallback
            with trace.span("tune.measure", phase="plan", family=family,
                            backend=backend, timed=True):
                cfg, record = _measure_family(family, g, spec, backend)
            cache.put(key, cfg, measurement=record)
            cache.save()
        else:
            metrics.counter("tune.cache_hit", family=family,
                            backend=backend).inc()
        overrides.update(spec_overrides(family, cfg, spec))
    # never let a tuned override change the tuning mode itself
    return spec.with_(**overrides) if overrides else spec


def autotune(g, spec, *, backend: str = "single",
             families: Optional[Tuple[str, ...]] = None,
             cache: Optional[TuningCache] = None) -> Dict[str, dict]:
    """Measure every ``families`` entry now and persist the winners.

    The explicit entry point benchmarks and CI use (``resolve_spec`` with
    ``tuning="auto"`` does the same lazily). Returns family -> measurement
    record (default vs tuned time, GB/s, per-candidate trials).
    """
    cache = cache if cache is not None else default_cache()
    out: Dict[str, dict] = {}
    for family in families or families_for(spec, backend):
        winner, record = _measure_family(family, g, spec, backend)
        key = cache_key(family, backend=backend, impl=spec.impl,
                        model=spec.model, num_edges=int(g.m))
        cache.put(key, winner, measurement=record)
        out[family] = record
    cache.save()
    return out
