from repro.train.data import DataConfig, data_iterator, synthetic_batch
from repro.train.optimizer import make_optimizer
from repro.train.train_step import TrainConfig, make_train_step
