"""Optimizers in pure JAX pytrees: AdamW and Adafactor.

Adafactor (factored second moment, no first moment) exists because the
largest assigned config (grok-1-314b) cannot afford AdamW's 2x fp32 state
at 256 chips x 16 GB; see EXPERIMENTS.md §Dry-run memory table.

States carry the same sharding specs as their parameters (train_step jits
with matching in_shardings), so FSDP shards optimizer state too (ZeRO-3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # state_specs(param_specs) -> state specs pytree
    state_specs: Callable[[Any], Any]


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, warmup: int = 100) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def schedule(count):
        w = jnp.minimum(count / max(warmup, 1), 1.0)
        return lr * w

    def update(grads, state, params, _step=None):
        count = state["count"] + 1
        cur_lr = schedule(count.astype(jnp.float32))
        b1c = 1 - b1 ** count.astype(jnp.float32)
        b2c = 1 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - cur_lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P
        return {"m": param_specs, "v": param_specs, "count": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              warmup: int = 100) -> Optimizer:
    """Factored second-moment (Shazeer & Stern 2018), momentum-free.

    >=2-D leaves factor over the *last two* dims (layer-stacked params keep
    their leading dims unfactored); 0/1-D leaves keep a full accumulator.
    """

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"acc": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _step=None):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        beta = 1.0 - cf ** -decay
        cur_lr = lr * jnp.minimum(cf / max(warmup, 1), 1.0)

        def upd(g, acc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * acc["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * acc["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(vr[..., None] * vc[..., None, :]
                                 / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps))
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                denom = jnp.sqrt(v)
                new_acc = {"v": v}
            step = g / jnp.maximum(denom, eps)
            norm = jnp.sqrt(jnp.mean(step * step))
            step = step / jnp.maximum(1.0, norm / clip_threshold)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - cur_lr * step).astype(p.dtype), new_acc

        is_acc = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, grads, state["acc"], params, is_leaf=is_acc)
        istup = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
        new_acc = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
        return new_params, {"acc": new_acc, "count": count}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def one(spec):
            # factored accumulators follow the parameter spec minus one axis
            return {"vr": P(*spec[:-1]) if len(spec) >= 2 else P(),
                    "vc": P(*(tuple(spec[:-2]) + tuple(spec[-1:]))) if len(spec) >= 2 else P()}

        # NOTE: leaves that are not factored (ndim<2) get {"v": spec}; we
        # cannot see shapes here, so state specs are resolved against real
        # state trees in train_step via tree-matching (see specs_for_state).
        return {"acc": jax.tree.map(one, param_specs, is_leaf=lambda x: isinstance(x, P)),
                "count": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def specs_for_state(state, param_specs):
    """Resolve optimizer-state sharding specs against a concrete state tree
    (handles adafactor's shape-dependent factoring)."""
    from jax.sharding import PartitionSpec as P

    if "m" in state:  # adamw
        return {"m": param_specs, "v": param_specs, "count": P()}

    def one(acc, spec):
        if "vr" in acc:
            return {"vr": P(*spec[:-1]), "vc": P(*(tuple(spec[:-2]) + tuple(spec[-1:])))}
        return {"v": spec}

    is_acc = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    return {"acc": jax.tree.map(one, state["acc"], param_specs, is_leaf=is_acc),
            "count": P()}


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
