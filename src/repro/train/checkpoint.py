"""Checkpointing: atomic, restartable, reshard-on-load.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per pytree leaf (path-encoded
filename) plus ``meta.json`` (step, tree structure, extra metadata). Writes
go to ``step_<N>.tmp`` and are atomically renamed — a killed run never
leaves a half checkpoint (the fault-tolerance contract launch/ft.py relies
on).

Resharding: ``restore`` returns host numpy trees; callers ``device_put``
with whatever shardings the *current* mesh prescribes, so restart on a
different topology (elastic scaling) is just load + re-place. On multi-host
deployments each process would write only its addressable shards
(process_index-suffixed files); single-process here writes full arrays —
the format is forward-compatible (shard files concatenate on axis 0).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict) -> Any:
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def save(ckpt_dir: str, step: int, state: dict, *, keep: int = 3) -> str:
    """state: arbitrary nested dict of arrays (params/opt_state/data state)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    for path, leaf in flat.items():
        np.save(os.path.join(tmp, path + ".npy"), np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "leaves": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None) -> tuple[int, dict]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat = {path: np.load(os.path.join(d, path + ".npy")) for path in meta["leaves"]}
    return meta["step"], _unflatten(flat)


def restore_sharded(ckpt_dir: str, shardings: Any, step: Optional[int] = None) -> tuple[int, dict]:
    """Restore + device_put each leaf with the target sharding (elastic
    re-scaling path: the mesh may differ from the one that saved)."""
    step, host_tree = restore(ckpt_dir, step)
    placed = jax.tree.map(lambda x, s: jax.device_put(x, s), host_tree, shardings)
    return step, placed


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
