"""Training step factory: loss, grad (with remat from the model config),
optional microbatch gradient accumulation, optional bf16 gradient
compression for the cross-pod all-reduce, optimizer apply.

The returned step is a pure function jitted with explicit in/out shardings
derived from models/sharding.py, so the same code path serves the CPU smoke
tests (trivial mesh) and the 512-chip dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy_loss
from repro.models.transformer import forward
from repro.train.optimizer import Optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1            # microbatch gradient accumulation
    grad_dtype: str = "float32"     # "bfloat16" = compressed grad reduce
    max_grad_norm: float = 1.0


def make_loss_fn(cfg: ModelConfig, mesh=None):
    """mesh != None adds an explicit sharding constraint on the logits —
    (batch over data[+pod], vocab over model). Without it XLA's sharding
    propagation can replicate the (B, S, V) fp32 logits, which at train_4k
    scale is a 134 GB/device temp (measured; see EXPERIMENTS.md §Dry-run)."""
    logits_sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.models.sharding import batch_axes
        logits_sharding = NamedSharding(mesh, P(batch_axes(mesh), None, "model"))

    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "encdec":
            kw["enc_embeds"] = batch["enc_embeds"]
        if cfg.family == "vlm":
            kw["prefix_embeds"] = batch["patch_embeds"]
        logits = forward(params, batch["tokens"], cfg, **kw)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # prefix positions carry no next-token target
            pad = jnp.full(batch["patch_embeds"].shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return cross_entropy_loss(logits, labels)

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    tcfg: Optional[TrainConfig] = None, mesh=None):
    tcfg = tcfg or TrainConfig()
    loss_fn = make_loss_fn(cfg, mesh=mesh)
    gdtype = jnp.dtype(tcfg.grad_dtype)

    def compute_grads(params, batch):
        if tcfg.accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, jax.tree.map(lambda g: g.astype(gdtype), grads)

        def micro(batch_slice, _):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch_slice)
            return loss, jax.tree.map(lambda g: g.astype(gdtype), grads)

        def reshape(x):
            return x.reshape((tcfg.accum_steps, x.shape[0] // tcfg.accum_steps) + x.shape[1:])

        micro_batches = jax.tree.map(reshape, batch)

        def body(carry, mb):
            acc_loss, acc_grads = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grads = jax.tree.map(lambda a, g: a + g.astype(gdtype), acc_grads, grads)
            return (acc_loss + loss, grads), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdtype), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), micro_batches)
        inv = 1.0 / tcfg.accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        # global-norm clip (f32 accumulate regardless of grad dtype)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, tcfg.max_grad_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def jit_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh, *,
                   tcfg: Optional[TrainConfig] = None, batch: int, seq: int,
                   opt_state_example: Any = None):
    """AOT-friendly jitted step with explicit shardings (used by launch/)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import batch_specs, param_specs, to_shardings
    from repro.train.optimizer import specs_for_state

    pspecs = param_specs(cfg, mesh)
    bspecs = batch_specs(cfg, mesh, batch=batch)
    if opt_state_example is None:
        shapes = jax.eval_shape(lambda k: __import__("repro.models.transformer",
                                                     fromlist=["init_params"]).init_params(cfg, k),
                                jax.random.PRNGKey(0))
        opt_state_example = jax.eval_shape(optimizer.init, shapes)
    ospecs = specs_for_state(opt_state_example, pspecs)

    step = make_train_step(cfg, optimizer, tcfg)
    return jax.jit(
        step,
        in_shardings=(to_shardings(pspecs, mesh), to_shardings(ospecs, mesh),
                      to_shardings(bspecs, mesh)),
        out_shardings=(to_shardings(pspecs, mesh), to_shardings(ospecs, mesh),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    ), pspecs, ospecs, bspecs
