"""Synthetic token pipeline: deterministic, restartable, host-sharded.

Real deployments plug a file-backed loader behind the same iterator
protocol; what matters for the framework is that (a) batches are a pure
function of (seed, step) so checkpoint restart resumes the stream exactly,
and (b) each host generates only its addressable slice (data-parallel
sharding happens at the source, not via scatter).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 128
    seed: int = 0
    zipf_a: float = 1.2           # skewed unigram distribution (more LM-like
                                  # than uniform; loss actually decreases)


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
                    *, host_id: int = 0, num_hosts: int = 1) -> dict:
    """Batch for ``step`` — pure function of (seed, step, host)."""
    rng = np.random.default_rng((dcfg.seed, step, host_id))
    b = dcfg.batch // num_hosts
    # zipf over the *logical* vocab, with a deterministic shift pattern so
    # the next-token structure is learnable (x[t+1] = (x[t]*3+7) % V on 50%)
    v = cfg.vocab_size
    base = rng.integers(0, v, size=(b, dcfg.seq + 1))
    zipf = np.minimum(rng.zipf(dcfg.zipf_a, size=(b, dcfg.seq + 1)) - 1, v - 1)
    toks = np.where(rng.random((b, dcfg.seq + 1)) < 0.5, zipf, base)
    follow = (toks[:, :-1] * 3 + 7) % v
    mask = rng.random((b, dcfg.seq)) < 0.5
    toks[:, 1:] = np.where(mask, follow, toks[:, 1:])
    batch = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = rng.standard_normal((b, dcfg.seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model)).astype(np.float32)
    return batch


def data_iterator(cfg: ModelConfig, dcfg: DataConfig, *, start_step: int = 0,
                  host_id: int = 0, num_hosts: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, dcfg, step, host_id=host_id, num_hosts=num_hosts)
        step += 1
