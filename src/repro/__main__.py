"""``python -m repro`` — the one front door to the IM drivers.

    PYTHONPATH=src python -m repro im --graph rmat:12 --k 10
    PYTHONPATH=src python -m repro serve --graph rmat:12 --queries 500
    PYTHONPATH=src python -m repro dryrun --im

Each subcommand forwards its remaining argv to the underlying launcher
(``repro.launch.im`` / ``repro.launch.serve_im`` / ``repro.launch.dryrun``),
which stay runnable directly for backward compatibility.
"""
from __future__ import annotations

import sys

_SUBCOMMANDS = {
    "im": "run DiFuseR end-to-end (seed selection + optional MC validation)",
    "serve": "build a sketch index once, serve a mixed query stream",
    "dryrun": "lower/compile production-mesh cells (no execution)",
}


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        lines = "\n".join(f"  {name:8s} {desc}"
                          for name, desc in _SUBCOMMANDS.items())
        print("usage: python -m repro <command> [args...]\n\n"
              f"commands:\n{lines}\n\n"
              "run `python -m repro <command> --help` for per-command flags")
        raise SystemExit(0 if argv else 2)
    cmd, rest = argv[0], argv[1:]
    if cmd == "im":
        from repro.launch.im import run

        run(rest)
    elif cmd == "serve":
        from repro.launch.serve_im import run

        run(rest)
    elif cmd == "dryrun":
        # dryrun owns sys.argv parsing (it must set XLA_FLAGS before jax
        # imports, so it cannot take argv as a parameter)
        sys.argv = [sys.argv[0]] + rest
        from repro.launch.dryrun import main as dryrun_main

        dryrun_main()
    else:
        raise SystemExit(f"unknown command {cmd!r}; options: "
                         f"{', '.join(_SUBCOMMANDS)}")


if __name__ == "__main__":
    main()
