"""Diffusion model zoo: pluggable hash-fused samplers.

The paper's pipeline (§2.2, Alg. 4) hardcodes one diffusion setting — edges
sampled by the fused ``(X ^ h(u,v)) < w * 2^32`` compare. The IM literature
it builds on (Göktürk & Kaya, arXiv:2105.04023 / arXiv:2008.03095) evaluates
across independent-cascade, weighted-cascade, and Linear Threshold models.
This registry makes the model a first-class, pluggable choice while keeping
the paper's core property: sampling stays one hash + one compare per
(edge, sample), with no stored samples and no RNG state.

Every model is two pure pieces:

  * **host preprocessing** (``edge_params``): numpy, runs once per graph —
    folds the model's probability structure into three per-edge uint32
    arrays ``(h, lo, width)``;
  * **fused predicate** (``predicate``): the device-side decision
    ``((X_r ^ h_e) - lo_e) < width_e`` (sampling.fused_predicate), shared by
    the jnp oracles, the Pallas kernels, and the distributed bucket sweeps.

``h`` is sample-independent for every model (it never depends on X_r), so
the distributed runtime's precomputed bucket hashes stay legal regardless of
the model — the partition builder just calls ``edge_params`` instead of
hashing inline.

Registered models:

  * ``ic``  — independent cascade with one uniform probability p on every
              edge (spec ``ic`` or ``ic:<p>``, default p = 0.1).
  * ``wc``  — weighted cascade: per-edge probabilities taken from the
              graph's weight array (the repo's historical behaviour; the
              canonical WC instance sets w_uv = 1/indeg(v) via
              graphs.generators.make_wc_weights). Default model everywhere.
  * ``lt``  — Linear Threshold via hash-based live-edge sampling: each
              vertex v partitions [0, 2^32) into cumulative in-weight
              intervals (b_uv = w_uv / max(1, sum_in w)), a per-(v, sample)
              uniform ``X_r ^ vertex_hash(v)`` lands in at most one
              interval, so v activates at most one in-edge per sample
              (Kempe et al.'s live-edge equivalence).
  * ``dic`` — decaying IC: each edge carries a deterministic transmission
              latency d_uv in [0, 1) (hash-derived) and its probability
              decays exponentially, w_eff = w_uv * exp(-lambda * d_uv)
              (spec ``dic`` or ``dic:<lambda>``, default lambda = 1.0).

The Monte-Carlo referee (baselines.mc_oracle) consumes the same model
objects through ``mc_sampler`` (one-shot convenience:
``baselines.sample_live_mask``) but draws its randomness from numpy PRNGs —
independent of the XOR-hash scheme, as the paper's §5.1 oracle demands.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.sampling import (edge_hash, fused_predicate,
                                 remix_interval_predicate, vertex_hash,
                                 weight_to_threshold)
from repro.diffusion.constants import DEFAULT_MODEL  # noqa: F401 (re-export)
from repro.graphs.structs import Graph

# salt for the dic latency hash — distinct from the sampling hash so the
# latency attribute and the sampling decision are independent
_DELAY_SALT = 0x5D1C0FFE

_TWO32 = 4294967296.0
_U32_MAX = np.uint64(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class EdgeParams:
    """Device-ready per-edge operands of the fused predicate (numpy, aligned
    with the graph's current edge order, padding edges inert by width = 0)."""

    h: np.ndarray       # uint32[m] sample-independent edge hash
    lo: np.ndarray      # uint32[m] interval low endpoint (0 for threshold models)
    thr: np.ndarray     # uint32[m] interval width / sampling threshold


def _real_edge_mask(g: Graph) -> np.ndarray:
    mask = np.zeros(g.m, dtype=bool)
    mask[: g.m_real] = True
    return mask


class DiffusionModel:
    """Base class: a stateless hash-fused edge-activation predicate plus its
    host-side preprocessing. Subclasses override ``edge_params`` and either
    ``live_edge_probability`` (threshold-style models) or ``mc_sampler``
    (anything with correlated edge draws, e.g. LT)."""

    name: str = ""
    spec: str = ""

    # the device-side hook every kernel calls; staticmethod so all models
    # sharing the interval form also share one jit cache entry
    predicate = staticmethod(fused_predicate)

    # whether the per-edge activation law depends only on the edge itself
    # (not the rest of the graph). True for ic / wc / dic; False for lt,
    # where every in-edge's interval is re-normalized by its siblings.
    # This is the soundness condition for BOTH service/delta.py fast paths:
    # insertions can only grow live-edge sets (monotone repair is sound) and
    # removal staleness keeps the matrix a sound over-approximation. A
    # context-sensitive model must rebuild on any delta.
    context_free_edges: bool = True

    # -- host preprocessing -------------------------------------------------

    def edge_params(self, g: Graph, *, seed: int = 0) -> EdgeParams:
        raise NotImplementedError

    # -- Monte-Carlo referee hooks -----------------------------------------

    def live_edge_probability(self, g: Graph) -> np.ndarray:
        """float64[m] independent per-edge live probability (threshold
        models). Models with correlated draws override ``mc_sampler``."""
        raise NotImplementedError

    def mc_sampler(self, g: Graph) -> Callable[[np.random.Generator], np.ndarray]:
        """One-time host preprocessing for Monte-Carlo simulation: returns a
        closure drawing bool[m] live-edge samples in the graph's edge order,
        so per-sim cost is just the RNG draw + compare (the oracle runs
        hundreds of sims against one graph)."""
        p = self.live_edge_probability(g)
        return lambda rng: rng.random(g.m) < p

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}({self.spec!r})"


class WeightedCascade(DiffusionModel):
    """``wc`` — the repo's historical setting: thresholds straight from the
    graph's weight array (degree-normalized when the graph was built with
    the ``wc`` weight setting). ``lo = 0`` makes the interval predicate
    collapse to the legacy ``(X ^ h) < thr`` compare bit-for-bit."""

    name = "wc"

    def __init__(self, spec: str = "wc"):
        self.spec = spec

    def edge_params(self, g: Graph, *, seed: int = 0) -> EdgeParams:
        h = edge_hash(g.src, g.dst, seed=seed)
        return EdgeParams(h=h, lo=np.zeros(g.m, dtype=np.uint32),
                          thr=weight_to_threshold(g.weight))

    def live_edge_probability(self, g: Graph) -> np.ndarray:
        p = np.asarray(g.weight, dtype=np.float64).copy()
        p[g.m_real:] = 0.0
        return p


class UniformIC(DiffusionModel):
    """``ic[:p]`` — independent cascade with one uniform probability on every
    real edge, ignoring the graph's per-edge weights."""

    name = "ic"

    def __init__(self, spec: str = "ic", p: float = 0.1):
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"ic probability must be in [0, 1], got {p}")
        self.spec = spec
        self.p = float(p)

    def edge_params(self, g: Graph, *, seed: int = 0) -> EdgeParams:
        h = edge_hash(g.src, g.dst, seed=seed)
        w = np.where(_real_edge_mask(g), np.float32(self.p), np.float32(0.0))
        return EdgeParams(h=h, lo=np.zeros(g.m, dtype=np.uint32),
                          thr=weight_to_threshold(w))

    def live_edge_probability(self, g: Graph) -> np.ndarray:
        return np.where(_real_edge_mask(g), self.p, 0.0)


class DecayingIC(DiffusionModel):
    """``dic[:lambda]`` — IC with per-edge exponential time-decay: every edge
    carries a deterministic transmission latency d_uv in [0, 1) derived from
    a salted edge hash (an edge *attribute*, not sampling randomness), and
    its activation probability decays as w_eff = w_uv * exp(-lambda * d_uv).
    Host preprocessing folds the decay into the threshold, so the device
    predicate is the plain threshold compare."""

    name = "dic"

    def __init__(self, spec: str = "dic", decay: float = 1.0):
        if decay < 0.0:
            raise ValueError(f"dic decay must be >= 0, got {decay}")
        self.spec = spec
        self.decay = float(decay)

    def edge_delay(self, g: Graph) -> np.ndarray:
        """float64[m] deterministic per-edge latency in [0, 1)."""
        h = edge_hash(g.src, g.dst, seed=_DELAY_SALT)
        return h.astype(np.float64) / _TWO32

    def live_edge_probability(self, g: Graph) -> np.ndarray:
        w = np.asarray(g.weight, dtype=np.float64).copy()
        w[g.m_real:] = 0.0
        return w * np.exp(-self.decay * self.edge_delay(g))

    def edge_params(self, g: Graph, *, seed: int = 0) -> EdgeParams:
        h = edge_hash(g.src, g.dst, seed=seed)
        w_eff = self.live_edge_probability(g).astype(np.float32)
        return EdgeParams(h=h, lo=np.zeros(g.m, dtype=np.uint32),
                          thr=weight_to_threshold(w_eff))


class LinearThreshold(DiffusionModel):
    """``lt`` — Linear Threshold by hash-based live-edge sampling.

    Kempe et al.: LT is distribution-equal to reachability over live-edge
    graphs where each vertex v independently selects at most one in-edge,
    edge (u, v) with probability b_uv (sum_u b_uv <= 1). We take
    b_uv = w_uv / max(1, sum_in w(v)) and realize the selection without
    storing samples: v's in-edges partition [0, 2^32) into consecutive
    intervals of width b_uv * 2^32 (cumulative in-weight order), and the
    per-(v, sample) uniform ``mix32(X_r ^ vertex_hash(v))`` is shared by all
    in-edges of v — it lands in at most one interval, so at most one in-edge
    fires. Still one hash + one compare per (edge, sample); the extra
    avalanche decorrelates different vertices' selections within a sample
    (see sampling.remix_interval_predicate)."""

    name = "lt"
    predicate = staticmethod(remix_interval_predicate)
    # any in-edge add/remove re-normalizes its dst's whole interval
    # partition, so old live-edge sets are neither subsets nor supersets of
    # new ones — every delta must rebuild
    context_free_edges = False

    def __init__(self, spec: str = "lt"):
        self.spec = spec

    def _interval_fractions(self, g: Graph) -> Tuple[np.ndarray, np.ndarray]:
        """Per-edge [lo, hi) fractions of the dst vertex's unit interval
        (float64, exact cumulative partition; padding edges get [x, x))."""
        w = np.clip(np.asarray(g.weight, dtype=np.float64), 0.0, 1.0)
        w[g.m_real:] = 0.0
        dst = g.dst.astype(np.int64)
        total_in = np.zeros(g.n_pad, dtype=np.float64)
        np.add.at(total_in, dst, w)
        b = w / np.maximum(total_in, 1.0)[dst]
        # grouped cumulative sum: stable sort by dst keeps the graph's edge
        # order within each in-edge run, cumsum, subtract each run's base
        order = np.argsort(dst, kind="stable")
        b_s = b[order]
        cum_hi = np.cumsum(b_s)
        cum_lo = cum_hi - b_s
        dst_s = dst[order]
        run_start = np.concatenate([[True], dst_s[1:] != dst_s[:-1]])
        base = np.maximum.accumulate(np.where(run_start, cum_lo, -np.inf))
        lo_s = cum_lo - base
        hi_s = cum_hi - base
        lo = np.empty_like(lo_s)
        hi = np.empty_like(hi_s)
        lo[order] = lo_s
        hi[order] = hi_s
        return lo, hi

    def edge_params(self, g: Graph, *, seed: int = 0) -> EdgeParams:
        lo_f, hi_f = self._interval_fractions(g)
        # round the cumulative *endpoints* so intervals stay disjoint and
        # exactly tile the rounded partition
        lo_u64 = np.minimum(np.round(lo_f * _TWO32), np.float64(_TWO32)).astype(np.uint64)
        hi_u64 = np.minimum(np.round(hi_f * _TWO32), np.float64(_TWO32)).astype(np.uint64)
        width = hi_u64 - lo_u64
        # a full-interval edge (b == 1) would need width 2^32; clamp to
        # 2^32 - 1 (miss probability 2^-32 per sample)
        width = np.minimum(width, _U32_MAX)
        lo = np.minimum(lo_u64, _U32_MAX).astype(np.uint32)
        return EdgeParams(h=vertex_hash(g.dst, seed=seed), lo=lo,
                          thr=width.astype(np.uint32))

    def mc_sampler(self, g: Graph) -> "Callable[[np.random.Generator], np.ndarray]":
        lo_f, hi_f = self._interval_fractions(g)
        dst = g.dst.astype(np.int64)

        def sample(rng: np.random.Generator) -> np.ndarray:
            t = rng.random(g.n_pad)[dst]
            return (lo_f <= t) & (t < hi_f)

        return sample


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# name -> factory(spec, param_str_or_None) — the extension point future
# scenario PRs plug new models into
_REGISTRY: Dict[str, Callable[[str, str], DiffusionModel]] = {}
_RESOLVED: Dict[str, DiffusionModel] = {}


def register_model(name: str, factory: Callable[[str, str], DiffusionModel]) -> None:
    """Register a model family under ``name``. ``factory(spec, param)``
    receives the full spec string and the optional ``:<param>`` suffix
    (None when absent) and returns a model instance."""
    if name in _REGISTRY:
        raise ValueError(f"diffusion model {name!r} already registered")
    _REGISTRY[name] = factory
    _RESOLVED.clear()


def available_models() -> Tuple[str, ...]:
    """Registered model family names (registration order)."""
    return tuple(_REGISTRY)


def resolve(spec: str) -> DiffusionModel:
    """Resolve a model spec string (``name`` or ``name:param``) to its
    instance. Instances are stateless and cached per spec."""
    if not isinstance(spec, str) or not spec:
        raise TypeError(f"diffusion model spec must be a non-empty str, got {spec!r}")
    hit = _RESOLVED.get(spec)
    if hit is not None:
        return hit
    name, sep, param = spec.partition(":")
    factory = _REGISTRY.get(name)
    if factory is None:
        raise KeyError(
            f"unknown diffusion model {name!r}; registered: {sorted(_REGISTRY)}")
    model = factory(spec, param if sep else None)
    _RESOLVED[spec] = model
    return model


def _float_param(param, default: float, what: str) -> float:
    if param is None:
        return default
    try:
        return float(param)
    except ValueError as e:
        raise ValueError(f"bad {what} parameter {param!r}") from e


def _no_param(param, name: str) -> None:
    # reject silently-ignored suffixes: "wc:0.5" would otherwise fork a
    # second store key with byte-identical sampling
    if param is not None:
        raise ValueError(f"diffusion model {name!r} takes no parameter, "
                         f"got {param!r}")


def _make_wc(spec, param):
    _no_param(param, "wc")
    return WeightedCascade(spec)


def _make_lt(spec, param):
    _no_param(param, "lt")
    return LinearThreshold(spec)


register_model("wc", _make_wc)
register_model("ic", lambda spec, param: UniformIC(
    spec, _float_param(param, 0.1, "ic probability")))
register_model("lt", _make_lt)
register_model("dic", lambda spec, param: DecayingIC(
    spec, _float_param(param, 1.0, "dic decay")))
