"""Diffusion model zoo — pluggable hash-fused samplers (ic / wc / lt / dic).

``resolve("wc")`` etc. returns a stateless model object exposing the fused
device predicate and the host-side preprocessing that lowers the model to
per-edge ``(h, lo, width)`` uint32 operands. See diffusion/models.py and
docs/diffusion.md.
"""
from repro.diffusion.models import (DEFAULT_MODEL, DiffusionModel, EdgeParams,
                                    available_models, register_model, resolve)

__all__ = [
    "DEFAULT_MODEL",
    "DiffusionModel",
    "EdgeParams",
    "available_models",
    "register_model",
    "resolve",
]
