"""Leaf constants for the diffusion model zoo (no imports, so modules on
either side of the repro.core <-> repro.diffusion package-init cycle —
core/difuser.py and diffusion/models.py — can share one source of truth)."""

# the backward-compatible default model everywhere: the repo's historical
# weighted-cascade sampling
DEFAULT_MODEL = "wc"
