"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE:
28L d_model=2048 16H (GQA kv=16) vocab=102400, 2 shared + 64 routed top-6
experts with per-expert d_ff=1408 (the paper-reported fine-grained layout).
EP: 64 experts shard 4-per-device over the 16-way model axis."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="decoder",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=102400,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_shard_mode="expert",
    sub_quadratic=False,
)
