"""mamba2-780m [arXiv:2405.21060; unverified] — attention-free SSD:
48L d_model=1536 d_ff=0 (no MLP block) vocab=50280 ssm_state=128.
Constant-size state cache => runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    sub_quadratic=True,
)
