"""qwen1.5-4b [hf:Qwen/Qwen1.5-0.5B; hf] — 40L d_model=2560 20H (GQA kv=20)
d_ff=6912 vocab=151936, QKV bias. 20 heads (MHA: kv=20 too)
don't divide the model axis: attention projections replicate over ``model``
(FSDP over ``data`` still shards them); head_dim sharding is banned because
it all-reduces the S x S scores (see yi-34b / EXPERIMENTS §Perf). Padding
an MHA model would need paired q+kv padding — left as future work."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="decoder",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    sub_quadratic=False,
)
