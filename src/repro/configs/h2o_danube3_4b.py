"""h2o-danube-3-4b [arXiv:2401.16818; unverified] — llama+mistral mix with
sliding-window attention (window 4096): 24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000. SWA makes it sub-quadratic => runs long_500k
(decode attends to a 4k window of the 512k cache)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="decoder",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    sub_quadratic=True,
)
