"""DiFuseR's own workload configs (the paper's §5 experiments), exposed the
same way the LM archs are: selectable presets for launch/im.py and the
production-scale dry-run cells in launch/dryrun.py (IM_CELLS).

The container-scale presets mirror the paper's graph/degree regimes at
sizes the CPU oracle can referee; the dry-run cells carry the full
SNAP-scale shapes (n up to 2^26, m up to 2^31) through lower()+compile().

``model`` selects a diffusion model from the repro.diffusion registry
(wc | ic[:p] | lt | dic[:lambda]); the ``zoo-*`` presets cover one workload
per registered model for the model-zoo benchmark (benchmarks/model_zoo.py).

``partition`` selects the vertex-assignment strategy of the 2-D distributed
partition (repro.partition registry: block | degree | edge | random); the
``balance-*`` presets pin the skewed-RMAT regime the planner benchmark
(benchmarks/partition_balance.py) measures.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class IMWorkload:
    name: str
    graph: str          # launch/im.py --graph spec
    setting: str        # paper influence setting (edge-weight generator)
    k: int = 50
    registers: int = 1024
    model: str = "wc"   # diffusion model spec (repro.diffusion registry)
    partition: str = "block"  # vertex-assignment strategy (repro.partition)


PRESETS = {
    # paper Table 3/4 regimes, container-scale
    "livejournal-like": IMWorkload("livejournal-like", "rmat:13", "0.1"),
    "orkut-like": IMWorkload("orkut-like", "ba:4096", "0.01"),
    "youtube-like": IMWorkload("youtube-like", "er:8192", "0.005"),
    "mixed-n005": IMWorkload("mixed-n005", "rmat:12", "N0.05"),
    "mixed-u01": IMWorkload("mixed-u01", "rmat:12", "U0.1"),
    # diffusion model zoo: one workload per registered model, shared topology
    "zoo-ic": IMWorkload("zoo-ic", "rmat:11", "0.1", k=16, registers=512,
                         model="ic:0.1"),
    "zoo-wc": IMWorkload("zoo-wc", "rmat:11", "0.1", k=16, registers=512,
                         model="wc"),
    "zoo-lt": IMWorkload("zoo-lt", "rmat:11", "0.1", k=16, registers=512,
                         model="lt"),
    "zoo-dic": IMWorkload("zoo-dic", "rmat:11", "0.1", k=16, registers=512,
                          model="dic:1.0"),
    # load-balanced 2-D partition: skewed Kronecker ids, hub-clustered — the
    # regime where block assignment straggles and the planners pay off
    "balance-degree": IMWorkload("balance-degree", "rmat-skew:11", "0.1",
                                 k=16, registers=512, partition="degree"),
    "balance-edge": IMWorkload("balance-edge", "rmat-skew:11", "0.1",
                               k=16, registers=512, partition="edge"),
}
