"""internvl2-26b [arXiv:2404.16821; hf] — VLM: InternViT frontend (STUB:
precomputed patch embeddings) + InternLM2 backbone 48L d_model=6144 48H
(GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672 for sharding)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_patches",
    num_patches=256,
    sub_quadratic=False,
)
