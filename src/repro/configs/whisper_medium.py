"""whisper-medium [arXiv:2212.04356; unverified] — enc-dec, 24L(+24 enc)
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. The conv audio frontend is
a STUB per the assignment: input_specs() feeds precomputed frame
embeddings (batch, frames, d_model). Decode = self-KV + cross-KV cache.
Vocab pads 51865 -> 51968 so embeddings shard 16-way. NOTE: the
framework uses SwiGLU MLPs uniformly, so the as-built param count is
~1.0B vs the original GELU model's 769M (documented in DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    enc_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    frontend="audio_frames",
    sub_quadratic=False,
)
