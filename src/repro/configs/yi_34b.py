"""yi-34b [arXiv:2403.04652; hf] — dense llama-arch GQA:
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
56 heads don't divide the 16-way model axis -> q heads are zero-padded to
64 per KV group (exact math, +14% attention FLOPs) so they shard 16-way;
head_dim sharding was measured to all-reduce 60 GB of scores per layer
(EXPERIMENTS.md §Perf yi-34b iterations)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="decoder",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    padded_q_heads=64,
    sub_quadratic=False,
)
