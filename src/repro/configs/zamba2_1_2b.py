"""zamba2-1.2b [arXiv:2411.15242; hf] — hybrid: Mamba2 backbone with ONE
weight-shared attention block applied every 6 layers: 38L d_model=2048
32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64. SSM state is O(1) in
sequence => runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    sub_quadratic=True,
)
