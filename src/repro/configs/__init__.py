"""Config registry: the DiFuseR influence-maximization workloads.

``repro.configs.difuser_workloads`` carries the selectable presets for
``launch/im.py`` / ``launch/serve_im.py`` and the production-scale dry-run
cells (``launch/dryrun.py``, ``IM_CELLS``). The LM seed-template arch
configs that used to live here were quarantined in PR 4 and deleted in
PR 5 — the IM pipeline never imported them.
"""
from repro.configs.difuser_workloads import PRESETS, IMWorkload

__all__ = ["PRESETS", "IMWorkload"]
