"""Config registry: the 10 assigned architectures + DiFuseR workloads.

``--arch <id>`` everywhere resolves through ``get_config``. Every (arch x
shape) dry-run cell is enumerated by ``iter_cells()`` with the assignment's
skip rules applied (long_500k only for sub-quadratic archs)."""
from __future__ import annotations

import dataclasses
from typing import Iterator

from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.qwen1_5_4b import CONFIG as _qwen
from repro.configs.zamba2_1_2b import CONFIG as _zamba
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.mamba2_780m import CONFIG as _mamba
from repro.configs.internvl2_26b import CONFIG as _internvl
from repro.models.config import ModelConfig, reduced

ARCHS = {
    "deepseek-moe-16b": _deepseek,
    "grok-1-314b": _grok,
    "yi-34b": _yi,
    "h2o-danube-3-4b": _danube,
    "tinyllama-1.1b": _tinyllama,
    "qwen1.5-4b": _qwen,
    "zamba2-1.2b": _zamba,
    "whisper-medium": _whisper,
    "mamba2-780m": _mamba,
    "internvl2-26b": _internvl,
}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_reduced(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def cell_is_valid(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Assignment skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (assignment rule)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "decode skipped: encoder-only arch"
    return True, ""


def iter_cells() -> Iterator[tuple[str, str, bool, str]]:
    """Yields (arch, shape, valid, skip_reason) over all 40 cells."""
    for arch, cfg in ARCHS.items():
        for shape_name, shape in SHAPES.items():
            ok, why = cell_is_valid(cfg, shape)
            yield arch, shape_name, ok, why
