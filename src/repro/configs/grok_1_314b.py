"""grok-1-314b [hf:xai-org/grok-1; unverified] — 64L d_model=6144 48H
(GQA kv=8) d_ff=32768 vocab=131072, 8 experts top-2. The 314B total /
~86B active parameter budget forces Adafactor (factored second moment):
AdamW fp32 m+v alone would be 2.5 TB (see EXPERIMENTS.md memory table).
moe_shard_mode="ffn": 8 experts don't divide the 16-way model axis, so TP
shards each expert's 32768-wide FFN instead (EP×TP hybrid)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="decoder",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    moe_d_ff=32768,
    vocab_size=131072,
    moe_num_experts=8,
    moe_top_k=2,
    moe_shard_mode="ffn",
    optimizer="adafactor",
    sub_quadratic=False,
)
