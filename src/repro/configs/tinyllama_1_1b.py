"""tinyllama-1.1b [arXiv:2401.02385; hf] — llama2-arch small:
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Also the reference arch for the train-loop example."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="decoder",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    sub_quadratic=False,
)
