"""Unified execution API for the DiFuseR reproduction.

One :class:`Backend` protocol, three registered implementations —

  * ``single`` — the jitted single-device Alg. 4 driver (reference
    numerics, always available);
  * ``serial`` — the serial-ring executor (the 2-D ring schedule on one
    host; always available; the only backend with per-shard repair);
  * ``mesh``   — the shard_map 2-D runtime (needs new-enough jax + devices)

— selected by :class:`RunSpec` (``backend="auto"`` picks the best available
strategy for the requested shard grid), behind one facade object,
:class:`InfluenceSession`. Results are backend-invariant by contract: the
same (graph, sketch setting) produces bit-identical seed sets and register
matrices on every backend that supports it (tests/test_runtime.py).

Quick start::

    from repro.runtime import InfluenceSession, RunSpec

    sess = InfluenceSession(graph, RunSpec(num_registers=512, model="ic"))
    cold = sess.find_seeds(10)          # resolved backend, cold run
    warm = sess.find_seeds_warm(10)     # resident-index path, byte-identical
    print(sess.last_report.backend)     # which backend "auto" picked

See docs/runtime.md for the protocol, the ``auto`` resolution rules, and
the migration table from the legacy entry points (which remain as thin
deprecation shims over this package).
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.runtime.base import (Backend, BackendCapabilities,
                                BackendUnavailable, RunReport,
                                available_backends, get_backend,
                                register_backend, resolve_backend,
                                resolve_residency)
from repro.runtime.spec import RunSpec

# importing the implementations registers them
from repro.runtime import single as _single   # noqa: F401,E402
from repro.runtime import serial as _serial   # noqa: F401,E402
from repro.runtime import mesh as _mesh       # noqa: F401,E402

from repro.runtime.session import InfluenceSession  # noqa: E402


def run(g, k: int, spec: Optional[RunSpec] = None, *, x=None, mesh=None,
        plan=None) -> RunReport:
    """One-shot facade: resolve the backend for ``spec`` and run Alg. 4.

    The functional spelling of ``InfluenceSession(g, spec).find_seeds(k)``
    for callers that don't need the resident-store half of the session.
    """
    spec = spec if spec is not None else RunSpec()
    backend = resolve_backend(spec, g, mesh=mesh)
    return backend.find_seeds(g, k, spec, x=x, mesh=mesh, plan=plan)


def warn_deprecated(old: str, new: str) -> None:
    """The shared deprecation notice of the legacy entry-point shims."""
    warnings.warn(f"{old} is deprecated; use {new} (see docs/runtime.md "
                  f"migration table)", DeprecationWarning, stacklevel=3)


__all__ = [
    "Backend",
    "BackendCapabilities",
    "BackendUnavailable",
    "InfluenceSession",
    "RunReport",
    "RunSpec",
    "available_backends",
    "get_backend",
    "register_backend",
    "resolve_backend",
    "resolve_residency",
    "run",
    "warn_deprecated",
]
