"""``mesh`` backend — the shard_map 2-D distributed runtime.

Wraps ``core/distributed.py``. Needs a jax new enough to ship
``jax.sharding.AxisType`` (the ``JAX_HAS_AXIS_TYPE`` guard) and at least
``mu_v * mu_s`` devices; otherwise ``supports`` says no and ``auto``
resolution falls back to the ``serial`` backend, which executes the exact
same ring schedule (results are bit-identical by contract).
"""
from __future__ import annotations

import math
import time
from typing import Optional

import numpy as np

from repro.graphs.structs import Graph
from repro.runtime.base import (Backend, BackendCapabilities, RunReport,
                                apply_tuning, register_backend)
from repro.runtime.spec import RunSpec
from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE


class MeshBackend(Backend):
    name = "mesh"

    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name=self.name, distributed=True, needs_mesh=True,
            shard_repair=True,
            description="shard_map 2-D runtime (ring/allgather schedules; "
                        "shard-restricted repair of device-resident banks)")

    def available(self):
        if not JAX_HAS_AXIS_TYPE:
            return False, ("jax.sharding.AxisType missing (old jax) — the "
                           "shard_map runtime needs a newer jax; the 'serial' "
                           "backend runs the same schedule meanwhile")
        return True, ""

    def supports(self, g, spec: RunSpec):
        ok, why = self.available()
        if not ok:
            return ok, why
        import jax

        ndev = len(jax.devices())
        if ndev < spec.num_shards:
            return False, (f"spec asks for {spec.num_shards} shards but only "
                           f"{ndev} device(s) are visible (export XLA_FLAGS="
                           f"--xla_force_host_platform_device_count="
                           f"{spec.num_shards} for a host-device mesh)")
        if spec.num_registers % max(spec.mu_s, 1) != 0:
            return False, (f"num_registers={spec.num_registers} not divisible "
                           f"by mu_s={spec.mu_s}")
        return True, ""

    def _mesh_for(self, spec: RunSpec, mesh=None):
        if mesh is not None:
            return mesh
        from repro.launch.mesh import make_mesh

        mu_v, mu_s = max(spec.mu_v, 1), max(spec.mu_s, 1)
        if len(spec.sim_axes) != 1:
            raise ValueError("pass an explicit mesh for multi-sim-axis specs")
        return make_mesh((mu_v, mu_s), (spec.vertex_axis, spec.sim_axes[0]))

    def _check(self, g, spec: RunSpec):
        ok, why = self.supports(g, spec)
        if not ok:
            from repro.runtime.base import BackendUnavailable

            raise BackendUnavailable(f"mesh backend: {why}")

    def find_seeds(self, g: Graph, k: int, spec: RunSpec, *,
                   x: Optional[np.ndarray] = None, mesh=None,
                   plan=None) -> RunReport:
        self._check(g, spec)
        from repro.core import distributed as _dist

        mesh = self._mesh_for(spec, mesh)
        t0 = time.perf_counter()
        # tuned on the serial ring twin — same bucket schedule, so the
        # (local_sweeps, pad_mode) ranking transfers to the device path
        spec = apply_tuning(g, spec, self.name)
        cfg = spec.distributed_config()
        res, part = _dist._find_seeds_distributed(g, k, mesh, cfg, x, plan=plan)
        return RunReport(result=res, backend=self.name, spec=spec,
                         partition=part, wall_s=time.perf_counter() - t0)

    def build_matrix(self, g: Graph, spec: RunSpec, x: np.ndarray, *,
                     reg_offset: int = 0, normalized: bool = False,
                     edges=None, mesh=None):
        # ``edges`` (single-backend device operands) is not applicable: the
        # shard_map build re-buckets per x-slice on host.
        self._check(g, spec)
        from repro.core import distributed as _dist

        spec = apply_tuning(g, spec, self.name)
        cfg = spec.distributed_config()
        if not normalized:
            from repro.core.difuser import normalize_inputs

            g, x = normalize_inputs(g, spec.difuser_config(), x)
        mesh = self._mesh_for(spec, mesh)
        mu_s = math.prod(mesh.shape[ax] for ax in cfg.sim_axes)
        if x is not None and np.asarray(x).shape[0] % mu_s != 0:
            raise ValueError(
                f"bank of {np.asarray(x).shape[0]} registers not divisible "
                f"by the mesh's {mu_s} sim shard(s)")
        m, iters, _ = _dist.build_matrix_distributed(
            g, mesh, cfg, x, reg_offset=reg_offset)
        return m, iters

    # -- shard-level repair (device-resident store banks) ------------------

    def repair_plan_shards(self, g: Graph, spec: RunSpec, x: np.ndarray,
                           planned_m, plan, touched, *, mesh=None):
        """Frontier-restricted re-propagation of only the touched plan
        shards under shard_map (``core.distributed.
        repair_plan_shards_distributed``) — the device twin of the serial
        ring repair, bit-identical to it and to a full rebuild. ``mesh``
        should be the placement mesh of the matrix (a device-resident
        entry's); without one, a row-only serving mesh of ``plan.mu_v``
        devices is constructed."""
        ok, why = self.available()
        if not ok:
            from repro.runtime.base import BackendUnavailable

            raise BackendUnavailable(f"mesh backend: {why}")
        from repro.core import distributed as _dist

        if mesh is None:
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(plan.mu_v, vertex_axis=spec.vertex_axis)
        sim_axes = tuple(ax for ax in mesh.axis_names if ax != spec.vertex_axis)
        cfg = spec.with_(sim_axes=sim_axes).distributed_config()
        return _dist.repair_plan_shards_distributed(
            g, mesh, cfg, x, planned_m, plan, touched)


register_backend(MeshBackend())
