"""InfluenceSession — one facade over the whole influence pipeline.

Before the runtime layer, a caller stitched four APIs by hand:
``core.difuser.find_seeds`` (cold), ``core.difuser.find_seeds_warm`` +
``build_sketch_matrix`` (amortized), ``SketchStore.get_or_build`` (resident
index), and one of three executors. A session binds a graph to a
:class:`RunSpec` once and exposes all of it behind a single object; the
backend is resolved lazily from the spec (``"auto"`` rules in
:mod:`repro.runtime.base`) so the same session code runs unchanged from one
device to a mesh.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import difuser as _difuser
from repro.core.difuser import InfluenceResult
from repro.graphs.structs import Graph, GraphDelta
from repro.runtime.base import (Backend, BackendUnavailable, RunReport,
                                resolve_backend, resolve_residency)
from repro.runtime.spec import RunSpec
from repro.service.delta import DeltaReport, apply_delta
from repro.service.store import SketchStore, StoreEntry


class InfluenceSession:
    """A graph bound to one execution contract (:class:`RunSpec`).

    ``store`` shares a :class:`SketchStore` across sessions (multi-graph
    tenancy); by default the session owns a private one, built through the
    session's backend. ``mesh`` pins an explicit jax mesh for the ``mesh``
    backend's ``find_seeds``/``build_sketch_matrix`` (otherwise one is
    constructed from ``spec.mu_v x spec.mu_s``); store-path builds
    (``entry()``) always construct their own mesh from the spec, since a
    shared store outlives any one session's device placement.
    """

    def __init__(self, graph: Graph, spec: Optional[RunSpec] = None, *,
                 store: Optional[SketchStore] = None, mesh=None,
                 num_banks: int = 1):
        self.graph = graph
        self.spec = spec if spec is not None else RunSpec()
        self.mesh = mesh
        self.store = (store if store is not None
                      else SketchStore(num_banks=num_banks, spec=self.spec))
        self.last_report: Optional[RunReport] = None
        # the store key of this session's resident entry: store keys name the
        # *lineage* graph (they survive deltas), so the session pins the key
        # instead of re-deriving it from the (possibly post-delta) graph
        self._entry_key = None

    @property
    def backend(self) -> Backend:
        """The backend the spec resolves to *right now* (auto rules are
        environment-dependent: device count, jax version)."""
        return resolve_backend(self.spec, self.graph, mesh=self.mesh)

    # ------------------------------------------------------------------
    # Cold path
    # ------------------------------------------------------------------

    def find_seeds(self, k: int, *, x: Optional[np.ndarray] = None,
                   plan=None) -> InfluenceResult:
        """Full Alg. 4 through the resolved backend. Execution provenance
        (backend name, built partition, wall time) lands in
        ``self.last_report``."""
        report = self.backend.find_seeds(self.graph, k, self.spec, x=x,
                                         mesh=self.mesh, plan=plan)
        self.last_report = report
        return report.result

    def build_sketch_matrix(self, *, x: Optional[np.ndarray] = None,
                            reg_offset: int = 0):
        """Alg. 4 lines 3-6 through the resolved backend: returns
        ``(matrix, iters, x_used)`` in the canonical layout (identical
        across backends)."""
        cfg = self.spec.difuser_config()
        g, x_norm = _difuser.normalize_inputs(self.graph, cfg, x)
        m, iters = self.backend.build_matrix(g, self.spec, x_norm,
                                             reg_offset=reg_offset,
                                             normalized=True, mesh=self.mesh)
        return m, iters, x_norm

    # ------------------------------------------------------------------
    # Warm / resident path (the store half of the facade)
    # ------------------------------------------------------------------

    def entry(self, *, x: Optional[np.ndarray] = None) -> StoreEntry:
        """The resident store entry for this session's (graph, setting),
        built through the session's backend on first demand — and *placed*
        per the spec's residency: ``residency="device"`` (or ``"auto"``
        resolving to the mesh backend) pins the banks as plan-order row
        blocks on the serving mesh, so queries reduce shard-local."""
        if (x is None and self._entry_key is not None
                and self._entry_key in self.store):
            e = self.store.entry(self._entry_key)
        else:
            e = self.store.get_or_build(self.graph,
                                        self.spec.difuser_config(), x)
            self._entry_key = e.key
        self._route_residency(e)
        return e

    def _route_residency(self, e: StoreEntry) -> None:
        """Place a host-order entry on the mesh when the spec asks for (or
        auto-resolves to) device residency; attach a serving plan first if
        the entry has none (``spec.partition`` strategy, one row block per
        shard of the spec's grid)."""
        backend = self.backend
        if resolve_residency(self.spec, backend) != "device":
            return
        if e.residency == "device":
            return
        from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

        if not JAX_HAS_AXIS_TYPE:
            raise BackendUnavailable(
                "device residency needs jax.sharding.AxisType (newer jax); "
                "residency='host' serves the same answers host-order")
        shards = (e.plan.mu_v if e.plan is not None
                  else max(self.spec.mu_v if self.spec.mu_v > 1
                           else self.spec.num_shards, 1))
        import jax

        if len(jax.devices()) < shards:
            raise BackendUnavailable(
                f"device residency places {shards} row blocks but only "
                f"{len(jax.devices())} device(s) are visible (export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} "
                f"for a host-device mesh); residency='host' serves the same "
                f"answers host-order")
        if e.plan is None:
            from repro.partition import plan_partition

            plan = plan_partition(e.graph, shards, mu_s=1,
                                  strategy=self.spec.partition, x=e.x,
                                  seed=e.cfg.seed, model=e.cfg.model)
            self.store.attach_plan(e.key, plan)
        e.place_on_mesh(self._serving_mesh(e.plan),
                        vertex_axis=self.spec.vertex_axis)

    def _serving_mesh(self, plan):
        """The session's pinned mesh when it matches the plan's row-only
        serving layout, else a fresh ``(mu_v, 1)`` mesh."""
        import math

        if (self.mesh is not None
                and self.mesh.shape.get(self.spec.vertex_axis) == plan.mu_v
                and math.prod(self.mesh.shape.values()) == plan.mu_v):
            return self.mesh
        from repro.launch.mesh import make_serving_mesh

        return make_serving_mesh(plan.mu_v, vertex_axis=self.spec.vertex_axis,
                                 sim_axis=self.spec.sim_axes[0])

    def find_seeds_warm(self, k: int, *,
                        x: Optional[np.ndarray] = None) -> InfluenceResult:
        """K seed rounds from the resident matrix (cold build amortized
        away). The round program is the identical trace as the cold path's,
        so warm seeds are byte-identical to ``find_seeds`` regardless of
        which backend built the matrix — a device-resident entry runs the
        rounds under shard_map straight off its placed row blocks. Routed
        through ``queries.top_k_seeds`` so a stale entry (removal deltas
        below the rebuild threshold) is lazily rebuilt first, exactly like
        engine-served TopKSeeds — warm never serves an unsound index."""
        from repro.service.queries import top_k_seeds

        return top_k_seeds(self.store, self.entry(x=x), k)

    def apply_delta(self, delta: GraphDelta, *,
                    staleness_threshold: float = 0.1) -> DeltaReport:
        """Apply a graph delta to the resident entry through the session's
        backend: on a shard-repair-capable backend (``serial``, or ``mesh``
        for device-resident banks) with a plan attached, insertions
        re-propagate only the plan shards the delta dirtied. The session's
        own graph follows the entry's post-delta graph, so the cold paths
        (``find_seeds``, ``build_sketch_matrix``) and the warm/resident
        paths keep answering about the same graph."""
        e = self.entry()
        report = apply_delta(self.store, e.key, delta,
                             staleness_threshold=staleness_threshold,
                             backend=self.backend)
        self.graph = self.store.entry(e.key).graph
        return report
