"""InfluenceSession — one facade over the whole influence pipeline.

Before the runtime layer, a caller stitched four APIs by hand:
``core.difuser.find_seeds`` (cold), ``core.difuser.find_seeds_warm`` +
``build_sketch_matrix`` (amortized), ``SketchStore.get_or_build`` (resident
index), and one of three executors. A session binds a graph to a
:class:`RunSpec` once and exposes all of it behind a single object; the
backend is resolved lazily from the spec (``"auto"`` rules in
:mod:`repro.runtime.base`) so the same session code runs unchanged from one
device to a mesh.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import difuser as _difuser
from repro.core.difuser import InfluenceResult
from repro.graphs.structs import Graph, GraphDelta
from repro.runtime.base import Backend, RunReport, resolve_backend
from repro.runtime.spec import RunSpec
from repro.service.delta import DeltaReport, apply_delta
from repro.service.store import SketchStore, StoreEntry


class InfluenceSession:
    """A graph bound to one execution contract (:class:`RunSpec`).

    ``store`` shares a :class:`SketchStore` across sessions (multi-graph
    tenancy); by default the session owns a private one, built through the
    session's backend. ``mesh`` pins an explicit jax mesh for the ``mesh``
    backend's ``find_seeds``/``build_sketch_matrix`` (otherwise one is
    constructed from ``spec.mu_v x spec.mu_s``); store-path builds
    (``entry()``) always construct their own mesh from the spec, since a
    shared store outlives any one session's device placement.
    """

    def __init__(self, graph: Graph, spec: Optional[RunSpec] = None, *,
                 store: Optional[SketchStore] = None, mesh=None,
                 num_banks: int = 1):
        self.graph = graph
        self.spec = spec if spec is not None else RunSpec()
        self.mesh = mesh
        self.store = (store if store is not None
                      else SketchStore(num_banks=num_banks, spec=self.spec))
        self.last_report: Optional[RunReport] = None

    @property
    def backend(self) -> Backend:
        """The backend the spec resolves to *right now* (auto rules are
        environment-dependent: device count, jax version)."""
        return resolve_backend(self.spec, self.graph, mesh=self.mesh)

    # ------------------------------------------------------------------
    # Cold path
    # ------------------------------------------------------------------

    def find_seeds(self, k: int, *, x: Optional[np.ndarray] = None,
                   plan=None) -> InfluenceResult:
        """Full Alg. 4 through the resolved backend. Execution provenance
        (backend name, built partition, wall time) lands in
        ``self.last_report``."""
        report = self.backend.find_seeds(self.graph, k, self.spec, x=x,
                                         mesh=self.mesh, plan=plan)
        self.last_report = report
        return report.result

    def build_sketch_matrix(self, *, x: Optional[np.ndarray] = None,
                            reg_offset: int = 0):
        """Alg. 4 lines 3-6 through the resolved backend: returns
        ``(matrix, iters, x_used)`` in the canonical layout (identical
        across backends)."""
        cfg = self.spec.difuser_config()
        g, x_norm = _difuser.normalize_inputs(self.graph, cfg, x)
        m, iters = self.backend.build_matrix(g, self.spec, x_norm,
                                             reg_offset=reg_offset,
                                             normalized=True, mesh=self.mesh)
        return m, iters, x_norm

    # ------------------------------------------------------------------
    # Warm / resident path (the store half of the facade)
    # ------------------------------------------------------------------

    def entry(self, *, x: Optional[np.ndarray] = None) -> StoreEntry:
        """The resident store entry for this session's (graph, setting),
        built through the session's backend on first demand."""
        return self.store.get_or_build(self.graph, self.spec.difuser_config(),
                                       x)

    def find_seeds_warm(self, k: int, *,
                        x: Optional[np.ndarray] = None) -> InfluenceResult:
        """K seed rounds from the resident matrix (cold build amortized
        away). The round program is the identical trace as the cold path's,
        so warm seeds are byte-identical to ``find_seeds`` regardless of
        which backend built the matrix."""
        e = self.entry(x=x)
        return _difuser.find_seeds_warm(e.graph, k, e.cfg, matrix=e.matrix,
                                        x=e.x, edges=e.device_edges())

    def apply_delta(self, delta: GraphDelta, *,
                    staleness_threshold: float = 0.1) -> DeltaReport:
        """Apply a graph delta to the resident entry through the session's
        backend: on a shard-repair-capable backend (``serial``) with a plan
        attached, insertions re-propagate only the plan shards the delta
        dirtied."""
        e = self.entry()
        return apply_delta(self.store, e.key, delta,
                           staleness_threshold=staleness_threshold,
                           backend=self.backend)
