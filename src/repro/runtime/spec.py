"""RunSpec — one flat description of *what* to run and *how* to execute it.

Before the runtime layer existed, the knobs of a DiFuseR run were scattered
across four call sites: ``DiFuserConfig`` (sketch + diffusion setting),
``DistributedConfig`` (mesh axes, ring schedule, partition strategy, bucket
padding), the mesh shape handed to ``find_seeds_distributed``, and the
``mu_v/mu_s/strategy`` keywords of the serial-ring executor. ``RunSpec``
consolidates all of them plus the *backend selection* itself, so a caller
states the full execution contract once and every backend reads the subset
it understands.

Only the sketch/diffusion fields affect *results* — the execution fields
(``backend``, ``mu_v``, ``mu_s``, ``partition``, ``pad_mode``, ``schedule``,
``local_sweeps``, ``fuse_sweeps``, ``lane_fill``) are pure strategy: seed
sets are bit-identical across
every backend and every partition plan (tests/test_runtime.py holds the
line). That invariance is what makes ``backend="auto"`` safe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.difuser import DiFuserConfig
from repro.diffusion.constants import DEFAULT_MODEL

#: DiFuserConfig field names (the result-affecting half of a RunSpec, plus
#: the performance-only tile knobs that ride in the same config).
_SKETCH_FIELDS = ("num_registers", "seed", "estimator", "rebuild_threshold",
                  "max_propagate_iters", "max_cascade_iters", "edge_chunk",
                  "impl", "sort_x", "model", "cascade_chunk", "edge_block",
                  "reg_tile")

#: DistributedConfig-only field names shared with RunSpec.
_EXEC_FIELDS = ("vertex_axis", "sim_axes", "schedule", "fasst",
                "local_sweeps", "fuse_sweeps", "lane_fill", "partition",
                "pad_mode")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The unified execution contract of one influence-maximization run."""

    # ---- sketch / diffusion setting (mirrors DiFuserConfig) ----
    num_registers: int = 1024
    seed: int = 0
    estimator: str = "hll"             # "hll" | "fm_mean"
    rebuild_threshold: float = 0.01
    max_propagate_iters: int = 64
    max_cascade_iters: int = 64
    edge_chunk: int = 2048
    impl: str = "ref"                  # "ref" | "pallas"
    sort_x: bool = True                # FASST sample ordering
    model: str = DEFAULT_MODEL         # diffusion model spec (repro.diffusion)
    # performance-only tile knobs (0 = library default; repro.tune writes
    # measured winners here — results are invariant by the kernel contract)
    cascade_chunk: int = 0             # cascade scan chunk (ref impl)
    edge_block: int = 0                # pallas edge tile
    reg_tile: int = 0                  # pallas register tile

    # ---- execution strategy ----
    backend: str = "auto"              # "auto" | registered backend name
    residency: str = "auto"            # "auto" | "host" | "device" — where
    #   store banks live for serving: "device" pins plan-order row blocks on
    #   the mesh (shard-local query reductions); "auto" follows the resolved
    #   backend (mesh -> device, else host); see runtime.resolve_residency
    mu_v: int = 1                      # vertex shards (2-D partition rows)
    mu_s: int = 1                      # sample-space shards
    partition: str = "block"           # vertex-assignment strategy
    pad_mode: str = "step"             # "step" | "global" bucket padding
    schedule: str = "ring"             # "ring" | "allgather" (mesh backend)
    fasst: bool = True                 # FASST sample partition (vs naive)
    local_sweeps: int = 0              # comm-free sweeps per ring exchange
    fuse_sweeps: bool = False          # run the local_sweeps prologue fused
    #   (kernels/fused_sweep: all sweeps in one launch, register block
    #   resident between them). Performance-only by the kernel contract.
    lane_fill: int = 0                 # fused-kernel register slab width
    #   (0 = full width); model-aware — repro.tune seeds denser fills for
    #   remixed-predicate models (lt)
    vertex_axis: str = "data"          # mesh axis names (mesh backend)
    sim_axes: Tuple[str, ...] = ("model",)

    # ---- serving objectives ----
    # per-query-class p99 latency budgets as ((class, budget_ms), ...) —
    # tuple-of-tuples keeps the spec frozen/hashable. Consumed by
    # InfluenceEngine (repro.obs.slo watchdog: rolling-window p99, breach
    # counters, flight-recorder dump on breach); empty = no objectives.
    # Not part of _SKETCH_FIELDS/_EXEC_FIELDS, so it never leaks into the
    # legacy config conversions.
    slo: Tuple[Tuple[str, float], ...] = ()

    # ---- async serving (repro.service.async_engine) ----
    # serve_async routes launch/serve_im through AsyncInfluenceEngine:
    # deadline-driven micro-batching, builds/repairs double-buffered off the
    # serving path, cost-aware eviction. deadline_ms is the end-to-end SLO
    # per query (0 = best effort, default 50ms inside the async engine);
    # max_resident_mb caps resident store bytes (0 = unbounded, no evictor).
    # Results are bit-identical to the synchronous path by contract; like
    # ``slo``, none of these are _SKETCH_FIELDS/_EXEC_FIELDS.
    serve_async: bool = False
    deadline_ms: float = 0.0
    max_resident_mb: float = 0.0

    # ---- measurement-driven kernel tuning (repro.tune) ----
    # "off"    — exact historical behaviour, no cache reads, no measuring
    # "cached" — apply TuningCache winners when present (deterministic
    #            fallback to the spec's own values on a miss)
    # "auto"   — like "cached", but a miss measures candidates against the
    #            actual graph and persists the winner
    # Performance-only by contract: seed sets and sketch matrices are
    # bit-identical across all three modes (tier-1 property-tested). Like
    # ``slo``, not part of _SKETCH_FIELDS/_EXEC_FIELDS.
    tuning: str = "off"

    @property
    def num_shards(self) -> int:
        """Total shard-grid size the spec asks for (1 = unsharded)."""
        return max(self.mu_v, 1) * max(self.mu_s, 1)

    # ------------------------------------------------------------------
    # Conversions to/from the legacy config objects
    # ------------------------------------------------------------------

    def difuser_config(self) -> DiFuserConfig:
        """The DiFuserConfig equivalent (single-device / store / queries)."""
        return DiFuserConfig(**{f: getattr(self, f) for f in _SKETCH_FIELDS})

    def distributed_config(self):
        """The DistributedConfig equivalent (mesh backend)."""
        from repro.core.distributed import DistributedConfig

        kw = {f: getattr(self, f) for f in _SKETCH_FIELDS}
        kw.update({f: getattr(self, f) for f in _EXEC_FIELDS})
        kw["sim_axes"] = tuple(self.sim_axes)
        return DistributedConfig(**kw)

    @classmethod
    def from_config(cls, config: Optional[DiFuserConfig] = None,
                    base: Optional["RunSpec"] = None, **overrides) -> "RunSpec":
        """Lift a legacy config into a RunSpec.

        ``config`` supplies the sketch/diffusion fields (and, when it is a
        ``DistributedConfig``, the execution fields it carries); ``base``
        supplies defaults for everything the config does not name (backend,
        mu_v/mu_s, ...); ``overrides`` win over both. ``config=None`` means
        paper defaults — exactly ``DiFuserConfig()``.
        """
        spec = base if base is not None else cls()
        kw: dict = {}
        if config is not None:
            for f in _SKETCH_FIELDS:
                kw[f] = getattr(config, f)
            for f in _EXEC_FIELDS:   # only DistributedConfig has these
                if hasattr(config, f):
                    kw[f] = getattr(config, f)
            if "sim_axes" in kw:
                kw["sim_axes"] = tuple(kw["sim_axes"])
        kw.update(overrides)
        return dataclasses.replace(spec, **kw)

    def with_(self, **overrides) -> "RunSpec":
        """Functional update (``dataclasses.replace`` spelled as a method)."""
        return dataclasses.replace(self, **overrides)
