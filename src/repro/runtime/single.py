"""``single`` backend — the jitted single-device Alg. 4 driver.

Wraps the one-program ``lax.scan``/``while_loop`` pipeline in
``core/difuser.py``. Always available; the reference numerics every other
backend must match bit-for-bit.
"""
from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import difuser as _difuser
from repro.core.cascade import cascade_from_seed
from repro.core.simulate import propagate_to_fixpoint
from repro.graphs.structs import Graph
from repro.runtime.base import (Backend, BackendCapabilities, RunReport,
                                apply_tuning, register_backend)
from repro.runtime.spec import RunSpec


class SingleDeviceBackend(Backend):
    name = "single"

    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name=self.name, distributed=False, needs_mesh=False,
            shard_repair=False,
            description="jitted single-device Alg. 4 (reference numerics)")

    def supports(self, g, spec: RunSpec):
        # a >1 shard grid is an execution *hint* the single backend simply
        # ignores (results are shard-invariant by contract), so it supports
        # every spec — auto resolution just won't pick it for sharded specs
        return True, ""

    def find_seeds(self, g: Graph, k: int, spec: RunSpec, *,
                   x: Optional[np.ndarray] = None, mesh=None,
                   plan=None) -> RunReport:
        t0 = time.perf_counter()
        spec = apply_tuning(g, spec, self.name)
        res = _difuser._find_seeds_single(g, k, spec.difuser_config(), x)
        return RunReport(result=res, backend=self.name, spec=spec,
                         partition=None, wall_s=time.perf_counter() - t0)

    def build_matrix(self, g: Graph, spec: RunSpec, x: np.ndarray, *,
                     reg_offset: int = 0, normalized: bool = False,
                     edges=None, mesh=None):
        spec = apply_tuning(g, spec, self.name)
        m, iters, _ = _difuser.build_sketch_matrix(
            g, spec.difuser_config(), x, reg_offset=reg_offset,
            normalized=normalized, edges=edges)
        return m, iters

    def fixpoint(self, m, g: Graph, spec: RunSpec, x: np.ndarray, *,
                 edges=None):
        cfg = apply_tuning(g, spec, self.name).difuser_config()
        if edges is None:
            edges = _difuser.edge_operands(g, cfg)
        src, dst, h, lo, thr = edges
        return propagate_to_fixpoint(
            m, src, dst, thr, jnp.asarray(np.asarray(x, np.uint32)), h, lo,
            seed=cfg.seed, impl=cfg.impl, edge_chunk=cfg.edge_chunk,
            max_iters=cfg.max_propagate_iters,
            predicate=_difuser.resolve_model(cfg.model).predicate,
            edge_block=cfg.edge_block, reg_tile=cfg.reg_tile)

    def cascade(self, m, seed_vertex: int, g: Graph, spec: RunSpec,
                x: np.ndarray, *, edges=None):
        cfg = apply_tuning(g, spec, self.name).difuser_config()
        if edges is None:
            edges = _difuser.edge_operands(g, cfg)
        src, dst, h, lo, thr = edges
        return cascade_from_seed(
            m, seed_vertex, src, dst, thr,
            jnp.asarray(np.asarray(x, np.uint32)), h, lo, seed=cfg.seed,
            impl=cfg.impl, edge_chunk=cfg.cascade_chunk or cfg.edge_chunk,
            max_iters=cfg.max_cascade_iters,
            predicate=_difuser.resolve_model(cfg.model).predicate,
            edge_block=cfg.edge_block, reg_tile=cfg.reg_tile)


register_backend(SingleDeviceBackend())
