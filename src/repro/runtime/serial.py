"""``serial`` backend — the 2-D ring schedule executed serially on one host.

Wraps :mod:`repro.partition.serial`. Always available (pure numpy, no mesh,
no jax version requirements), which makes it the ``auto`` fallback whenever
a sharded spec is requested on an environment whose jax cannot run
``shard_map`` — and the only backend that can repair *individual plan
shards* of a store matrix (``repair_plan_shards``), the hook behind
``DeltaReport.plan_shards_touched``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.graphs.structs import Graph
from repro.partition import serial as _serial
from repro.runtime.base import (Backend, BackendCapabilities, RunReport,
                                apply_tuning, register_backend)
from repro.runtime.spec import RunSpec


def _grid(spec: RunSpec) -> tuple[int, int]:
    """The (mu_v, mu_s) shard grid a spec asks the serial ring to emulate."""
    return max(spec.mu_v, 1), max(spec.mu_s, 1)


class SerialRingBackend(Backend):
    name = "serial"

    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name=self.name, distributed=True, needs_mesh=False,
            shard_repair=True,
            description="serial-ring executor (numpy twin of the shard_map "
                        "body; always available)")

    def supports(self, g, spec: RunSpec):
        mu_v, mu_s = _grid(spec)
        if spec.num_registers % mu_s != 0:
            return False, (f"num_registers={spec.num_registers} not divisible "
                           f"by mu_s={mu_s}")
        return True, ""

    def find_seeds(self, g: Graph, k: int, spec: RunSpec, *,
                   x: Optional[np.ndarray] = None, mesh=None,
                   plan=None) -> RunReport:
        t0 = time.perf_counter()
        spec = apply_tuning(g, spec, self.name)
        mu_v, mu_s = _grid(spec)
        res, part = _serial._find_seeds_ring_serial(
            g, k, spec.difuser_config(), mu_v=mu_v, mu_s=mu_s,
            strategy=spec.partition, plan=plan, x=x, pad_mode=spec.pad_mode,
            local_sweeps=spec.local_sweeps, fuse_sweeps=spec.fuse_sweeps,
            lane_fill=spec.lane_fill)
        return RunReport(result=res, backend=self.name, spec=spec,
                         partition=part, wall_s=time.perf_counter() - t0)

    def build_matrix(self, g: Graph, spec: RunSpec, x: np.ndarray, *,
                     reg_offset: int = 0, normalized: bool = False,
                     edges=None, mesh=None):
        # ``edges`` (single-backend device operands) and ``mesh`` are not
        # applicable: the ring build re-buckets per x-slice on host.
        spec = apply_tuning(g, spec, self.name)
        cfg = spec.difuser_config()
        if not normalized:
            from repro.core.difuser import normalize_inputs

            g, x = normalize_inputs(g, cfg, x)
        mu_v, mu_s = _grid(spec)
        if x is not None and np.asarray(x).shape[0] % mu_s != 0:
            mu_s = 1   # bank slice narrower than the sim grid: keep it whole
        m, iters, _ = _serial.build_matrix_ring_serial(
            g, cfg, x, mu_v=mu_v, mu_s=mu_s, strategy=spec.partition,
            pad_mode=spec.pad_mode, reg_offset=reg_offset,
            local_sweeps=spec.local_sweeps, fuse_sweeps=spec.fuse_sweeps,
            lane_fill=spec.lane_fill)
        return m, iters

    def fixpoint(self, m, g: Graph, spec: RunSpec, x: np.ndarray, *,
                 edges=None):
        """Canonical-layout fixpoint via a full (unrestricted) ring repair:
        every shard starts dirty."""
        mu_v, mu_s = _grid(spec)
        x = np.asarray(x, dtype=np.uint32)
        if x.shape[0] % mu_s != 0:
            mu_s = 1
        cfg = spec.difuser_config()
        from repro.partition import plan_partition

        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy=spec.partition,
                              seed=cfg.seed, model=cfg.model)
        n_extra = plan.n_pad - g.n_pad
        m_np = np.asarray(m, dtype=np.int8)
        if n_extra > 0:
            m_np = np.concatenate(
                [m_np, np.full((n_extra, m_np.shape[1]), np.int8(-1))], axis=0)
        planned = m_np[plan.inv_perm]
        planned, iters, _ = _serial.repair_plan_shards(
            g, cfg, x, planned, plan, range(mu_v), pad_mode=spec.pad_mode)
        return planned[plan.perm[: g.n_pad]], iters

    # -- shard-level repair (the mesh-sharded store-bank hook) -------------

    def repair_plan_shards(self, g: Graph, spec: RunSpec, x: np.ndarray,
                           planned_m: np.ndarray, plan, touched, *,
                           mesh=None):
        """Delegates to :func:`repro.partition.serial.repair_plan_shards`:
        frontier-restricted ring sweeps that re-propagate only the shards a
        delta dirtied (plus any shard the repair actually spreads into).
        ``mesh`` (a device placement) is not applicable — the ring runs on
        host; a device-resident matrix is pulled host-side first."""
        return _serial.repair_plan_shards(
            g, spec.difuser_config(), x, np.asarray(planned_m), plan, touched,
            pad_mode=spec.pad_mode)


register_backend(SerialRingBackend())
