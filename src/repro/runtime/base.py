"""Backend protocol + registry — one execution contract, three strategies.

A :class:`Backend` answers four questions about one (graph, :class:`RunSpec`)
pair:

  * ``supports(g, spec)``   — can I run this, and if not, why not;
  * ``find_seeds(...)``     — the full Alg. 4 seed-selection loop;
  * ``build_matrix(...)``   — Alg. 4 lines 3-6 only (fill + propagate to
    fixpoint), the half the :class:`~repro.service.store.SketchStore`
    amortizes; banks build through *any* registered backend because every
    backend returns the canonical (original-id row order, full-J column)
    ``int8`` matrix;
  * ``fixpoint(...)`` / ``cascade(...)`` — the two inner hooks (re-propagate
    an existing matrix / spread one committed seed) for repair-style callers
    holding a sound lower bound; plan-aware delta repair dispatches on the
    ``shard_repair`` capability and calls ``repair_plan_shards`` instead.

Results are backend-invariant by contract: the same (graph, sketch setting)
must produce bit-identical seed sets and matrices on every backend that
supports it. ``resolve_backend`` implements ``backend="auto"``:

  1. an explicit name is honored (and raises with the reason when that
     backend cannot run here);
  2. ``spec.num_shards <= 1`` and no mesh given -> ``single``;
  3. otherwise ``mesh`` if the jax version + device count allow it,
     else ``serial`` (the always-available fallback — the exact ring
     schedule, one host).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.difuser import InfluenceResult
from repro.graphs.structs import Graph
from repro.runtime.spec import RunSpec


class BackendUnavailable(RuntimeError):
    """The requested backend cannot run in this environment/spec."""


def apply_tuning(g: Optional[Graph], spec: RunSpec,
                 backend_name: str) -> RunSpec:
    """The backends' tuning hook: overlay measured kernel-config winners
    onto ``spec`` per its ``tuning`` mode (see :mod:`repro.tune`).

    ``tuning="off"`` short-circuits here without importing the tuner —
    the historical zero-overhead path. Tuned fields are performance-only
    (tile shapes, scan chunks, ring schedule), so results are identical
    whichever spec comes back.
    """
    if getattr(spec, "tuning", "off") == "off" or g is None:
        return spec
    from repro.tune import resolve_spec

    return resolve_spec(g, spec, backend=backend_name)


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a backend reports about itself (the ``supports`` fast facts)."""

    name: str
    distributed: bool        # shards work across a (mu_v, mu_s) grid
    needs_mesh: bool         # requires a jax device mesh to run
    shard_repair: bool       # can re-propagate individual plan shards
    description: str = ""


@dataclasses.dataclass
class RunReport:
    """What a backend's ``find_seeds`` returns: the result plus provenance.

    ``result`` is the plain :class:`InfluenceResult` (identical across
    backends); ``partition`` is the built :class:`Partition2D` when the
    backend sharded the graph (``None`` on ``single``); ``wall_s`` is the
    end-to-end wall time including host partition builds.
    """

    result: InfluenceResult
    backend: str
    spec: RunSpec
    partition: Optional[object] = None
    wall_s: float = 0.0


class Backend(abc.ABC):
    """One execution strategy for the DiFuseR pipeline (see module doc)."""

    name: str = "?"

    @abc.abstractmethod
    def capabilities(self) -> BackendCapabilities:
        ...

    def available(self) -> Tuple[bool, str]:
        """Environment check only (jax version, device count...)."""
        return True, ""

    def supports(self, g: Optional[Graph], spec: RunSpec) -> Tuple[bool, str]:
        """Can this backend execute ``spec`` (optionally against ``g``)?"""
        return self.available()

    @abc.abstractmethod
    def find_seeds(self, g: Graph, k: int, spec: RunSpec, *,
                   x: Optional[np.ndarray] = None, mesh=None,
                   plan=None) -> RunReport:
        """Run the full Alg. 4 loop; seeds come back in original vertex ids."""

    @abc.abstractmethod
    def build_matrix(self, g: Graph, spec: RunSpec, x: np.ndarray, *,
                     reg_offset: int = 0, normalized: bool = False,
                     edges=None, mesh=None):
        """Fill + propagate-to-fixpoint; returns ``(matrix, iters)``.

        ``matrix`` is the canonical layout every backend agrees on:
        ``int8[g.n_pad, len(x)]`` with rows in original-id order (sharded
        backends un-permute before returning). ``reg_offset`` offsets the
        register hash slots (sample-space bank builds). ``normalized=True``
        promises ``g`` is dst-sorted and ``x`` canonical already. ``edges``
        passes precomputed ``(src, dst, h, lo, thr)`` device operands —
        an optimization hint only the ``single`` backend consumes. ``mesh``
        pins an explicit jax mesh — only the ``mesh`` backend consumes it.
        """

    def fixpoint(self, m, g: Graph, spec: RunSpec, x: np.ndarray, *,
                 edges=None):
        """Hook: re-propagate an existing canonical matrix to fixpoint.
        Returns ``(matrix, iters)``. Exposed for repair-style callers that
        hold a sound lower bound of the fixpoint (the plan-aware path goes
        through ``repair_plan_shards`` instead)."""
        raise NotImplementedError(f"backend {self.name!r} has no fixpoint hook")

    def cascade(self, m, seed_vertex: int, g: Graph, spec: RunSpec,
                x: np.ndarray, *, edges=None):
        """Hook: commit ``seed_vertex`` and spread its cascade to fixpoint.
        Returns ``(matrix, iters)``."""
        raise NotImplementedError(f"backend {self.name!r} has no cascade hook")

    def repair_plan_shards(self, g: Graph, spec: RunSpec, x: np.ndarray,
                           planned_m, plan, touched, *, mesh=None):
        """Shard-restricted repair of a plan-order matrix; returns
        ``(planned_matrix, sweeps, shards_swept)``. MUST be implemented by
        every backend whose ``capabilities().shard_repair`` is True —
        ``service.delta.apply_delta`` dispatches on that flag. ``mesh`` pins
        the jax mesh of a device-resident matrix (the entry's placement) —
        only the ``mesh`` backend consumes it."""
        raise NotImplementedError(
            f"backend {self.name!r} reports no shard_repair capability")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Register a backend under ``backend.name`` (pluggable like the
    diffusion-model and partition-strategy registries)."""
    if backend.name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name) -> Backend:
    """Resolve a backend by name (a Backend instance passes through)."""
    if isinstance(name, Backend):
        return name
    b = _BACKENDS.get(name)
    if b is None:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{sorted(_BACKENDS)} (plus 'auto')")
    return b


def available_backends() -> Dict[str, Tuple[bool, str]]:
    """name -> (available, reason-if-not) for every registered backend."""
    return {name: b.available() for name, b in sorted(_BACKENDS.items())}


def resolve_backend(spec: RunSpec, g: Optional[Graph] = None, *,
                    mesh=None) -> Backend:
    """Apply the ``backend="auto"`` rules (module doc) to pick a backend."""
    if spec.backend != "auto":
        b = get_backend(spec.backend)
        ok, why = b.supports(g, spec)
        if not ok:
            raise BackendUnavailable(
                f"backend {spec.backend!r} cannot run this spec: {why}")
        return b
    if mesh is None and spec.num_shards <= 1:
        return get_backend("single")
    b = get_backend("mesh")
    ok, _ = b.supports(g, spec)
    if ok:
        return b
    serial = get_backend("serial")
    ok, why = serial.supports(g, spec)
    if not ok:
        # the fallback must also say *why* it cannot run (e.g. registers
        # not divisible by the sim grid) instead of failing mid-build
        raise BackendUnavailable(
            f"no backend can run this spec: mesh unavailable and the "
            f"serial fallback cannot either: {why}")
    return serial


def resolve_residency(spec: RunSpec, backend: Backend) -> str:
    """Apply the ``residency="auto"`` rule: banks live on the mesh exactly
    when the resolved backend runs there (``needs_mesh``) — serving
    reductions then happen where the registers already are — and on the host
    otherwise. An explicit ``"host"``/``"device"`` is honored as-is."""
    if spec.residency != "auto":
        return spec.residency
    return "device" if backend.capabilities().needs_mesh else "host"
