"""Batched influence query engine — the serving analogue of serve/engine.py
for IM traffic.

A request stream of mixed queries is grouped by (store key, query class),
padded into fixed-shape batches (batch size and candidate-set length rounded
up to powers of two so the jit cache stays small), executed under one jit
per query class, and scattered back to per-request results with latency
accounting.

``TopKSeeds`` requests are deduplicated: identical (store, k) requests in a
batch share one execution, and results are memoized against the entry's
``version`` token (bumped by every delta/rebuild), so repeated top-k traffic
against an unchanged index is a dictionary hit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.difuser import DiFuserConfig
from repro.graphs.structs import Graph
from repro.obs import flight, metrics, trace
from repro.obs.slo import SLOConfig, SLOWatchdog
from repro.service import queries as Q
from repro.service.store import SketchStore, StoreEntry, StoreKey


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass(frozen=True)
class Request:
    """One query bound to a store key (assigned by ``InfluenceEngine.submit``)."""

    key: StoreKey
    query: Q.Query


@dataclasses.dataclass
class QueryResult:
    """Per-request result with serving metadata.

    value: float (SpreadEstimate / MarginalGain / CoverageProbe) or
           InfluenceResult (TopKSeeds).
    latency_s: wall time of the batch this request rode in.
    amortized_s: latency_s / batch_size — the per-query serving cost.
    batch_size: number of real requests in the executed batch.
    backend: which lowering served the batch — ``"single:host"`` (canonical
             jitted reductions), ``"mesh:device"`` (shard-local reductions
             on the placed row blocks), or ``"memo"`` (top-k cache hit, no
             execution). Benchmarks report host vs device rows off this.
    cache_hit: True if the result came from the top-k memo (no execution).
    deduped: True if this request shared another identical request's
             execution within the same batch (distinct from a memo hit).
    """

    query: Q.Query
    value: object
    latency_s: float
    amortized_s: float
    batch_size: int
    backend: str = "single:host"
    cache_hit: bool = False
    deduped: bool = False


class InfluenceEngine:
    """Accepts a stream of mixed queries and executes them in padded batches."""

    def __init__(self, store: Optional[SketchStore] = None, max_batch: int = 256,
                 backend=None, spec=None, slo=None):
        # explicit None check: an empty SketchStore is falsy (__len__ == 0)
        # backend/spec (repro.runtime) configure the engine-owned store's
        # build strategy; an explicitly passed store keeps its own
        if store is None:
            store = SketchStore(backend=backend, spec=spec)
        elif backend is not None or spec is not None:
            raise ValueError("pass backend/spec to the SketchStore itself "
                             "when sharing an explicit store")
        self.store = store
        self.max_batch = max_batch
        self._pending: list[Request] = []
        # (store key, k) -> (state token, InfluenceResult); keying tokens in
        # the *value* means a delta/rebuild overwrites instead of stranding
        # old-version entries, so the memo is bounded by distinct (key, k)
        self._topk_memo: dict[tuple, tuple] = {}
        # SLO budgets: explicit `slo` (SLOConfig / {class: p99_ms} mapping /
        # (class, p99_ms) pairs) wins; else inherited from spec.slo. With
        # budgets configured, every batch latency feeds the watchdog and a
        # rising-edge breach dumps the flight ring (Perfetto-loadable
        # post-mortem of the offending window).
        if slo is None and spec is not None:
            slo = getattr(spec, "slo", None)
        cfg = SLOConfig.coerce(slo)
        self.slo = (SLOWatchdog(cfg, on_breach=self._on_slo_breach)
                    if cfg is not None else None)
        # a swap (double-buffered delta/rebuild landing) must retire memoized
        # top-k results for that key immediately — the version token already
        # rejects them on lookup, but dropping eagerly keeps the memo from
        # accumulating dead versions across key churn
        self.store.add_swap_hook(self._on_store_swap)

    def _on_store_swap(self, key, old, new) -> None:
        for mk in [mk for mk in self._topk_memo if mk[0] == key]:
            del self._topk_memo[mk]

    @staticmethod
    def _on_slo_breach(qclass, p99_ms, budget_ms, watchdog) -> None:
        flight.dump(f"slo-breach-{qclass}-p99-{p99_ms:.1f}ms"
                    f"-budget-{budget_ms:.1f}ms")

    def slo_summary(self) -> dict:
        """Per-class SLO state (empty when no budgets are configured) —
        what the perf report's SLO section renders."""
        return self.slo.summary() if self.slo is not None else {}

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def register(self, g: Graph, config: Optional[DiFuserConfig] = None) -> StoreKey:
        """Warm the store for a graph (the one cold build) and return its key."""
        return self.store.get_or_build(g, config).key

    def submit(self, key: StoreKey, query: Q.Query) -> int:
        """Enqueue a query; returns its request index in the next ``run``.

        Unknown keys are rejected here, before enqueueing — a bad key
        surfacing as KeyError mid-``run`` would drop the whole already-
        swapped-out batch, valid requests included."""
        if key not in self.store:
            raise KeyError(f"store key not registered with this engine: {key}")
        self._pending.append(Request(key=key, query=query))
        return len(self._pending) - 1

    def clear_topk_memo(self) -> None:
        """Drop all memoized top-k results (they re-execute on next demand).
        Benchmarks use this to measure genuine warm serving instead of
        0-cost memo hits; deltas/rebuilds invalidate per-entry via the
        version token and don't need it."""
        self._topk_memo.clear()

    # ------------------------------------------------------------------
    # Execution
    # ------------------------------------------------------------------

    def run(self, requests: Optional[Sequence[Request]] = None) -> list[QueryResult]:
        """Execute pending (or explicitly passed) requests; results are
        returned in request order."""
        if requests is None:
            requests, self._pending = self._pending, []
        else:
            # explicitly-passed lists skipped submit()'s guard: reject bad
            # keys up front, before any group executes and gets discarded
            for req in requests:
                if req.key not in self.store:
                    raise KeyError(
                        f"store key not registered with this engine: {req.key}")
        results: list[Optional[QueryResult]] = [None] * len(requests)

        groups: dict[tuple, list[int]] = {}
        for i, req in enumerate(requests):
            groups.setdefault((req.key, type(req.query).__name__), []).append(i)

        try:
            for (key, qname), idxs in groups.items():
                entry = self.store.entry(key)
                for lo in range(0, len(idxs), self.max_batch):
                    self.execute_chunk(entry, requests,
                                       idxs[lo: lo + self.max_batch], results)
        except Exception as e:
            # post-mortem capture: the flight ring holds the spans leading
            # up to the fault; dump never raises, then the fault propagates
            metrics.counter("engine.exceptions",
                            error=type(e).__name__).inc()
            flight.dump(f"engine-exception-{type(e).__name__}")
            raise
        return results  # type: ignore[return-value]

    def __call__(self, key: StoreKey, query: Q.Query) -> QueryResult:
        """Convenience single-query path (batch of one)."""
        return self.run([Request(key=key, query=query)])[0]

    def execute_chunk(self, entry: StoreEntry, requests: Sequence[Request],
                      chunk: Sequence[int], results: list) -> None:
        """Execute one homogeneous chunk (same entry, same query class)
        against a *snapshotted* entry, writing ``QueryResult``s into
        ``results`` at the chunk's indices. This is the unit the async
        scheduler flushes: it takes the entry object rather than the key so
        in-flight batches finish against the version they started with even
        if a double-buffered swap lands mid-execution."""
        qname = type(requests[chunk[0]].query).__name__
        if qname == "TopKSeeds":
            self._run_topk(entry, requests, chunk, results)
        elif qname == "SpreadEstimate":
            self._run_spread(entry, requests, chunk, results)
        elif qname == "MarginalGain":
            self._run_marginal(entry, requests, chunk, results)
        elif qname == "CoverageProbe":
            self._run_probe(entry, requests, chunk, results)
        else:  # pragma: no cover
            raise TypeError(f"unknown query type: {qname}")

    # -- per-class executors ------------------------------------------------

    def _account(self, qclass: str, dt: float, batch: int) -> None:
        """Per-query-class serving metrics: batch latency distribution,
        amortized per-request cost, request count — and the SLO watchdog's
        rolling window when budgets are configured."""
        metrics.counter("engine.requests", query=qclass).inc(batch)
        metrics.histogram("engine.batch_latency_s", unit="s",
                          query=qclass).observe(dt)
        metrics.histogram("engine.amortized_s", unit="s",
                          query=qclass).observe(dt / max(batch, 1))
        if self.slo is not None:
            self.slo.observe(qclass, dt)

    def _pad_sets(self, sets: list[tuple]) -> list[tuple]:
        """Pad the batch dim to a power of two with empty sets (sentinel-only
        rows are inert) so jit specializations stay O(log max_batch)."""
        b = _pow2(len(sets))
        return sets + [()] * (b - len(sets))

    def _run_spread(self, entry, requests, chunk, results):
        sets = self._pad_sets([requests[i].query.candidates for i in chunk])
        length = _pow2(max((len(s) for s in sets), default=1))
        # timed=True: the engine's latency accounting runs whether or not
        # tracing is on; sp.sync makes dt cover device execution, not just
        # dispatch (async-dispatch under-reporting fix)
        with trace.span("engine.spread_batch", phase="query", timed=True,
                        batch=len(chunk)) as sp:
            est = sp.sync(Q.spread_estimates(entry, sets, length))
        dt = sp.duration_s
        self._account("SpreadEstimate", dt, len(chunk))
        for j, i in enumerate(chunk):
            results[i] = QueryResult(requests[i].query, float(est[j]), dt,
                                     dt / len(chunk), len(chunk),
                                     backend=entry.serving_backend)

    def _run_marginal(self, entry, requests, chunk, results):
        sentinel = entry.graph.n_pad - 1
        cands = [requests[i].query.candidate for i in chunk]
        comm = self._pad_sets([requests[i].query.committed for i in chunk])
        length = _pow2(max((len(s) for s in comm), default=1))
        cands = cands + [sentinel] * (len(comm) - len(chunk))
        with trace.span("engine.marginal_batch", phase="query", timed=True,
                        batch=len(chunk)) as sp:
            gains = sp.sync(Q.marginal_gains(entry, cands, comm, length))
        dt = sp.duration_s
        self._account("MarginalGain", dt, len(chunk))
        for j, i in enumerate(chunk):
            results[i] = QueryResult(requests[i].query, float(gains[j]), dt,
                                     dt / len(chunk), len(chunk),
                                     backend=entry.serving_backend)

    def _run_probe(self, entry, requests, chunk, results):
        sentinel = entry.graph.n_pad - 1
        flat: list[int] = []
        spans = []
        for i in chunk:
            vs = requests[i].query.vertices
            spans.append((len(flat), len(vs)))
            flat.extend(vs)
        b = _pow2(max(len(flat), 1))
        flat = flat + [sentinel] * (b - len(flat))
        with trace.span("engine.probe_batch", phase="query", timed=True,
                        batch=len(chunk)) as sp:
            est, max_reg = sp.sync(Q.coverage_probes(entry, flat))
        dt = sp.duration_s
        self._account("CoverageProbe", dt, len(chunk))
        for (off, ln), i in zip(spans, chunk):
            value = {"est": est[off: off + ln].copy(),
                     "max_register": max_reg[off: off + ln].copy()}
            results[i] = QueryResult(requests[i].query, value, dt,
                                     dt / len(chunk), len(chunk),
                                     backend=entry.serving_backend)

    def _run_topk(self, entry, requests, chunk, results):
        # dedupe identical k within the batch; memoize against entry.version
        by_k: dict[int, list[int]] = {}
        for i in chunk:
            by_k.setdefault(requests[i].query.k, []).append(i)
        for k, idxs in by_k.items():
            memo_key = (entry.key, k)
            cached = self._topk_memo.get(memo_key)
            if cached is not None and cached[0] == (entry.version, entry.stale):
                metrics.counter("engine.topk_memo_hits").inc(len(idxs))
                for i in idxs:
                    results[i] = QueryResult(requests[i].query, cached[1], 0.0,
                                             0.0, len(idxs), backend="memo",
                                             cache_hit=True)
                continue
            served_by = entry.serving_backend
            metrics.counter("engine.topk_memo_misses").inc()
            with trace.span("engine.topk_batch", phase="query", timed=True,
                            k=k, batch=len(idxs)) as sp:
                res = sp.sync(Q.top_k_seeds(self.store, entry, k))
            dt = sp.duration_s
            self._account("TopKSeeds", dt, len(idxs))
            # top_k_seeds may have rebuilt a stale entry — store.rebuild
            # mutates in place, so the *executed* entry object carries the
            # bumped token. Memoize under it, not a fresh store lookup: a
            # concurrent swap to N+1 mid-execution must not file version-N
            # results under the N+1 token.
            self._topk_memo[memo_key] = ((entry.version, entry.stale), res)
            for j, i in enumerate(idxs):
                results[i] = QueryResult(requests[i].query, res, dt,
                                         dt / len(idxs), len(idxs),
                                         backend=served_by, deduped=j > 0)


def summarize_latencies(results: Sequence[QueryResult]) -> dict:
    """Aggregate serving stats: p50/p99 per-request latency, amortized cost,
    and the per-backend request counts (``by_backend``: how many requests
    each lowering — host jit, shard-local device, memo — answered)."""
    lat = np.asarray([r.latency_s for r in results], dtype=np.float64)
    amort = np.asarray([r.amortized_s for r in results], dtype=np.float64)
    total = float(amort.sum())
    by_backend: dict[str, int] = {}
    for r in results:
        by_backend[r.backend] = by_backend.get(r.backend, 0) + 1
    return {
        "num_queries": len(results),
        "total_s": total,
        # 0.0, not inf: an empty (or all-memo-hit, total==0) result set has
        # no measured throughput, and inf poisons JSON artifacts + trend math
        "qps": len(results) / total if total > 0 else 0.0,
        "p50_ms": float(np.percentile(lat, 50) * 1e3) if len(results) else 0.0,
        "p99_ms": float(np.percentile(lat, 99) * 1e3) if len(results) else 0.0,
        "amortized_ms": total / len(results) * 1e3 if len(results) else 0.0,
        "cache_hits": sum(1 for r in results if r.cache_hit),
        "deduped": sum(1 for r in results if r.deduped),
        "by_backend": by_backend,
    }
