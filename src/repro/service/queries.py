"""Influence query types and their lowerings to register reductions.

Every query except ``TopKSeeds`` is a pure reduction over the store's
propagated matrix — no propagation, no cascade — using the same sufficient
statistics the distributed selection reduces (sketch.partial_sums /
estimate_from_sums, paper eqs. 6/7 and Fig. 3):

* ``SpreadEstimate(S)``: union the candidate rows (eq. 5 max-merge) and
  finish the estimate — expected IC spread of seed set S.
* ``MarginalGain(c, S)``: spread(S + {c}) - spread(S), two such reductions.
* ``CoverageProbe(V)``: per-vertex singleton spread for each probed vertex
  (the quantity Alg. 4's argmax scans globally, served point-wise).
* ``TopKSeeds(k)``: the full Alg. 4 round loop warm-started from the cached
  matrix (fill + propagate skipped). If deltas left the entry stale, the
  lazy-rebuild check fires first and the rebuilt pristine matrix is written
  back into the store.

Candidate sets are padded with the graph's sentinel vertex (``n_pad - 1``),
whose row is all VISITED (= -1, the bottom of the max lattice), so padding
is inert under the union merge by construction — batches of ragged candidate
sets lower to one fixed-shape jit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch
from repro.core.difuser import InfluenceResult, find_seeds_warm
from repro.service.store import SketchStore, StoreEntry


def _as_tuple(v) -> tuple:
    if isinstance(v, (int, np.integer)):
        return (int(v),)
    return tuple(int(u) for u in np.asarray(v).reshape(-1))


@dataclasses.dataclass(frozen=True)
class TopKSeeds:
    """Greedy top-k seed set (Alg. 4 rounds, warm-started)."""

    k: int


@dataclasses.dataclass(frozen=True)
class SpreadEstimate:
    """Expected IC spread of a fixed candidate seed set."""

    candidates: tuple

    def __init__(self, candidates):
        object.__setattr__(self, "candidates", _as_tuple(candidates))


@dataclasses.dataclass(frozen=True)
class MarginalGain:
    """Expected gain of adding ``candidate`` to ``committed``."""

    candidate: int
    committed: tuple

    def __init__(self, candidate, committed=()):
        object.__setattr__(self, "candidate", int(candidate))
        object.__setattr__(self, "committed", _as_tuple(committed))


@dataclasses.dataclass(frozen=True)
class CoverageProbe:
    """Per-vertex singleton influence estimates for the probed vertices."""

    vertices: tuple

    def __init__(self, vertices):
        object.__setattr__(self, "vertices", _as_tuple(vertices))


Query = Union[TopKSeeds, SpreadEstimate, MarginalGain, CoverageProbe]


# ---------------------------------------------------------------------------
# Jitted batch kernels (one compile per (B, L) bucket)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _spread_batch(m, cands, *, total_regs: int, estimator: str) -> jnp.ndarray:
    """m int8[n_pad, J], cands int32[B, L] (sentinel-padded) -> float32[B]."""
    rows = m[cands]                      # (B, L, J)
    merged = jnp.max(rows, axis=1)       # eq. (5) union; sentinel rows are -1
    sums = sketch.partial_sums(merged, estimator=estimator)  # (2, B)
    return sketch.estimate_from_sums(sums, total_regs, estimator=estimator)


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _marginal_batch(m, cand, committed, *, total_regs: int, estimator: str):
    """cand int32[B], committed int32[B, L] -> (gain, with, without) float32[B]."""
    with_c = jnp.concatenate([committed, cand[:, None]], axis=1)
    est_with = _spread_batch(m, with_c, total_regs=total_regs, estimator=estimator)
    est_without = _spread_batch(m, committed, total_regs=total_regs,
                                estimator=estimator)
    return est_with - est_without, est_with, est_without


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _probe_batch(m, verts, *, total_regs: int, estimator: str):
    """verts int32[B] -> (est float32[B], max_register int32[B])."""
    rows = m[verts]                      # (B, J)
    sums = sketch.partial_sums(rows, estimator=estimator)
    est = sketch.estimate_from_sums(sums, total_regs, estimator=estimator)
    return est, jnp.max(rows, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Lowering helpers (host side)
# ---------------------------------------------------------------------------


def pad_candidate_sets(sets: Sequence[tuple], sentinel: int, length: int) -> np.ndarray:
    """Stack ragged candidate tuples into int32[B, length], sentinel-padded."""
    out = np.full((len(sets), max(length, 1)), sentinel, dtype=np.int32)
    for i, s in enumerate(sets):
        if len(s):
            out[i, : len(s)] = np.asarray(s, dtype=np.int32)
    return out


def spread_estimates(entry: StoreEntry, sets: Sequence[tuple],
                     length: int | None = None) -> np.ndarray:
    """Batch of SpreadEstimate queries against one store entry. ``length``
    overrides the padded set length (the engine rounds it to a power of two
    to bound jit specializations)."""
    if length is None:
        length = max((len(s) for s in sets), default=1)
    cands = pad_candidate_sets(sets, entry.graph.n_pad - 1, length)
    est = _spread_batch(entry.matrix, jnp.asarray(cands),
                        total_regs=entry.x.shape[0], estimator=entry.cfg.estimator)
    return np.asarray(est)


def marginal_gains(entry: StoreEntry, cands: Sequence[int],
                   committed: Sequence[tuple],
                   length: int | None = None) -> np.ndarray:
    if length is None:
        length = max((len(s) for s in committed), default=1)
    comm = pad_candidate_sets(committed, entry.graph.n_pad - 1, length)
    gain, _, _ = _marginal_batch(
        entry.matrix, jnp.asarray(np.asarray(cands, dtype=np.int32)),
        jnp.asarray(comm), total_regs=entry.x.shape[0],
        estimator=entry.cfg.estimator)
    return np.asarray(gain)


def coverage_probes(entry: StoreEntry, verts: Sequence[int]) -> tuple[np.ndarray, np.ndarray]:
    est, max_reg = _probe_batch(
        entry.matrix, jnp.asarray(np.asarray(verts, dtype=np.int32)),
        total_regs=entry.x.shape[0], estimator=entry.cfg.estimator)
    return np.asarray(est), np.asarray(max_reg)


def top_k_seeds(store: SketchStore, entry: StoreEntry, k: int) -> InfluenceResult:
    """Warm-start Alg. 4 from the cached matrix. The lazy-rebuild check: a
    stale entry (edge removals since the last build) is rebuilt pristine
    first and the fresh matrix written back into the store, so this query —
    and every later one — serves from a sound index."""
    if entry.stale:
        entry = store.rebuild(entry.key)
    return find_seeds_warm(entry.graph, k, entry.cfg, matrix=entry.matrix,
                           x=entry.x, edges=entry.device_edges())
