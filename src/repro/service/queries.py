"""Influence query types and their lowerings to register reductions.

Every query except ``TopKSeeds`` is a pure reduction over the store's
propagated matrix — no propagation, no cascade — using the same sufficient
statistics the distributed selection reduces (sketch.partial_sums /
estimate_from_sums, paper eqs. 6/7 and Fig. 3):

* ``SpreadEstimate(S)``: union the candidate rows (eq. 5 max-merge) and
  finish the estimate — expected IC spread of seed set S.
* ``MarginalGain(c, S)``: spread(S + {c}) - spread(S), two such reductions.
* ``CoverageProbe(V)``: per-vertex singleton spread for each probed vertex
  (the quantity Alg. 4's argmax scans globally, served point-wise).
* ``TopKSeeds(k)``: the full Alg. 4 round loop warm-started from the cached
  matrix (fill + propagate skipped). If deltas left the entry stale, the
  lazy-rebuild check fires first and the rebuilt pristine matrix is written
  back into the store.

Candidate sets are padded with the graph's sentinel vertex (``n_pad - 1``),
whose row is all VISITED (= -1, the bottom of the max lattice), so padding
is inert under the union merge by construction — batches of ragged candidate
sets lower to one fixed-shape jit.

Two lowerings per query class, selected by ``StoreEntry.residency``:

* **host** — the historical jitted reductions over the canonical matrix;
* **device** — shard-local partial reductions under ``shard_map`` against
  the plan-order row blocks a :meth:`StoreEntry.place_on_mesh` pinned per
  device: each shard merges the candidate rows it owns (rows it does not
  own contribute VISITED, the bottom of the max lattice) and one ``pmax``
  over the vertex axis combines the partial registers; the estimator then
  runs on the identical merged vector, so device answers are bit-identical
  to host answers (tests/test_sharded_serving.py holds the line). TopKSeeds
  routes through the warm shard_map round loop
  (``core.distributed.find_seeds_warm_distributed``) — same contract.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch
from repro.core.difuser import InfluenceResult, find_seeds_warm
from repro.core.sketch import VISITED
from repro.service.store import SketchStore, StoreEntry


def _as_tuple(v) -> tuple:
    if isinstance(v, (int, np.integer)):
        return (int(v),)
    return tuple(int(u) for u in np.asarray(v).reshape(-1))


@dataclasses.dataclass(frozen=True)
class TopKSeeds:
    """Greedy top-k seed set (Alg. 4 rounds, warm-started)."""

    k: int


@dataclasses.dataclass(frozen=True)
class SpreadEstimate:
    """Expected IC spread of a fixed candidate seed set."""

    candidates: tuple

    def __init__(self, candidates):
        object.__setattr__(self, "candidates", _as_tuple(candidates))


@dataclasses.dataclass(frozen=True)
class MarginalGain:
    """Expected gain of adding ``candidate`` to ``committed``."""

    candidate: int
    committed: tuple

    def __init__(self, candidate, committed=()):
        object.__setattr__(self, "candidate", int(candidate))
        object.__setattr__(self, "committed", _as_tuple(committed))


@dataclasses.dataclass(frozen=True)
class CoverageProbe:
    """Per-vertex singleton influence estimates for the probed vertices."""

    vertices: tuple

    def __init__(self, vertices):
        object.__setattr__(self, "vertices", _as_tuple(vertices))


Query = Union[TopKSeeds, SpreadEstimate, MarginalGain, CoverageProbe]


# ---------------------------------------------------------------------------
# Jitted batch kernels (one compile per (B, L) bucket)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _spread_batch(m, cands, *, total_regs: int, estimator: str) -> jnp.ndarray:
    """m int8[n_pad, J], cands int32[B, L] (sentinel-padded) -> float32[B]."""
    rows = m[cands]                      # (B, L, J)
    merged = jnp.max(rows, axis=1)       # eq. (5) union; sentinel rows are -1
    sums = sketch.partial_sums(merged, estimator=estimator)  # (2, B)
    return sketch.estimate_from_sums(sums, total_regs, estimator=estimator)


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _marginal_batch(m, cand, committed, *, total_regs: int, estimator: str):
    """cand int32[B], committed int32[B, L] -> (gain, with, without) float32[B]."""
    with_c = jnp.concatenate([committed, cand[:, None]], axis=1)
    est_with = _spread_batch(m, with_c, total_regs=total_regs, estimator=estimator)
    est_without = _spread_batch(m, committed, total_regs=total_regs,
                                estimator=estimator)
    return est_with - est_without, est_with, est_without


@partial(jax.jit, static_argnames=("total_regs", "estimator"))
def _probe_batch(m, verts, *, total_regs: int, estimator: str):
    """verts int32[B] -> (est float32[B], max_register int32[B])."""
    rows = m[verts]                      # (B, J)
    sums = sketch.partial_sums(rows, estimator=estimator)
    est = sketch.estimate_from_sums(sums, total_regs, estimator=estimator)
    return est, jnp.max(rows, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Sharded batch kernels (device residency): shard-local partials + one
# pmax combine under shard_map
# ---------------------------------------------------------------------------


def shard_partial_rows(m_loc, rows, row0: int, n_loc: int):
    """The per-shard half of a sharded row gather: of the global plan-order
    ``rows`` requested, return the ones this shard owns (rows ``[row0,
    row0 + n_loc)`` of the planned matrix) and VISITED — the bottom of the
    max lattice, inert under every downstream merge — for the rest.

    Pure function of one shard's block, shared by the ``shard_map`` bodies
    below and the numpy-twin equivalence tests (which combine per-shard
    calls with ``np.maximum`` and must reproduce the host reductions
    bit-for-bit)."""
    local = rows - row0
    owned = jnp.logical_and(local >= 0, local < n_loc)
    safe = jnp.clip(local, 0, n_loc - 1)
    return jnp.where(owned[..., None], m_loc[safe], jnp.int8(VISITED))


# bounded: each slot pins a Mesh + three compiled shard_map executables, and
# multi-tenant serving constructs a fresh serving mesh per placed graph —
# unbounded caching would leak them for process lifetime as graphs turn over
@lru_cache(maxsize=16)
def _sharded_kernels(mesh, vertex_axis: str, n_loc: int, total_regs: int,
                     estimator: str):
    """Jitted shard_map executors for one (mesh, plan geometry, estimator).

    The matrix argument's in_spec matches the ``NamedSharding`` placement of
    a device-resident entry (rows over ``vertex_axis``), so serving consumes
    the banks where they live; candidate arrays are replicated (they are
    O(batch), the registers are O(n)). Each body computes its shard's
    partial row-merge and combines with a single ``pmax`` over the vertex
    axis; the estimator math then sees the exact merged vector the host
    kernels see, making results bit-identical by construction.
    """
    from jax.sharding import PartitionSpec as P

    def _merged(m_loc, rows):
        row0 = jax.lax.axis_index(vertex_axis) * n_loc
        return shard_partial_rows(m_loc, rows, row0, n_loc)

    def _estimate(merged):
        sums = sketch.partial_sums(merged, estimator=estimator)
        return sketch.estimate_from_sums(sums, total_regs, estimator=estimator)

    def spread_body(m_loc, cands):
        part_rows = jnp.max(_merged(m_loc, cands), axis=1)     # (B, J) partial
        return _estimate(jax.lax.pmax(part_rows, vertex_axis))

    def marginal_body(m_loc, cand, committed):
        with_c = jnp.concatenate([committed, cand[:, None]], axis=1)
        est_with = spread_body(m_loc, with_c)
        est_without = spread_body(m_loc, committed)
        return est_with - est_without, est_with, est_without

    def probe_body(m_loc, verts):
        rows = jax.lax.pmax(_merged(m_loc, verts), vertex_axis)  # (B, J)
        return _estimate(rows), jnp.max(rows, axis=-1).astype(jnp.int32)

    m_spec = P(vertex_axis, None)

    def _wrap(body, n_rep, out_specs):
        return jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(m_spec,) + (P(),) * n_rep,
            out_specs=out_specs, check_vma=False))

    return {"spread": _wrap(spread_body, 1, P()),
            "marginal": _wrap(marginal_body, 2, (P(), P(), P())),
            "probe": _wrap(probe_body, 1, (P(), P()))}


def _entry_kernels(entry: StoreEntry):
    return _sharded_kernels(entry.mesh, entry.vertex_axis, entry.plan.n_loc,
                            int(entry.x.shape[0]), entry.cfg.estimator)


def _plan_rows(entry: StoreEntry, ids: np.ndarray) -> np.ndarray:
    """Original vertex ids -> plan-order row indices (host side, O(batch)).
    The sentinel (``graph.n_pad - 1``) maps to a padding row that is VISITED
    everywhere in the planned layout, so sentinel inertness carries over."""
    return entry.plan.perm[np.asarray(ids, dtype=np.int64)].astype(np.int32)


# ---------------------------------------------------------------------------
# Lowering helpers (host side)
# ---------------------------------------------------------------------------


def pad_candidate_sets(sets: Sequence[tuple], sentinel: int, length: int) -> np.ndarray:
    """Stack ragged candidate tuples into int32[B, length], sentinel-padded."""
    out = np.full((len(sets), max(length, 1)), sentinel, dtype=np.int32)
    for i, s in enumerate(sets):
        if len(s):
            out[i, : len(s)] = np.asarray(s, dtype=np.int32)
    return out


def spread_estimates(entry: StoreEntry, sets: Sequence[tuple],
                     length: int | None = None) -> np.ndarray:
    """Batch of SpreadEstimate queries against one store entry. ``length``
    overrides the padded set length (the engine rounds it to a power of two
    to bound jit specializations). Device-resident entries serve the
    shard-local lowering; host entries the canonical jit — bit-identical."""
    if length is None:
        length = max((len(s) for s in sets), default=1)
    cands = pad_candidate_sets(sets, entry.graph.n_pad - 1, length)
    if entry.residency == "device":
        est = _entry_kernels(entry)["spread"](
            entry.planned_matrix(), jnp.asarray(_plan_rows(entry, cands)))
    else:
        est = _spread_batch(entry.matrix, jnp.asarray(cands),
                            total_regs=entry.x.shape[0],
                            estimator=entry.cfg.estimator)
    return np.asarray(est)


def marginal_gains(entry: StoreEntry, cands: Sequence[int],
                   committed: Sequence[tuple],
                   length: int | None = None) -> np.ndarray:
    if length is None:
        length = max((len(s) for s in committed), default=1)
    comm = pad_candidate_sets(committed, entry.graph.n_pad - 1, length)
    cands = np.asarray(cands, dtype=np.int32)
    if entry.residency == "device":
        gain, _, _ = _entry_kernels(entry)["marginal"](
            entry.planned_matrix(), jnp.asarray(_plan_rows(entry, cands)),
            jnp.asarray(_plan_rows(entry, comm)))
    else:
        gain, _, _ = _marginal_batch(
            entry.matrix, jnp.asarray(cands), jnp.asarray(comm),
            total_regs=entry.x.shape[0], estimator=entry.cfg.estimator)
    return np.asarray(gain)


def coverage_probes(entry: StoreEntry, verts: Sequence[int]) -> tuple[np.ndarray, np.ndarray]:
    verts = np.asarray(verts, dtype=np.int32)
    if entry.residency == "device":
        est, max_reg = _entry_kernels(entry)["probe"](
            entry.planned_matrix(), jnp.asarray(_plan_rows(entry, verts)))
    else:
        est, max_reg = _probe_batch(
            entry.matrix, jnp.asarray(verts),
            total_regs=entry.x.shape[0], estimator=entry.cfg.estimator)
    return np.asarray(est), np.asarray(max_reg)


def top_k_seeds(store: SketchStore, entry: StoreEntry, k: int) -> InfluenceResult:
    """Warm-start Alg. 4 from the cached matrix. The lazy-rebuild check: a
    stale entry (edge removals since the last build) is rebuilt pristine
    first and the fresh matrix written back into the store, so this query —
    and every later one — serves from a sound index. Device-resident entries
    run the K rounds under shard_map straight off the placed row blocks."""
    if entry.stale:
        entry = store.rebuild(entry.key)
    if entry.residency == "device":
        from repro.core.distributed import (_partition_for_plan,
                                            find_seeds_warm_distributed)
        from repro.runtime.spec import RunSpec

        sim_axes = tuple(ax for ax in entry.mesh.axis_names
                         if ax != entry.vertex_axis)
        dcfg = RunSpec.from_config(
            entry.cfg, vertex_axis=entry.vertex_axis,
            sim_axes=sim_axes).distributed_config()
        # the bucket partition is the cold-build-grade host cost of this
        # path — cache it against the version so warm top-k pays it once
        # per (graph, plan) state, not once per query
        if (entry._serving_part_cache is None
                or entry._serving_part_cache[0] != entry.version):
            entry._serving_part_cache = (entry.version, _partition_for_plan(
                entry.graph, entry.mesh, dcfg, entry.x, entry.plan))
        return find_seeds_warm_distributed(
            entry.graph, k, entry.mesh, dcfg, entry.planned_matrix(),
            entry.plan, entry.x, part=entry._serving_part_cache[1])
    return find_seeds_warm(entry.graph, k, entry.cfg, matrix=entry.matrix,
                           x=entry.x, edges=entry.device_edges())
