"""Cost-aware eviction for multi-graph tenancy.

Every resident :class:`~repro.service.store.StoreEntry` pins its register
banks on device; with many graphs resident the store needs a budget. The
evictor keeps ``store.resident_bytes()`` under ``budget_bytes`` by dropping
the entries that are cheapest to lose:

    score = rebuild_cost × recency ÷ device_bytes

* **rebuild_cost** — the entry's measured ``build_time_s`` (what a future
  touch pays to bring it back; the store keeps an
  :class:`~repro.service.store.EvictionRecipe` so the rebuild is
  transparent).
* **recency** — ``1 / (1 + age_s)`` since the last touch: hot entries are
  worth keeping, cold ones approach score 0.
* **device_bytes** — the bank footprint: big entries buy back more budget
  per eviction.

Lowest score goes first. Entries the store refuses to evict are skipped:
*stale* entries (their over-approximating matrix is history-dependent — a
pristine rebuild would change answers, violating the async≡sync contract)
and *device-placed* entries (mesh state the recipe cannot re-derive), plus
any key the caller protects (e.g. keys with queries in flight, to avoid
evict/rebuild thrash within one tick).
"""
from __future__ import annotations

import time
from typing import Iterable, Optional

from repro.obs import metrics
from repro.service.store import SketchStore, StoreKey


class CostAwareEvictor:
    """Keep a store's resident device bytes under a budget."""

    def __init__(self, budget_bytes: int, clock=time.monotonic):
        self.budget_bytes = int(budget_bytes)
        self._clock = clock
        self._last_touch: dict[StoreKey, float] = {}

    def touch(self, key: StoreKey, now: Optional[float] = None) -> None:
        """Record demand for a key (every submit/serve against it)."""
        self._last_touch[key] = self._clock() if now is None else now

    def score(self, entry, now: Optional[float] = None) -> float:
        """Keep-value of an entry: high = expensive to lose. The enforce
        loop evicts ascending."""
        now = self._clock() if now is None else now
        age_s = max(now - self._last_touch.get(entry.key, 0.0), 0.0)
        recency = 1.0 / (1.0 + age_s)
        return (max(entry.build_time_s, 1e-9) * recency
                / max(entry.device_bytes(), 1))

    def evictable(self, entry) -> bool:
        return not entry.stale and entry.residency != "device"

    def enforce(self, store: SketchStore,
                protect: Iterable[StoreKey] = ()) -> list[StoreKey]:
        """Evict lowest-score entries until the store fits the budget (or
        nothing evictable remains). Returns the evicted keys."""
        protected = set(protect)
        evicted: list[StoreKey] = []
        while store.resident_bytes() > self.budget_bytes:
            now = self._clock()
            candidates = [e for e in (store.entry(k)
                                      for k in store.resident_keys())
                          if e.key not in protected and self.evictable(e)]
            if not candidates:
                break
            victim = min(candidates, key=lambda e: self.score(e, now))
            store.evict(victim.key)
            evicted.append(victim.key)
        over = store.resident_bytes() - self.budget_bytes
        metrics.gauge("evictor.over_budget_bytes").set(float(max(over, 0)))
        return evicted
