"""Async influence serving: admission queue + overlapped mutation + tenancy.

The synchronous :class:`~repro.service.engine.InfluenceEngine` batches then
blocks: a cold bank build stalls every query behind it, and the store grows
without bound. This module is the production admission path in front of it:

* **Deadline-driven micro-batching** — ``submit`` returns a ``Future``
  immediately; a :class:`~repro.service.scheduler.MicroBatchScheduler`
  coalesces compatible requests per ``(store key, query class)`` and the
  serve thread flushes each bucket when it fills or its flush window (a
  quarter of the e2e deadline by default) expires.
* **Overlapped builds and repairs** — ``register_async`` /
  ``apply_delta_async`` / ``rebuild_async`` run on a dedicated mutation
  thread against a :meth:`SketchStore.shadow` double buffer: queries keep
  serving version N off the resident entry while N+1 propagates in the
  shadow; :meth:`SketchStore.swap_entry` installs it atomically. In-flight
  batches snapshotted entry N and finish against it.
* **Cost-aware eviction** — with a device budget configured, a
  :class:`~repro.service.eviction.CostAwareEvictor` keeps resident bytes
  under it; evicted entries rebuild transparently on next touch.
* **Cross-entry dispatch** — SpreadEstimate buckets against *different*
  host-resident graphs with the same register geometry are concatenated
  (row-offset) into one device round-trip.

The async layer reorders work but never changes it: every result is
bit-identical to what the synchronous engine returns for the same query
against the same entry version (tests/test_async_service.py holds the
line). Observability: queue-depth gauge + timeline, deadline-miss
counters, an SLO watchdog on end-to-end latency, and a flight-recorder
dump on admission stalls.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.difuser import DiFuserConfig
from repro.graphs.structs import Graph, GraphDelta
from repro.obs import flight, metrics, trace
from repro.obs.slo import SLOConfig, SLOWatchdog
from repro.service.delta import apply_delta
from repro.service.engine import InfluenceEngine, QueryResult, Request, _pow2
from repro.service.eviction import CostAwareEvictor
from repro.service.scheduler import AsyncRequest, MicroBatchScheduler
from repro.service.store import SketchStore, StoreEntry, StoreKey


@dataclasses.dataclass
class _Mutation:
    kind: str            # "build" | "repair" | "rebuild"
    label: str           # span attribute (graph key or "")
    fn: object
    future: Future
    on_done: object = None   # called under the engine lock after fn


class AsyncInfluenceEngine:
    """Future-returning admission front for an :class:`InfluenceEngine`."""

    def __init__(self, engine: Optional[InfluenceEngine] = None, *,
                 store: Optional[SketchStore] = None, max_batch: int = 256,
                 deadline_ms: Optional[float] = None,
                 flush_window_s: Optional[float] = None,
                 max_resident_mb: Optional[float] = None,
                 backend=None, spec=None, slo=None):
        if engine is None:
            engine = InfluenceEngine(store=store, max_batch=max_batch,
                                     backend=backend, spec=spec, slo=slo)
        self.engine = engine
        self.store = engine.store
        # RunSpec async knobs are the defaults; explicit kwargs win
        if deadline_ms is None:
            deadline_ms = float(getattr(spec, "deadline_ms", 0.0) or 0.0) or 50.0
        if max_resident_mb is None:
            max_resident_mb = float(getattr(spec, "max_resident_mb", 0.0) or 0.0)
        self.deadline_ms = float(deadline_ms)
        if flush_window_s is None:
            flush_window_s = self.deadline_ms / 4.0 / 1e3
        self._sched = MicroBatchScheduler(max_batch=max_batch,
                                          flush_window_s=flush_window_s)
        self.evictor = (CostAwareEvictor(int(max_resident_mb * 2**20))
                        if max_resident_mb and max_resident_mb > 0 else None)
        self._watchdog = SLOWatchdog(SLOConfig.coerce({"e2e": self.deadline_ms}),
                                     on_breach=self._on_e2e_breach)

        self._cv = threading.Condition()
        self._mut_q: collections.deque[_Mutation] = collections.deque()
        self._rebuilding: set[StoreKey] = set()
        self._outstanding = 0          # unresolved futures (queries + mutations)
        self._closed = False
        self._stalled = False
        self._concat_cache: Optional[tuple] = None  # (signature, concat matrix)

        # admission telemetry (admission_summary() / obs report "Admission")
        self._t0 = time.monotonic()
        self._depth_timeline: collections.deque = collections.deque(maxlen=4096)
        self._e2e_s: collections.deque = collections.deque(maxlen=200_000)
        self._completed = 0
        self._misses = 0
        self._flushes = 0
        self._cross_batches = 0
        self._stall_dumps = 0

        self._serve_thread = threading.Thread(
            target=self._serve_loop, name="im-serve", daemon=True)
        self._mut_thread = threading.Thread(
            target=self._mutate_loop, name="im-mutate", daemon=True)
        self._serve_thread.start()
        self._mut_thread.start()

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def submit(self, key: StoreKey, query, *,
               deadline_ms: Optional[float] = None) -> Future:
        """Enqueue a query; resolves to the same :class:`QueryResult` the
        sync engine would return. Rejects unknown keys up front (evicted
        keys are known — they rebuild transparently at flush time)."""
        if key not in self.store:
            raise KeyError(f"store key not registered with this engine: {key}")
        dl = self.deadline_ms if deadline_ms is None else float(deadline_ms)
        fut: Future = Future()
        now = time.monotonic()
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncInfluenceEngine is closed")
            req = self._sched.make_request(
                key, query, fut, now,
                deadline_t=(now + dl / 1e3) if dl > 0 else None)
            self._outstanding += 1
            full = self._sched.offer(req)
            depth = self._sched.depth()
            self._record_depth(depth)
            # wake the serve thread only when it could act sooner than its
            # scheduled timeout: the bucket just filled, or the queue was
            # empty (indefinite wait). Any other pending bucket already has
            # an earlier-or-equal flush deadline driving the timeout.
            if full or depth == 1:
                self._cv.notify_all()
        if self.evictor is not None:
            self.evictor.touch(key)
        return fut

    def register_async(self, g: Graph,
                       config: Optional[DiFuserConfig] = None) -> Future:
        """Cold-admit a graph: the bank build runs on the mutation thread
        (serving continues) and the future resolves to the StoreKey."""
        def fn():
            entry = self.store.get_or_build(g, config)
            if self.evictor is not None:
                self.evictor.touch(entry.key)
            return entry.key
        return self._submit_mutation(_Mutation(
            "build", g.content_key()[:12], fn, Future()))

    def apply_delta_async(self, key: StoreKey, delta: GraphDelta,
                          **kwargs) -> Future:
        """Double-buffered delta repair: propagate into a shadow clone of
        the entry, then atomically swap version N+1 in. Resolves to the
        DeltaReport."""
        def fn():
            shadow = self.store.shadow(key)
            rep = apply_delta(shadow, key, delta, **kwargs)
            self._before_swap(key)
            self.store.swap_entry(key, shadow.entry(key))
            return rep
        return self._submit_mutation(_Mutation(
            "repair", key.graph_key[:12], fn, Future()))

    def rebuild_async(self, key: StoreKey, *, _on_done=None) -> Future:
        """Double-buffered pristine rebuild (shadow build → swap)."""
        def fn():
            shadow = self.store.shadow(key)
            entry = shadow.rebuild(key)
            self._before_swap(key)
            self.store.swap_entry(key, entry)
            return entry
        return self._submit_mutation(_Mutation(
            "rebuild", key.graph_key[:12], fn, Future(), on_done=_on_done))

    def _before_swap(self, key: StoreKey) -> None:
        """Test hook: runs on the mutation thread after the shadow is ready
        and immediately before the swap — tests override it to submit (and
        resolve) queries mid-build, proving serving overlapped the build."""

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    def drain(self, timeout_s: float = 300.0) -> None:
        """Block until every submitted future (queries + mutations) has
        resolved."""
        deadline = time.monotonic() + timeout_s
        with self._cv:
            while self._outstanding > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"{self._outstanding} requests still outstanding")
                self._cv.wait(timeout=min(remaining, 0.05))

    def close(self, timeout_s: float = 60.0) -> None:
        """Stop both threads; queued work is flushed on the way out."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        self._serve_thread.join(timeout=timeout_s)
        self._mut_thread.join(timeout=timeout_s)

    def __enter__(self) -> "AsyncInfluenceEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # Serve thread
    # ------------------------------------------------------------------

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                batches = self._sched.take_due(time.monotonic())
                while not batches and not self._closed:
                    nxt = self._sched.next_flush_t()
                    now = time.monotonic()
                    self._cv.wait(timeout=None if nxt is None
                                  else max(nxt - now, 1e-4))
                    batches = self._sched.take_due(time.monotonic())
                if not batches and self._closed:
                    batches = self._sched.take_all()
                    if not batches and not self._mut_q:
                        return
                self._record_depth(self._sched.depth())
                stall_s = self._sched.oldest_wait_s(time.monotonic())
            self._check_stall(stall_s)
            if batches:
                self._execute_flush(batches)

    def _execute_flush(self, batches: list) -> None:
        runnable: list[tuple[StoreEntry, list[AsyncRequest]]] = []
        for bucket in batches:
            key, qclass = bucket[0].key, bucket[0].qclass
            try:
                entry = self.store.entry(key)  # transparent evicted rebuild
            except Exception as e:  # noqa: BLE001 — fail the bucket only
                self._fail_bucket(bucket, e)
                continue
            if self.evictor is not None:
                self.evictor.touch(key)
            if qclass == "TopKSeeds" and entry.stale and not self._closed:
                # don't block the serve thread on a full rebuild: kick it
                # to the mutation thread, park the bucket until the swap
                self._rebuild_and_hold(key, bucket)
                continue
            runnable.append((entry, bucket))

        runnable = self._dispatch_cross_entry(runnable)
        for entry, bucket in runnable:
            try:
                self._run_bucket(entry, bucket)
            except Exception as e:  # noqa: BLE001
                self._fail_bucket(bucket, e)
        if self.evictor is not None:
            protect = {r.key for _, b in runnable for r in b}
            try:
                self.evictor.enforce(self.store, protect=protect)
            except Exception:  # noqa: BLE001 — budget pressure must not
                pass           # fail serving

    def _run_bucket(self, entry: StoreEntry,
                    bucket: Sequence[AsyncRequest]) -> None:
        reqs = [Request(key=r.key, query=r.query) for r in bucket]
        results: list = [None] * len(bucket)
        t0 = time.monotonic()
        for lo in range(0, len(bucket), self.engine.max_batch):
            idxs = list(range(lo, min(lo + self.engine.max_batch, len(bucket))))
            self.engine.execute_chunk(entry, reqs, idxs, results)
        now = time.monotonic()
        metrics.counter("async.flushes", query=bucket[0].qclass).inc()
        self._flushes += 1
        for r, res in zip(bucket, results):
            self._finish(r, res, now)
        self._done(len(bucket))

    def _rebuild_and_hold(self, key: StoreKey,
                          bucket: Sequence[AsyncRequest]) -> None:
        with self._cv:
            self._sched.hold(key, "TopKSeeds")
            self._sched.requeue(bucket)
            already = key in self._rebuilding
            if not already:
                self._rebuilding.add(key)
        if already:
            return
        metrics.counter("async.stale_rebuilds").inc()

        def on_done():   # runs under the engine lock when the swap lands
            self._rebuilding.discard(key)
            self._sched.release(key, "TopKSeeds")
        self.rebuild_async(key, _on_done=on_done)

    # ------------------------------------------------------------------
    # Cross-entry dispatch
    # ------------------------------------------------------------------

    def _dispatch_cross_entry(self, runnable: list) -> list:
        """Merge SpreadEstimate buckets against different host-resident
        entries with identical register geometry (same J, same estimator)
        into one concatenated device round-trip. Returns the buckets left
        for per-entry execution."""
        by_sig: dict[tuple, list] = {}
        rest: list = []
        for entry, bucket in runnable:
            if (bucket[0].qclass == "SpreadEstimate"
                    and entry.residency == "host"):
                sig = (int(entry.x.shape[0]), entry.cfg.estimator)
                by_sig.setdefault(sig, []).append((entry, bucket))
            else:
                rest.append((entry, bucket))
        for groups in by_sig.values():
            if len(groups) < 2:       # one entry — the plain path is the
                rest.extend(groups)   # same round-trip count
                continue
            try:
                self._run_cross_spread(groups)
            except Exception as e:  # noqa: BLE001
                for _, bucket in groups:
                    self._fail_bucket(bucket, e)
        return rest

    def _run_cross_spread(self, groups: list) -> None:
        from repro.service.queries import _spread_batch
        total_regs = int(groups[0][0].x.shape[0])
        estimator = groups[0][0].cfg.estimator
        # stable order so the concat-matrix cache key is deterministic
        groups = sorted(groups,
                        key=lambda g: dataclasses.astuple(g[0].key))
        sig = tuple((dataclasses.astuple(e.key), e.version) for e, _ in groups)
        if self._concat_cache is None or self._concat_cache[0] != sig:
            self._concat_cache = (sig, jnp.concatenate(
                [e.matrix for e, _ in groups], axis=0))
        mat = self._concat_cache[1]

        rows: list[tuple] = []
        sentinels: list[int] = []
        flat: list[AsyncRequest] = []
        off = 0
        for entry, bucket in groups:
            sent = entry.graph.n_pad - 1 + off
            for r in bucket:
                rows.append(tuple(v + off for v in r.query.candidates))
                sentinels.append(sent)
                flat.append(r)
            off += int(entry.graph.n_pad)

        b = _pow2(len(rows))
        length = _pow2(max((len(c) for c in rows), default=1))
        # per-row sentinel padding: each row pads with *its own* entry's
        # sentinel row (all-VISITED in its block), so the merged registers
        # are exactly the single-entry batch's — bit-identical values
        arr = np.empty((b, length), dtype=np.int32)
        for i in range(b):
            arr[i, :] = sentinels[i] if i < len(rows) else sentinels[0]
            if i < len(rows) and rows[i]:
                arr[i, : len(rows[i])] = rows[i]
        with trace.span("async.cross_spread", phase="query", timed=True,
                        batch=len(rows), entries=len(groups)) as sp:
            vals = sp.sync(_spread_batch(mat, jnp.asarray(arr),
                                         total_regs=total_regs,
                                         estimator=estimator))
        dt = sp.duration_s
        vals = np.asarray(vals)
        metrics.counter("engine.cross_entry_batches").inc()
        self._cross_batches += 1
        self.engine._account("SpreadEstimate", dt, len(flat))
        now = time.monotonic()
        for i, r in enumerate(flat):
            self._finish(r, QueryResult(r.query, float(vals[i]), dt,
                                        dt / len(flat), len(flat),
                                        backend="cross:host"), now)
        self._done(len(flat))

    # ------------------------------------------------------------------
    # Mutation thread
    # ------------------------------------------------------------------

    def _submit_mutation(self, mut: _Mutation) -> Future:
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncInfluenceEngine is closed")
            self._outstanding += 1
            self._mut_q.append(mut)
            self._cv.notify_all()
        return mut.future

    def _mutate_loop(self) -> None:
        while True:
            with self._cv:
                while not self._mut_q and not self._closed:
                    self._cv.wait()
                if not self._mut_q:
                    return
                mut = self._mut_q.popleft()
            try:
                with trace.span(f"async.{mut.kind}", phase="service",
                                timed=True, key=mut.label):
                    res = mut.fn()
                mut.future.set_result(res)
            except Exception as e:  # noqa: BLE001
                metrics.counter("async.mutation_errors", kind=mut.kind).inc()
                mut.future.set_exception(e)
            if self.evictor is not None:
                try:
                    self.evictor.enforce(self.store)
                except Exception:  # noqa: BLE001
                    pass
            with self._cv:
                if mut.on_done is not None:
                    try:
                        mut.on_done()
                    except Exception:  # noqa: BLE001
                        pass
                self._outstanding -= 1
                self._cv.notify_all()

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------

    def _finish(self, req: AsyncRequest, result: QueryResult,
                now: float) -> None:
        e2e = now - req.enqueue_t
        self._e2e_s.append(e2e)
        self._completed += 1
        metrics.histogram("async.e2e_s", unit="s",
                          query=req.qclass).observe(e2e)
        if req.deadline_t is not None and now > req.deadline_t:
            self._misses += 1
            metrics.counter("async.deadline_misses", query=req.qclass).inc()
        self._watchdog.observe("e2e", e2e)
        req.future.set_result(result)

    def _fail_bucket(self, bucket: Sequence[AsyncRequest], exc) -> None:
        for r in bucket:
            r.future.set_exception(exc)
        self._done(len(bucket))

    def _done(self, n: int) -> None:
        with self._cv:
            self._outstanding -= n
            self._cv.notify_all()

    def _record_depth(self, depth: int) -> None:
        metrics.gauge("async.queue_depth").set(float(depth))
        self._depth_timeline.append((time.monotonic() - self._t0, depth))

    def _check_stall(self, oldest_wait_s: float) -> None:
        """Rising-edge admission-stall detector: the oldest queued request
        waiting far past the deadline means flushes stopped keeping up —
        dump the flight ring once per episode for the post-mortem."""
        thresh = max(10.0 * self.deadline_ms / 1e3, 1.0)
        if oldest_wait_s > thresh:
            if not self._stalled:
                self._stalled = True
                self._stall_dumps += 1
                metrics.counter("async.admission_stalls").inc()
                flight.dump(f"admission-stall-{oldest_wait_s * 1e3:.0f}ms")
        else:
            self._stalled = False

    @staticmethod
    def _on_e2e_breach(qclass, p99_ms, budget_ms, watchdog) -> None:
        flight.dump(f"async-e2e-p99-{p99_ms:.1f}ms-budget-{budget_ms:.1f}ms")

    def admission_summary(self) -> dict:
        """Queue/deadline/tenancy state for the perf report's Admission
        section and the throughput benchmark's async blob."""
        e2e = np.asarray(self._e2e_s, dtype=np.float64)
        pct = (lambda q: float(np.percentile(e2e, q) * 1e3)) if len(e2e) \
            else (lambda q: 0.0)
        return {
            "completed": self._completed,
            "deadline_ms": self.deadline_ms,
            "deadline_misses": self._misses,
            "deadline_miss_rate": (self._misses / self._completed
                                   if self._completed else 0.0),
            "e2e_p50_ms": pct(50),
            "e2e_p95_ms": pct(95),
            "e2e_p99_ms": pct(99),
            "flushes": self._flushes,
            "cross_entry_batches": self._cross_batches,
            "admission_stalls": self._stall_dumps,
            "queue_depth_timeline": [(round(t, 4), d)
                                     for t, d in self._depth_timeline],
            "resident_bytes": self.store.resident_bytes(),
            "budget_bytes": (self.evictor.budget_bytes
                             if self.evictor is not None else None),
            "slo": self._watchdog.summary(),
        }
