"""Persistent sketch store — the index half of the influence query service.

DiFuseR's expensive step is building the FM register matrix to fixpoint
(paper Alg. 1 + Alg. 4 lines 3-6); everything downstream — top-k selection,
spread estimation, marginal gains — is cheap register reductions over it.
The ``SketchStore`` runs that build exactly once per (graph, diffusion
setting, seed) key, keeps the resulting ``int8[n_pad, J]`` matrix
device-resident, and hands queries a warm matrix instead of a cold start.

Register banks: the sample space can be split into ``num_banks`` contiguous
chunks of the FASST-sorted X vector (the same partition core/fasst.py gives
each device in the distributed runtime). Bank ``b`` fills register slots
``[b*J_loc, (b+1)*J_loc)`` so propagation per bank is column-independent and
the concatenation of the banks is bit-identical to one monolithic build —
banks are purely a residency/sharding choice (per-bank eviction, per-bank
delta repair, future per-device placement).
"""
from __future__ import annotations

import copy
import dataclasses
import math
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.difuser import (DiFuserConfig, edge_operands,
                                normalize_inputs, normalize_x)
from repro.diffusion import DEFAULT_MODEL
from repro.graphs.structs import Graph
from repro.obs import metrics, trace
from repro.partition import PartitionPlan


@dataclasses.dataclass(frozen=True)
class StoreKey:
    """Identity of one cached index: graph content + the full diffusion
    setting (every DiFuserConfig field that affects results — two configs
    differing in any of them must not share a matrix). ``model`` is the
    diffusion model spec, so one engine serves mixed-model traffic against
    the same graph through distinct keys."""

    graph_key: str
    num_registers: int
    seed: int
    estimator: str
    impl: str
    sort_x: bool
    rebuild_threshold: float
    max_propagate_iters: int
    max_cascade_iters: int
    edge_chunk: int
    model: str = DEFAULT_MODEL

    @staticmethod
    def for_graph(g: Graph, cfg: DiFuserConfig) -> "StoreKey":
        return StoreKey(graph_key=g.content_key(), num_registers=cfg.num_registers,
                        seed=cfg.seed, estimator=cfg.estimator, impl=cfg.impl,
                        sort_x=cfg.sort_x, rebuild_threshold=cfg.rebuild_threshold,
                        max_propagate_iters=cfg.max_propagate_iters,
                        max_cascade_iters=cfg.max_cascade_iters,
                        edge_chunk=cfg.edge_chunk, model=cfg.model)


@dataclasses.dataclass
class StoreEntry:
    """One resident index. ``banks[b]`` is int8[n_pad, J/num_banks] on device.

    Residency: ``"host"`` keeps the banks in canonical (original-id) row
    order on the default device — the historical single/serial layout.
    ``"device"`` (via :meth:`place_on_mesh`) keeps each bank's rows in the
    partition plan's order, placed as row blocks across a mesh with
    ``NamedSharding`` (shard ``v`` of the plan owns the device holding rows
    ``[v*n_loc, (v+1)*n_loc)``): ``planned_matrix()`` then IS the resident
    array, shard-local query reductions serve off it without a gather, and
    ``matrix`` becomes the gather-to-host fallback behind the same API.
    """

    key: StoreKey
    graph: Graph                 # dst-sorted serving layout
    cfg: DiFuserConfig
    x: np.ndarray                # uint32[J], FASST-sorted iff cfg.sort_x
    banks: list                  # list[jnp int8[n_pad, J_loc]]
    build_iters: int
    build_time_s: float
    version: int = 0             # bumped by every delta / rebuild (cache token)
    stale: bool = False          # removals applied but matrix not yet rebuilt
    staleness_frac: float = 0.0  # removed-edge fraction since last rebuild
    rebuilds: int = 0
    evictions: int = 0           # times this index was evicted + rebuilt
    plan: Optional[PartitionPlan] = None   # vertex-shard plan (mesh residency)
    residency: str = "host"      # "host" | "device" (row order of banks)
    mesh: Optional[object] = None          # jax Mesh of a device-placed entry
    vertex_axis: str = "data"              # mesh axis the row blocks shard on
    _matrix_cache: Optional[tuple] = None  # (version, concatenated matrix)
    _edges_cache: Optional[tuple] = None   # (version, (src, dst, h, lo, thr) device)
    _planned_cache: Optional[tuple] = None  # (version, plan-row-order matrix)
    _serving_part_cache: Optional[tuple] = None  # (version, Partition2D) —
    #   the bucketed partition the device-resident warm TopKSeeds sweeps;
    #   its O(m * mu_s) host build is the dominant warm-serving cost, so it
    #   is cached like the edge operands (deltas bump the version)

    @property
    def num_banks(self) -> int:
        return len(self.banks)

    @property
    def regs_per_bank(self) -> int:
        return self.x.shape[0] // len(self.banks)

    @property
    def serving_backend(self) -> str:
        """Which execution path answers queries against this entry —
        ``"mesh:device"`` (shard-local reductions on the placed banks) or
        ``"single:host"`` (jitted reductions on the canonical matrix).
        Recorded per batch in :class:`~repro.service.engine.QueryResult`."""
        return "mesh:device" if self.residency == "device" else "single:host"

    def device_bytes(self) -> int:
        """Device footprint of the resident banks (the eviction currency —
        the caches are derived and droppable, the banks are the index)."""
        return int(sum(int(getattr(b, "nbytes", 0) or np.asarray(b).nbytes)
                       for b in self.banks))

    def clone_for_update(self) -> "StoreEntry":
        """Shallow clone for double-buffered mutation: shares the immutable
        payloads (graph, x, device bank arrays) but owns its banks *list*
        and starts with cold derived caches, so repairs/rebuilds against the
        clone never touch the serving copy. The clone is version N until the
        mutation bumps it; :meth:`SketchStore.swap_entry` installs it as
        N+1 atomically."""
        c = copy.copy(self)
        c.banks = list(self.banks)
        c._matrix_cache = c._edges_cache = None
        c._planned_cache = c._serving_part_cache = None
        return c

    @property
    def matrix(self) -> jnp.ndarray:
        """Full int8[n_pad, J] register matrix in canonical (original-id) row
        order — the host-order serving layout.

        The concatenation is cached against ``version`` so multi-bank entries
        don't repeat the O(n_pad * J) device copy on every query batch; every
        banks mutation (rebuild, delta, set_matrix) bumps the version. On a
        device-resident entry this is the *gather* fallback: the plan-order
        row blocks are un-permuted back to canonical order (shard-local
        serving never calls it).
        """
        if self.residency == "device":
            if self._matrix_cache is None or self._matrix_cache[0] != self.version:
                perm = jnp.asarray(self.plan.perm[: self.graph.n_pad])
                self._matrix_cache = (self.version, self.planned_matrix()[perm])
            return self._matrix_cache[1]
        if len(self.banks) == 1:
            return self.banks[0]
        if self._matrix_cache is None or self._matrix_cache[0] != self.version:
            self._matrix_cache = (self.version, jnp.concatenate(self.banks, axis=1))
        return self._matrix_cache[1]

    def device_edges(self) -> tuple:
        """Device-resident (src, dst, h, lo, thr) fused-predicate operands of
        the serving graph under the entry's diffusion model, cached against
        ``version`` — warm TopKSeeds skips the per-query host sort, model
        preprocessing, and re-upload (the graph only changes via deltas,
        which bump it)."""
        if self._edges_cache is None or self._edges_cache[0] != self.version:
            self.prime_edges_cache()
        return self._edges_cache[1]

    def prime_edges_cache(self, edges: Optional[tuple] = None) -> tuple:
        """Install ``(src, dst, h, lo, thr)`` device operands for the entry's
        *current* (graph, cfg, version) — the sanctioned way for build/delta
        paths that just computed the operands to warm the serving cache
        (``device_edges``) instead of poking the private tuple. With no
        argument, computes them fresh."""
        if edges is None:
            edges = edge_operands(self.graph, self.cfg)
        self._edges_cache = (self.version, edges)
        return edges

    def planned_matrix(self) -> jnp.ndarray:
        """Register matrix with rows in the entry's plan order (shard ``v``
        of the plan owns contiguous rows ``[v*n_loc, (v+1)*n_loc)``) — the
        layout a mesh-sharded store bank slices per device. Cached against
        ``version``; rows past ``n_pad`` of the plan are padding (VISITED
        everywhere), exactly like the distributed runtime's. On a
        device-resident entry this is the resident array itself — sharded
        over the mesh's vertex axis, no data movement."""
        if self.plan is None:
            raise ValueError("entry has no partition plan attached")
        if self._planned_cache is None or self._planned_cache[0] != self.version:
            if self.residency == "device":
                pm = (self.banks[0] if len(self.banks) == 1
                      else jnp.concatenate(self.banks, axis=1))
                pm = jax.device_put(pm, self._row_sharding())
            else:
                pm = self._to_plan_order(self.matrix)
            self._planned_cache = (self.version, pm)
        return self._planned_cache[1]

    # ------------------------------------------------------------------
    # Residency (docs/service.md, "Sharded serving")
    # ------------------------------------------------------------------

    def _row_sharding(self):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        return NamedSharding(self.mesh, P(self.vertex_axis, None))

    def _to_plan_order(self, m: jnp.ndarray) -> jnp.ndarray:
        """Canonical rows -> plan-order rows, padded to ``plan.n_pad``."""
        n_pad = self.plan.n_pad
        if m.shape[0] < n_pad:  # plan pads further than the graph did
            pad = jnp.full((n_pad - m.shape[0], m.shape[1]), jnp.int8(-1))
            m = jnp.concatenate([m, pad], axis=0)
        return m[jnp.asarray(self.plan.inv_perm)]

    def _place_banks(self, pm) -> jnp.ndarray:
        """Place a plan-order matrix and its per-bank column slices as
        row-block-sharded device arrays; returns the placed matrix. The one
        spot the NamedSharding placement happens (place_on_mesh and every
        device-residency mutation go through it)."""
        sh = self._row_sharding()
        pm = jax.device_put(jnp.asarray(pm, jnp.int8), sh)
        j_loc = self.regs_per_bank
        self.banks = [jax.device_put(pm[:, b * j_loc:(b + 1) * j_loc], sh)
                      for b in range(self.num_banks)]
        return pm

    def _install_planned(self, pm: jnp.ndarray) -> None:
        """Split a plan-order matrix into placed row-block banks (device
        residency) and make it the new resident state (version bump)."""
        pm = self._place_banks(pm)
        self.version += 1
        self._planned_cache = (self.version, pm)
        self._matrix_cache = None

    def place_on_mesh(self, mesh, vertex_axis: str = "data") -> "StoreEntry":
        """Pin this entry's banks to ``mesh`` as plan-order row blocks.

        Each bank becomes ``int8[plan.n_pad, J_loc]`` with rows in the
        attached plan's order, sharded over ``vertex_axis`` via
        ``NamedSharding`` — shard ``v`` of the plan lives on device ``v``.
        Requires a plan with ``mu_v == mesh.shape[vertex_axis]`` and a mesh
        whose other axes are trivial (rows are the only sharded dim; the
        sample space splits into *banks*, not mesh columns). Idempotent
        content-wise: placement is a layout change, not a version bump.
        """
        if self.plan is None:
            raise ValueError("attach a partition plan before device placement "
                             "(SketchStore.attach_plan)")
        if mesh.shape[vertex_axis] != self.plan.mu_v:
            raise ValueError(
                f"plan has mu_v={self.plan.mu_v} row blocks but mesh axis "
                f"{vertex_axis!r} is {mesh.shape[vertex_axis]}-way")
        if math.prod(mesh.shape.values()) != self.plan.mu_v:
            raise ValueError(
                "serving meshes shard rows only: every non-vertex axis must "
                f"have size 1, got shape {dict(mesh.shape)}")
        canonical = self.matrix      # computed from the current layout
        self.mesh, self.vertex_axis = mesh, vertex_axis
        self.residency = "device"
        with trace.span("store.place_banks", phase="build",
                        mu_v=self.plan.mu_v) as sp:
            pm = sp.sync(self._place_banks(self._to_plan_order(canonical)))
        metrics.counter("store.device_placements").inc()
        metrics.gauge("store.device_resident_entries").value += 1.0
        self._planned_cache = (self.version, pm)
        self._matrix_cache = (self.version, canonical)
        return self

    def to_host(self) -> "StoreEntry":
        """Undo :meth:`place_on_mesh`: back to canonical host-order banks."""
        if self.residency != "device":
            return self
        canonical = jnp.asarray(self.matrix)
        self.residency, self.mesh = "host", None
        metrics.gauge("store.device_resident_entries").value -= 1.0
        j_loc = self.regs_per_bank
        self.banks = [canonical[:, b * j_loc:(b + 1) * j_loc]
                      for b in range(self.num_banks)]
        self._matrix_cache = (self.version, canonical)
        self._planned_cache = None
        return self

    def set_matrix(self, m: jnp.ndarray) -> None:
        """Replace the resident matrix (canonical row order), preserving the
        bank split and the entry's residency."""
        if self.residency == "device":
            self._install_planned(self._to_plan_order(jnp.asarray(m, jnp.int8)))
            return
        j_loc = self.regs_per_bank
        self.banks = [m[:, b * j_loc:(b + 1) * j_loc] for b in range(self.num_banks)]
        self.version += 1

    def set_planned_matrix(self, pm) -> None:
        """Replace the resident matrix from a plan-order array (the shard
        repair output) — a device-resident entry installs it as-is (still
        sharded); a host entry un-permutes back to canonical order."""
        if self.residency == "device":
            self._install_planned(pm)
            return
        canon = jnp.asarray(pm, jnp.int8)[
            jnp.asarray(self.plan.perm[: self.graph.n_pad])]
        self.set_matrix(canon)

    def install_canonical_banks(self, banks: list) -> None:
        """Adopt freshly built canonical-order banks (the rebuild path),
        preserving residency: a device-resident entry re-places the new
        matrix as plan-order row blocks on its mesh."""
        if self.residency == "device":
            m = banks[0] if len(banks) == 1 else jnp.concatenate(banks, axis=1)
            self._install_planned(self._to_plan_order(jnp.asarray(m, jnp.int8)))
            return
        self.banks = list(banks)
        self.version += 1


@dataclasses.dataclass
class EvictionRecipe:
    """Everything needed to rebuild an evicted entry transparently on its
    next touch: the *current* graph (deltas already applied), the sketch
    setting, and the exact sample vector — a rebuild from these is
    bit-identical to the matrix that was dropped (insertion repairs converge
    to the pristine fixpoint; stale entries are never evicted, see
    :meth:`SketchStore.evict`). The banks themselves are gone — that is the
    point: the recipe is O(graph), the banks are O(n_pad * J) device bytes.
    """

    key: StoreKey
    graph: Graph
    cfg: DiFuserConfig
    x: np.ndarray
    plan: Optional[PartitionPlan]
    version: int                 # version at eviction; rebuild resumes past it
    build_time_s: float          # last measured build cost (eviction scoring)
    evictions: int               # lifetime eviction count of this index


class SketchStore:
    """Build-once, query-many cache of propagated sketch matrices.

    ``backend`` / ``spec`` select the execution strategy of the builds
    (:mod:`repro.runtime`): any registered backend can build the banks,
    because every backend returns the canonical matrix layout. The defaults
    reproduce the historical behaviour exactly (``"auto"`` on an unsharded
    spec resolves to the ``single`` backend). ``spec`` also carries the
    shard-grid knobs (``mu_v``/``partition``/``pad_mode``) a sharded build
    needs.
    """

    def __init__(self, num_banks: int = 1, backend=None, spec=None):
        assert num_banks >= 1
        self.num_banks = num_banks
        self.backend = backend   # str | runtime.Backend | None (spec's choice)
        self.spec = spec         # Optional[runtime.RunSpec] execution knobs
        self._entries: dict[StoreKey, StoreEntry] = {}
        # evicted indexes: banks dropped, rebuild recipe kept — entry()/
        # get_or_build transparently rebuild on next touch
        self._evicted: dict[StoreKey, EvictionRecipe] = {}
        # structural mutations (evict / evicted-rebuild / swap) serialize on
        # this; the query fast path stays an unlocked dict read
        self._lock = threading.RLock()
        # called as hook(key, old_entry_or_None, new_entry) after every
        # atomic entry swap — engines drop per-key memos here
        self._swap_hooks: list[Callable] = []

    def _resolve_backend(self, cfg: DiFuserConfig):
        """The (backend, RunSpec) pair builds run through: ``cfg`` supplies
        the result-affecting sketch fields, ``self.spec`` the execution
        strategy, ``self.backend`` an explicit override."""
        from repro.runtime import RunSpec, get_backend, resolve_backend

        spec = RunSpec.from_config(cfg, base=self.spec)
        if self.backend is not None:
            return get_backend(self.backend), spec
        return resolve_backend(spec), spec

    def __len__(self) -> int:
        return len(self._entries) + len(self._evicted)

    def __contains__(self, key: StoreKey) -> bool:
        return key in self._entries or key in self._evicted

    def entry(self, key: StoreKey) -> StoreEntry:
        """The resident entry for ``key``. An evicted key transparently
        rebuilds from its recipe here — the touch path of the eviction
        contract — so callers never observe the eviction except as latency.
        """
        e = self._entries.get(key)
        if e is not None:
            return e
        if key in self._evicted:
            return self._rebuild_evicted(key)
        raise KeyError(key)

    def keys(self):
        return list(self._entries) + list(self._evicted)

    def resident_keys(self):
        """Keys whose banks are currently on device (excludes evicted)."""
        return list(self._entries)

    def is_evicted(self, key: StoreKey) -> bool:
        return key in self._evicted

    def resident_bytes(self) -> int:
        """Total device bytes of all resident banks (the evictor's budget
        currency)."""
        return sum(e.device_bytes() for e in list(self._entries.values()))

    def invalidate(self, key: StoreKey) -> None:
        self._entries.pop(key, None)
        self._evicted.pop(key, None)

    def get_or_build(self, g: Graph, config: Optional[DiFuserConfig] = None,
                     x: Optional[np.ndarray] = None) -> StoreEntry:
        """Return the resident entry for (g, config), building it on miss.

        The build is the one cold fixpoint every subsequent query amortizes;
        hits are O(1) dict lookups.
        """
        cfg = config or DiFuserConfig()
        key = StoreKey.for_graph(g, cfg)
        hit = self._entries.get(key)
        if hit is None and key in self._evicted:
            hit = self._rebuild_evicted(key)   # transparent rebuild on touch
        if hit is not None:
            # the key doesn't carry x: validate the caller's sample space
            # (explicit x, or the seed-derived default when x is None)
            # against the resident one — O(J), no graph work on the hit path
            x_norm = normalize_x(cfg, x)
            if not np.array_equal(x_norm, hit.x):
                raise ValueError(
                    "store hit for this (graph, config) was built with a "
                    "different sample vector x; use a distinct config.seed "
                    "or a separate store for a separate sample space")
            return hit
        g_norm, x_norm = normalize_inputs(g, cfg, x)
        entry = self._build_entry(key, g_norm, cfg, x_norm)
        self._entries[key] = entry
        return entry

    def _build_entry(self, key: StoreKey, g_norm: Graph, cfg: DiFuserConfig,
                     x_norm: np.ndarray) -> StoreEntry:
        banks, iters, dt, edges = self._build_banks(g_norm, cfg, x_norm)
        entry = StoreEntry(key=key, graph=g_norm, cfg=cfg, x=x_norm, banks=banks,
                           build_iters=iters, build_time_s=dt)
        entry.prime_edges_cache(edges)
        return entry

    def _build_banks(self, g_norm: Graph, cfg: DiFuserConfig, x_norm: np.ndarray):
        j = x_norm.shape[0]
        assert j % self.num_banks == 0, (j, self.num_banks)
        j_loc = j // self.num_banks
        t0 = time.perf_counter()
        backend, spec = self._resolve_backend(cfg)
        with trace.span("store.build_banks", phase="build",
                        banks=self.num_banks, n=g_norm.n, registers=j):
            # hoisted out of the bank loop: the O(m) model preprocessing +
            # device upload is identical for every bank (banks split the
            # sample space, not the graph); sharded backends ignore the hint
            # but the serving cache (device_edges) wants the operands
            # regardless
            edges = edge_operands(g_norm, cfg)
            banks, iters = [], 0
            for b in range(self.num_banks):
                with trace.span("store.build_bank", bank=b,
                                timed=True) as sp:
                    m_b, it_b = backend.build_matrix(
                        g_norm, spec, x_norm[b * j_loc:(b + 1) * j_loc],
                        reg_offset=b * j_loc, normalized=True, edges=edges)
                    m_b = sp.sync(jnp.asarray(m_b))
                banks.append(m_b)
                iters = max(iters, it_b)
                metrics.histogram("store.bank_build_s",
                                  unit="s").observe(sp.duration_s)
            for m_b in banks:
                m_b.block_until_ready()
        dt = time.perf_counter() - t0
        metrics.counter("store.bank_builds").inc(self.num_banks)
        metrics.histogram("store.entry_build_s", unit="s").observe(dt)
        return banks, iters, dt, edges

    def rebuild(self, key: StoreKey) -> StoreEntry:
        """Full pristine rebuild from the entry's *current* graph (Alg. 4
        rebuild machinery at the store level: after deltas marked the entry
        stale, or on explicit request). Clears staleness, bumps version."""
        entry = self.entry(key)
        banks, iters, dt, edges = self._build_banks(entry.graph, entry.cfg, entry.x)
        entry.install_canonical_banks(banks)   # device entries re-place
        entry.build_iters = iters
        entry.build_time_s = dt
        entry.stale = False
        entry.staleness_frac = 0.0
        entry.rebuilds += 1
        metrics.counter("store.rebuilds").inc()
        entry.prime_edges_cache(edges)
        return entry

    # ------------------------------------------------------------------
    # Eviction + double-buffered swap (docs/service.md, "Async serving")
    # ------------------------------------------------------------------

    def evict(self, key: StoreKey) -> int:
        """Drop a resident entry's banks, keeping its rebuild recipe; the
        next touch (``entry``/``get_or_build``) rebuilds transparently.
        Returns the device bytes freed.

        Only host-resident, non-stale entries are evictable: a stale matrix
        (removals pending) is history-dependent — a pristine rebuild would
        *change* query answers, not restore them — and a device-placed entry
        pins mesh state the recipe cannot re-derive. The evictor skips both.
        """
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                if key in self._evicted:
                    return 0
                raise KeyError(key)
            if e.stale:
                raise ValueError("stale entries are not evictable: the "
                                 "over-approximating matrix cannot be "
                                 "reconstructed by a pristine rebuild")
            if e.residency == "device":
                raise ValueError("device-resident entries are not evictable;"
                                 " to_host() first")
            freed = e.device_bytes()
            self._evicted[key] = EvictionRecipe(
                key=e.key, graph=e.graph, cfg=e.cfg, x=e.x, plan=e.plan,
                version=e.version, build_time_s=e.build_time_s,
                evictions=e.evictions + 1)
            del self._entries[key]
        metrics.counter("store.evictions").inc()
        metrics.gauge("store.resident_bytes").set(float(self.resident_bytes()))
        return freed

    def _rebuild_evicted(self, key: StoreKey) -> StoreEntry:
        """Rebuild an evicted entry from its recipe (the touch path). The
        rebuilt matrix is bit-identical to the dropped one: the recipe holds
        the post-delta graph and the exact x, and insertion-repaired
        matrices equal the from-scratch fixpoint by the monotone-lattice
        argument. The version resumes *past* the evicted one, so memos keyed
        on the old version correctly miss."""
        with self._lock:
            live = self._entries.get(key)
            if live is not None:     # lost the race: someone rebuilt first
                return live
            recipe = self._evicted.pop(key)
            with trace.span("store.evicted_rebuild", phase="build",
                            timed=True) as sp:
                banks, iters, dt, edges = self._build_banks(
                    recipe.graph, recipe.cfg, recipe.x)
                for b in banks:
                    sp.sync(b)
            entry = StoreEntry(key=recipe.key, graph=recipe.graph,
                               cfg=recipe.cfg, x=recipe.x, banks=banks,
                               build_iters=iters, build_time_s=dt,
                               version=recipe.version + 1,
                               plan=recipe.plan,
                               evictions=recipe.evictions)
            entry.prime_edges_cache(edges)
            self._entries[key] = entry
        metrics.counter("store.evicted_rebuilds").inc()
        metrics.histogram("store.evicted_rebuild_s", unit="s").observe(dt)
        metrics.gauge("store.resident_bytes").set(float(self.resident_bytes()))
        return entry

    def add_swap_hook(self, fn: Callable) -> None:
        """Register ``fn(key, old_entry_or_None, new_entry)`` to run after
        every :meth:`swap_entry` — how engines sharing this store learn that
        a key's resident state was atomically replaced (memo drop, metrics).
        """
        if fn not in self._swap_hooks:
            self._swap_hooks.append(fn)

    def shadow(self, key: StoreKey) -> "SketchStore":
        """The double-buffer: a fresh store (same build strategy) holding a
        :meth:`StoreEntry.clone_for_update` of ``key``'s entry. Mutations
        (``apply_delta``, ``rebuild``) run against the shadow while this
        store keeps serving version N; :meth:`swap_entry` then installs the
        shadow's entry as N+1."""
        e = self.entry(key)          # rebuilds an evicted entry first
        s = SketchStore(num_banks=self.num_banks, backend=self.backend,
                        spec=self.spec)
        s._entries[key] = e.clone_for_update()
        return s

    def swap_entry(self, key: StoreKey, new_entry: StoreEntry) -> Optional[StoreEntry]:
        """Atomically make ``new_entry`` the resident state of ``key`` and
        fire the swap hooks. Returns the displaced entry (None for a cold
        admit). The swap itself is a dict write under the store lock —
        queries snapshotting the entry before the swap finish against
        version N; every later lookup sees N+1."""
        t0 = time.perf_counter()
        with self._lock:
            old = self._entries.get(key)
            self._evicted.pop(key, None)
            self._entries[key] = new_entry
        for hook in list(self._swap_hooks):
            try:
                hook(key, old, new_entry)
            except Exception:  # noqa: BLE001 — observers must not break
                pass           # the serving path
        metrics.counter("store.swaps").inc()
        metrics.histogram("store.swap_s", unit="s").observe(
            time.perf_counter() - t0)
        metrics.gauge("store.resident_bytes").set(float(self.resident_bytes()))
        return old

    def attach_plan(self, key: StoreKey, plan: PartitionPlan) -> StoreEntry:
        """Remember a vertex-shard plan on a resident entry.

        The matrix stays in canonical (original-id) row order — queries are
        untouched — but ``entry.planned_matrix()`` now serves the plan-order
        layout a mesh-sharded bank would slice, and deltas report which plan
        shards they touched (``DeltaReport.plan_shards_touched``), the hook
        distributed delta repair keys on. Plans survive deltas/rebuilds (the
        vertex set is fixed) and are persisted by ``save``/``load``."""
        entry = self.entry(key)
        if entry.residency == "device":
            raise ValueError("entry is device-resident under its current "
                             "plan; to_host() before attaching another")
        plan.validate(entry.graph)
        entry.plan = plan
        entry._planned_cache = None
        return entry

    def place(self, key: StoreKey, mesh, *,
              vertex_axis: str = "data") -> StoreEntry:
        """Convenience: :meth:`StoreEntry.place_on_mesh` by key."""
        return self.entry(key).place_on_mesh(mesh, vertex_axis=vertex_axis)

    # ------------------------------------------------------------------
    # Persistence
    # ------------------------------------------------------------------

    @staticmethod
    def _npz_path(path: str) -> str:
        # np.savez_compressed appends ".npz" to suffix-less paths; np.load
        # does not — normalize both directions so save(p) -> load(p) works
        return path if path.endswith(".npz") else path + ".npz"

    def save(self, path: str, key: StoreKey) -> None:
        """Serialize one entry (matrix + graph + setting) to npz."""
        path = self._npz_path(path)
        e = self.entry(key)
        g = e.graph
        plan_fields = {}
        if e.plan is not None:
            plan_fields = dict(plan_strategy=np.str_(e.plan.strategy),
                               plan_perm=e.plan.perm,
                               plan_mu_v=e.plan.mu_v, plan_mu_s=e.plan.mu_s)
        np.savez_compressed(
            path,
            matrix=np.asarray(e.matrix), x=e.x,
            **plan_fields,
            n=g.n, n_pad=g.n_pad, m_real=g.m_real,
            src=g.src, dst=g.dst, weight=g.weight,
            graph_key=np.str_(e.key.graph_key),
            num_registers=e.cfg.num_registers, seed=e.cfg.seed,
            estimator=np.str_(e.cfg.estimator), impl=np.str_(e.cfg.impl),
            model=np.str_(e.cfg.model),
            sort_x=e.cfg.sort_x,
            rebuild_threshold=e.cfg.rebuild_threshold,
            max_propagate_iters=e.cfg.max_propagate_iters,
            max_cascade_iters=e.cfg.max_cascade_iters,
            edge_chunk=e.cfg.edge_chunk,
            build_iters=e.build_iters, version=e.version,
            residency=np.str_(e.residency),
            stale=e.stale, staleness_frac=e.staleness_frac)

    def load(self, path: str, *, mesh=None,
             vertex_axis: str = "data") -> StoreEntry:
        """Restore an entry saved by ``save`` (skipping the build fixpoint).

        Snapshots from before the diffusion-model zoo carry no ``model``
        field; they are re-keyed on load under the backward-compatible
        default (``wc`` — exactly the sampling they were built with).

        ``mesh`` round-trips a device-resident layout: an entry saved with
        ``residency="device"`` (the plan rides the snapshot) is re-placed as
        plan-order row blocks on the given mesh. Without a mesh the entry
        loads host-order — same answers, gather-path serving — and an
        explicit ``mesh`` also places snapshots saved host-order."""
        z = np.load(self._npz_path(path))
        cfg = DiFuserConfig(
            num_registers=int(z["num_registers"]), seed=int(z["seed"]),
            estimator=str(z["estimator"]), impl=str(z["impl"]),
            model=str(z["model"]) if "model" in getattr(z, "files", ()) else DEFAULT_MODEL,
            sort_x=bool(z["sort_x"]),
            rebuild_threshold=float(z["rebuild_threshold"]),
            max_propagate_iters=int(z["max_propagate_iters"]),
            max_cascade_iters=int(z["max_cascade_iters"]),
            edge_chunk=int(z["edge_chunk"]))
        g = Graph(n=int(z["n"]), src=z["src"], dst=z["dst"], weight=z["weight"],
                  n_pad=int(z["n_pad"]), m_real=int(z["m_real"]))
        # the lineage key (the graph the index was registered under) survives
        # deltas; recomputing from the saved graph would fork the identity
        key = dataclasses.replace(StoreKey.for_graph(g, cfg),
                                  graph_key=str(z["graph_key"]))
        m = jnp.asarray(z["matrix"])
        assert m.shape[1] % self.num_banks == 0, (m.shape[1], self.num_banks)
        j_loc = m.shape[1] // self.num_banks
        banks = [m[:, b * j_loc:(b + 1) * j_loc] for b in range(self.num_banks)]
        entry = StoreEntry(key=key, graph=g, cfg=cfg, x=z["x"].astype(np.uint32),
                           banks=banks, build_iters=int(z["build_iters"]),
                           build_time_s=0.0, version=int(z["version"]),
                           stale=bool(z["stale"]),
                           staleness_frac=float(z["staleness_frac"]))
        if "plan_strategy" in getattr(z, "files", ()):
            entry.plan = PartitionPlan.from_permutation(
                g.n, int(z["plan_mu_v"]), int(z["plan_mu_s"]),
                z["plan_perm"], strategy=str(z["plan_strategy"]))
        self._entries[key] = entry
        saved_residency = (str(z["residency"])
                           if "residency" in getattr(z, "files", ()) else "host")
        if mesh is not None:
            if entry.plan is None:
                raise ValueError(
                    "load(mesh=...) asked for device placement but the "
                    "snapshot carries no partition plan to place with")
            entry.place_on_mesh(mesh, vertex_axis=vertex_axis)
        elif saved_residency == "device":
            # a device snapshot restored without a mesh serves host-order —
            # bit-identical answers through the gather fallback, but slower
            # than the layout it was saved with, so say so
            import warnings

            warnings.warn(
                "snapshot was saved device-resident; pass load(mesh=...) to "
                "restore the placed row-block layout (serving host-order "
                "for now — identical answers, gather path)", stacklevel=2)
        return entry
