"""Deadline-driven micro-batching for the async serving pipeline.

The scheduler is the pure data-structure half of
:class:`~repro.service.async_engine.AsyncInfluenceEngine`: it owns no
threads, no locks, and no device state. Requests arrive one at a time and
are coalesced into *buckets* keyed by ``(store key, query class)`` — the
unit :meth:`InfluenceEngine.execute_chunk` executes in one padded jit call.
A bucket flushes when it is **full** (``max_batch`` requests — batching
gain has saturated) or when its earliest member's **flush deadline**
arrives (latency bound — a lone request never waits longer than the flush
window for company). Between those two events the engine sleeps; the
scheduler tells it exactly how long via :meth:`next_flush_t`.

Buckets can be *held*: a hold token ``(key, qclass)`` parks that bucket
(``qclass=None`` parks every class for the key) so ``take_due`` skips it —
the engine holds ``(key, "TopKSeeds")`` while a background rebuild of a
stale entry is in flight, then releases and the parked requests flush
against the fresh version. Holds exclude a bucket from ``next_flush_t`` as
well, so a parked bucket costs no wakeups.

All methods assume the caller serializes access (the async engine calls
everything under one condition variable).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

from repro.service.store import StoreKey


@dataclasses.dataclass
class AsyncRequest:
    """One admitted query waiting for (or riding in) a flush.

    enqueue_t:  monotonic admission time (queue-wait accounting).
    flush_t:    when the request's bucket must flush even if not full —
                ``enqueue_t + flush window``.
    deadline_t: absolute end-to-end SLO deadline (None = best effort);
                resolution after it counts as a deadline miss.
    future:     resolves to the :class:`~repro.service.engine.QueryResult`.
    """

    seq: int
    key: StoreKey
    query: object
    future: object
    enqueue_t: float
    flush_t: float
    deadline_t: Optional[float] = None

    @property
    def qclass(self) -> str:
        return type(self.query).__name__


class MicroBatchScheduler:
    """Coalesce compatible requests; flush on batch-full or deadline."""

    def __init__(self, max_batch: int = 256, flush_window_s: float = 0.005):
        self.max_batch = int(max_batch)
        self.flush_window_s = float(flush_window_s)
        self._buckets: dict[tuple, list[AsyncRequest]] = {}
        self._holds: set[tuple] = set()
        self._seq = itertools.count()

    # -- admission ---------------------------------------------------------

    def make_request(self, key: StoreKey, query, future, now: float,
                     deadline_t: Optional[float] = None) -> AsyncRequest:
        return AsyncRequest(seq=next(self._seq), key=key, query=query,
                            future=future, enqueue_t=now,
                            flush_t=now + self.flush_window_s,
                            deadline_t=deadline_t)

    def offer(self, req: AsyncRequest) -> bool:
        """Enqueue into the request's bucket; True if the bucket is now
        full (the engine should flush without waiting for the window)."""
        b = self._buckets.setdefault((req.key, req.qclass), [])
        b.append(req)
        return len(b) >= self.max_batch

    def requeue(self, reqs: Sequence[AsyncRequest]) -> None:
        """Put deferred requests back (front of their buckets, original
        admission order) — their ``flush_t`` is unchanged, so once any hold
        clears they are immediately due."""
        by_bucket: dict[tuple, list[AsyncRequest]] = {}
        for r in reqs:
            by_bucket.setdefault((r.key, r.qclass), []).append(r)
        for bk, rs in by_bucket.items():
            self._buckets[bk] = sorted(rs + self._buckets.get(bk, []),
                                       key=lambda r: r.seq)

    # -- holds -------------------------------------------------------------

    def hold(self, key: StoreKey, qclass: Optional[str] = None) -> None:
        self._holds.add((key, qclass))

    def release(self, key: StoreKey, qclass: Optional[str] = None) -> None:
        self._holds.discard((key, qclass))

    def is_held(self, key: StoreKey, qclass: str) -> bool:
        return (key, qclass) in self._holds or (key, None) in self._holds

    # -- flush selection ---------------------------------------------------

    def take_due(self, now: float) -> list[list[AsyncRequest]]:
        """Remove and return every unheld bucket that is full or whose
        earliest member's flush window has expired."""
        due = []
        for bk, b in list(self._buckets.items()):
            key, qclass = bk
            if not b or self.is_held(key, qclass):
                continue
            if len(b) >= self.max_batch or min(r.flush_t for r in b) <= now:
                due.append(b)
                del self._buckets[bk]
        return due

    def take_all(self) -> list[list[AsyncRequest]]:
        """Remove and return every bucket, holds ignored (shutdown drain)."""
        out = [b for b in self._buckets.values() if b]
        self._buckets.clear()
        return out

    def next_flush_t(self) -> Optional[float]:
        """Earliest flush deadline among unheld buckets (None = nothing
        pending — sleep until a new arrival)."""
        ts = [min(r.flush_t for r in b)
              for (key, qclass), b in self._buckets.items()
              if b and not self.is_held(key, qclass)]
        return min(ts) if ts else None

    def depth(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def oldest_wait_s(self, now: float) -> float:
        """Age of the oldest queued request (0.0 when empty) — the
        admission-stall signal the engine watches."""
        ts = [r.enqueue_t for b in self._buckets.values() for r in b]
        return (now - min(ts)) if ts else 0.0
