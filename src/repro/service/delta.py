"""Incremental graph-delta repair for resident sketch indexes.

Edge insertions are sound without a rebuild: registers form a max-merge
lattice and adding edges only grows each simulation's reachability sets, so
the old fixpoint sits *below* the new one and monotone sweeps climb the rest
of the way. The repair is frontier-shaped: one cheap sweep over just the
touched edges (O(E_delta * J)) decides whether anything changed at all; only
if it did do full sweeps run — and they start from the old fixpoint, so they
converge in frontier-depth iterations instead of graph-diameter ones.

Edge removals cannot un-merge registers, so they accrue *staleness*: the
matrix keeps over-estimating until the removed fraction crosses
``staleness_threshold`` (the Alg. 4 line-22 lazy-rebuild idea lifted to the
store), at which point a full pristine rebuild runs. Below the threshold the
entry is only marked stale — TopKSeeds' lazy-rebuild check (queries.py)
rebuilds on first exact-query demand and writes the matrix back.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.simulate import propagate_to_fixpoint
from repro.diffusion import resolve as resolve_model
from repro.graphs.structs import (Graph, GraphDelta, edge_pair_keys,
                                  pad_to_multiple)
from repro.kernels import ops
from repro.obs import metrics, trace
from repro.service.store import SketchStore, StoreEntry, StoreKey


@dataclasses.dataclass
class DeltaReport:
    """What apply_delta did: repair path taken + work accounting."""

    added: int
    removed: int              # edges actually removed (absent pairs don't count)
    rebuilt: bool             # full rebuild ran (threshold crossed)
    stale: bool               # entry left stale (removals below threshold)
    staleness_frac: float
    repair_sweeps: int        # fixpoint sweeps the insertion repair ran
    banks_touched: int        # banks whose frontier sweep found real work
    time_s: float
    # vertex-shards of the entry's PartitionPlan whose rows the delta's
    # endpoints land in (empty without a plan) — the invalidation set a
    # mesh-sharded store bank repairs instead of the whole matrix
    plan_shards_touched: tuple = ()
    # when the repair ran through a shard_repair backend: the shards whose
    # buckets were actually re-swept (== plan_shards_touched for a localized
    # delta; grows only if the repair genuinely spread further)
    shards_swept: tuple = ()
    repair_backend: str = "single"   # backend the insertion repair ran on


def _touched_edge_arrays(new_g: Graph, delta: GraphDelta, ep,
                         edge_block: int = 256):
    """Slice the *new* graph's padded edge arrays (and its model's
    fused-predicate operands ``ep``, computed against the full graph so
    context-dependent params stay right) down to the edges whose (src, dst)
    pair appears in the delta's additions — their final compound
    probabilities included (an added duplicate raises the pair's
    threshold)."""
    hit = np.isin(
        edge_pair_keys(new_g.src[: new_g.m_real], new_g.dst[: new_g.m_real],
                       new_g.n_pad),
        edge_pair_keys(delta.add_src, delta.add_dst, new_g.n_pad))
    src = new_g.src[: new_g.m_real][hit]
    dst = new_g.dst[: new_g.m_real][hit]
    if src.size == 0:
        # every added edge vanished in from_edges (self-loops): nothing touched
        return None
    sentinel = np.int32(new_g.n_pad - 1)
    zero = np.uint32(0)  # thr=0 padding is inert under every predicate
    return (pad_to_multiple(src, edge_block, sentinel),
            pad_to_multiple(dst, edge_block, sentinel),
            pad_to_multiple(ep.h[: new_g.m_real][hit], edge_block, zero),
            pad_to_multiple(ep.lo[: new_g.m_real][hit], edge_block, zero),
            pad_to_multiple(ep.thr[: new_g.m_real][hit], edge_block, zero))


def apply_delta(store: SketchStore, key: StoreKey, delta: GraphDelta,
                *, staleness_threshold: float = 0.1,
                backend=None) -> DeltaReport:
    """Apply edge insertions/removals to a resident entry, repairing or
    invalidating its matrix as cheaply as soundness allows.

    The entry's graph is always updated; its StoreKey is kept (the key names
    the *lineage* — the graph the index was registered under — so engine
    handles stay valid across deltas).

    ``staleness_threshold``: removed-edge fraction beyond which a removal
    triggers an immediate pristine rebuild instead of marking the entry
    stale. Deliberately distinct from ``DiFuserConfig.rebuild_threshold``
    (Alg. 4's per-round score epsilon) — the two knobs govern different
    mechanisms.

    ``backend``: a :mod:`repro.runtime` backend (name or instance). When it
    reports ``shard_repair`` capability and the entry has a partition plan
    attached, the insertion repair runs shard-restricted: only the plan
    shards the delta dirtied (``plan_shards_touched``) are re-propagated,
    with results bit-identical to a full rebuild. ``"auto"`` picks by the
    entry's residency — ``mesh`` when the banks are device-resident (the
    repair then runs where the rows live and the result stays sharded),
    else ``serial`` when a plan is attached, else the historical per-bank
    single-device repair. ``None`` keeps the historical repair, except for
    device-resident entries, which always route through a shard_repair
    backend (the per-bank kernels assume canonical row order).
    """
    t0 = time.perf_counter()
    sp = trace.span("delta.apply", phase="repair", timed=True,
                    added=delta.num_added, removals=delta.num_removed)
    sp.__enter__()
    entry = store.entry(key)
    m_before = entry.graph.m_real
    # count edges the removals actually hit (a pair absent from the graph, or
    # listed twice, removes nothing and must not accrue staleness)
    removed = 0
    if delta.num_removed:
        removed = int(np.isin(
            edge_pair_keys(entry.graph.src[: m_before],
                           entry.graph.dst[: m_before], entry.graph.n_pad),
            edge_pair_keys(delta.rem_src, delta.rem_dst,
                           entry.graph.n_pad)).sum())
    new_g = entry.graph.apply_delta(delta).sorted_by_dst()
    entry.graph = new_g
    entry.version += 1

    # permute the delta through the entry's plan (if any): which vertex
    # shards of the planned layout does this delta dirty?
    plan_shards: tuple = ()
    if entry.plan is not None:
        touched_v = np.unique(np.concatenate(
            [delta.add_src, delta.add_dst, delta.rem_src, delta.rem_dst]))
        if touched_v.size:
            plan_shards = tuple(
                np.unique(entry.plan.owner_of(touched_v)).tolist())

    rebuilt = False
    repair_sweeps = 0
    banks_touched = 0
    shards_swept: tuple = ()
    repair_backend = "single"
    # lt-style models: any in-edge add/remove re-normalizes the destination's
    # interval partition, so the old fixpoint is neither a lower bound
    # (insertions) nor a sound over-approximation (removals) — both fast
    # paths are unsound and a pristine rebuild runs instead
    context_free = resolve_model(entry.cfg.model).context_free_edges

    if removed:
        entry.staleness_frac += removed / max(m_before, 1)
        if not context_free or entry.staleness_frac > staleness_threshold:
            store.rebuild(key)   # clears stale/staleness, bumps version
            rebuilt = True
        else:
            entry.stale = True

    if delta.num_added and not rebuilt:
        if context_free:
            shard_backend = _shard_repair_backend(backend, entry)
            if shard_backend is not None and entry.plan is not None and plan_shards:
                repair_sweeps, banks_touched, shards_swept = \
                    _repair_insertions_sharded(entry, new_g, plan_shards,
                                               shard_backend)
                repair_backend = shard_backend.name
            else:
                repair_sweeps, banks_touched = _repair_insertions(entry, new_g, delta)
        else:
            store.rebuild(key)
            rebuilt = True

    entry = store.entry(key)
    sp.annotate(rebuilt=rebuilt, sweeps=repair_sweeps,
                backend=repair_backend)
    sp.__exit__(None, None, None)
    metrics.histogram("delta.repair_sweeps").observe(repair_sweeps)
    metrics.histogram("delta.apply_s", unit="s").observe(sp.duration_s)
    if rebuilt:
        metrics.counter("delta.rebuilds").inc()
    if entry.plan is not None and entry.plan.mu_v:
        metrics.gauge("delta.dirty_shard_frac").set(
            len(plan_shards) / entry.plan.mu_v)
    return DeltaReport(added=delta.num_added, removed=removed,
                       rebuilt=rebuilt, stale=entry.stale,
                       staleness_frac=entry.staleness_frac,
                       repair_sweeps=repair_sweeps, banks_touched=banks_touched,
                       time_s=time.perf_counter() - t0,
                       plan_shards_touched=plan_shards,
                       shards_swept=shards_swept,
                       repair_backend=repair_backend)


def _shard_repair_backend(backend, entry: StoreEntry):
    """Resolve ``backend`` (name | Backend | "auto" | None) to a
    shard_repair-capable backend instance, or None when the historical
    per-bank repair should run. The entry's residency is authoritative
    over the caller's backend in both directions: device-resident entries
    never get None (their banks are plan-ordered, which the per-bank
    kernels cannot consume — they route to ``mesh``, rows repaired where
    they live, with ``serial`` as the host fallback), and host-resident
    entries never get ``mesh`` (shipping a host matrix to a throwaway
    device mesh just to gather it back is strictly worse than the in-place
    serial repair, and may not even have the devices)."""
    from repro.runtime import get_backend

    if backend == "auto" or (backend is None and entry.residency == "device"):
        if entry.plan is None:
            return None
        if entry.residency == "device":
            b = get_backend("mesh")
            if b.available()[0]:
                return b
        return get_backend("serial")
    if backend is None:
        return None
    b = get_backend(backend)
    if not b.capabilities().shard_repair:
        return get_backend("serial") if entry.residency == "device" else None
    if b.capabilities().needs_mesh and entry.residency != "device":
        return get_backend("serial")
    return b


def _repair_insertions_sharded(entry: StoreEntry, new_g: Graph,
                               touched: tuple, backend):
    """Shard-restricted monotone insertion repair through a shard_repair
    backend (``serial`` on host, ``mesh`` for device-resident banks): the
    plan-order matrix is repaired starting from exactly the shards the
    delta dirtied, and sweeps widen only where changes actually spread.
    Bit-identical to a full rebuild (and to the per-bank single-device
    repair) by the same monotone-lattice argument. A device-resident
    entry's matrix goes in sharded and comes back sharded — the repair is
    the only data movement.
    """
    from repro.runtime.spec import RunSpec

    planned_old = entry.planned_matrix()
    spec = RunSpec.from_config(entry.cfg, vertex_axis=entry.vertex_axis)
    planned_new, sweeps, swept = backend.repair_plan_shards(
        new_g, spec, entry.x, planned_old, entry.plan, touched,
        mesh=entry.mesh)
    old_banks = list(entry.banks)
    entry.set_planned_matrix(planned_new)
    banks_touched = sum(
        1 for b_old, b_new in zip(old_banks, entry.banks)
        if bool(jnp.any(b_old != b_new)))
    # warm the serving cache for the post-delta graph (same contract as the
    # single-device repair path)
    entry.prime_edges_cache()
    return sweeps, banks_touched, swept


def _repair_insertions(entry: StoreEntry, new_g: Graph, delta: GraphDelta):
    """Monotone insertion repair, per register bank.

    Even for a stale entry this is worth doing: the matrix stays a sound
    over-approximation and the eventual rebuild starts no worse off.
    """
    cfg = entry.cfg
    mdl = resolve_model(cfg.model)
    ep = mdl.edge_params(new_g, seed=cfg.seed)
    touched_arrays = _touched_edge_arrays(new_g, delta, ep)
    if touched_arrays is None:
        return 0, 0
    t_src, t_dst, t_h, t_lo, t_thr = (jnp.asarray(a) for a in touched_arrays)
    full_src, full_dst = jnp.asarray(new_g.src), jnp.asarray(new_g.dst)
    full_h, full_lo, full_thr = (jnp.asarray(ep.h), jnp.asarray(ep.lo),
                                 jnp.asarray(ep.thr))
    # warm the serving-path cache with the operands just computed — the next
    # TopKSeeds would otherwise redo the O(m) model preprocessing + upload
    # for the identical graph/cfg (apply_delta already bumped the version)
    entry.prime_edges_cache((full_src, full_dst, full_h, full_lo, full_thr))

    j_loc = entry.regs_per_bank
    total_sweeps = 0
    touched = 0
    new_banks = []
    for b, m_b in enumerate(entry.banks):
        x_b = jnp.asarray(entry.x[b * j_loc:(b + 1) * j_loc])
        # frontier probe: one sweep over just the touched edges
        m_probe = ops.propagate_sweep(m_b, t_src, t_dst, t_thr, x_b,
                                      seed=cfg.seed, impl=cfg.impl,
                                      edge_chunk=cfg.edge_chunk, h=t_h, lo=t_lo,
                                      predicate=mdl.predicate)
        if not bool(jnp.any(m_probe != m_b)):
            new_banks.append(m_b)   # no sample in this bank uses the new edges
            continue
        touched += 1
        m_fix, iters = propagate_to_fixpoint(
            m_probe, full_src, full_dst, full_thr, x_b, full_h, full_lo,
            seed=cfg.seed, impl=cfg.impl, edge_chunk=cfg.edge_chunk,
            max_iters=cfg.max_propagate_iters, predicate=mdl.predicate)
        total_sweeps += int(iters) + 1
        new_banks.append(m_fix)
    entry.banks = new_banks
    return total_sweeps, touched
