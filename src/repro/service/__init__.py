"""Online influence query service: persistent sketch store, batched query
engine, incremental graph-delta repair, and the async admission pipeline
(deadline-driven micro-batching, double-buffered builds/repairs, cost-aware
eviction) over the DiFuseR index."""
from repro.service.async_engine import AsyncInfluenceEngine
from repro.service.delta import DeltaReport, apply_delta
from repro.service.engine import (InfluenceEngine, QueryResult, Request,
                                  summarize_latencies)
from repro.service.eviction import CostAwareEvictor
from repro.service.queries import (CoverageProbe, MarginalGain, SpreadEstimate,
                                   TopKSeeds)
from repro.service.scheduler import AsyncRequest, MicroBatchScheduler
from repro.service.store import (EvictionRecipe, SketchStore, StoreEntry,
                                 StoreKey)

__all__ = [
    "SketchStore", "StoreEntry", "StoreKey", "EvictionRecipe",
    "TopKSeeds", "SpreadEstimate", "MarginalGain", "CoverageProbe",
    "InfluenceEngine", "QueryResult", "Request", "summarize_latencies",
    "DeltaReport", "apply_delta",
    "AsyncInfluenceEngine", "MicroBatchScheduler", "AsyncRequest",
    "CostAwareEvictor",
]
