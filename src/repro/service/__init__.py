"""Online influence query service: persistent sketch store, batched query
engine, and incremental graph-delta repair over the DiFuseR index."""
from repro.service.delta import DeltaReport, apply_delta
from repro.service.engine import (InfluenceEngine, QueryResult, Request,
                                  summarize_latencies)
from repro.service.queries import (CoverageProbe, MarginalGain, SpreadEstimate,
                                   TopKSeeds)
from repro.service.store import SketchStore, StoreEntry, StoreKey

__all__ = [
    "SketchStore", "StoreEntry", "StoreKey",
    "TopKSeeds", "SpreadEstimate", "MarginalGain", "CoverageProbe",
    "InfluenceEngine", "QueryResult", "Request", "summarize_latencies",
    "DeltaReport", "apply_delta",
]
