from repro.serve.engine import Engine, ServeConfig, make_serve_step
