"""Batched serving engine: prefill + greedy/temperature decode.

``serve_step`` — one token for the whole batch against the KV/SSM cache —
is the unit the decode_32k / long_500k dry-run cells lower. The engine
wraps it with cache allocation, prompt prefill, and a sampling loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


def make_serve_step(cfg: ModelConfig):
    """(params, token (B,), cache, position) -> (logits (B, V), cache)."""

    def serve_step(params, token, cache, position):
        return decode_step(params, token, cache, position, cfg)

    return serve_step


def _pad_cache(cache: dict, max_len: int) -> dict:
    """Grow the sequence axis of attention caches to max_len."""
    def grow(name, x):
        if name in ("k", "v") and x.ndim == 5:
            pad = max_len - x.shape[2]
            if pad > 0:
                return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x

    return {k: grow(k, v) for k, v in cache.items()}


class Engine:
    """Minimal batched generation engine over the zoo models."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg or ServeConfig()
        self._prefill = jax.jit(partial(prefill, cfg=cfg))
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, prompt_tokens: np.ndarray, num_steps: int,
                 enc_embeds=None, prefix_embeds=None) -> np.ndarray:
        """prompt_tokens: (B, S). Returns (B, num_steps) generated ids."""
        cfg, scfg = self.cfg, self.scfg
        bsz, plen = prompt_tokens.shape
        kw = {}
        if enc_embeds is not None:
            kw["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            kw["prefix_embeds"] = prefix_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompt_tokens), **kw)
        cache = _pad_cache(cache, plen + num_steps)
        key = jax.random.PRNGKey(scfg.seed)
        out = []
        tok = self._sample(logits[:, -1], key)
        pos = plen + (prefix_embeds.shape[1] if prefix_embeds is not None else 0)
        for i in range(num_steps):
            out.append(np.asarray(tok))
            step_logits, cache = self._step(self.params, tok, cache, jnp.int32(pos + i))
            key, sub = jax.random.split(key)
            tok = self._sample(step_logits, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        # clamp to the logical vocab (embeddings are padded for sharding)
        logits = logits[:, : self.cfg.vocab_size]
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)
