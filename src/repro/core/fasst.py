"""FASST — fusing-aware sample-space tasking (paper §4.1).

Because samples are decided by ``(X_r XOR h_e) < thr_e``, permuting the
entries of X changes nothing statistically (each X_r still induces the same
sampled graph) but changes *which* samples land next to each other. FASST
sorts X so that:

  1. consecutive register lanes make correlated sampling decisions for the
     same edge -> higher SIMD/VPU lane fill (paper Table 6),
  2. each device's contiguous chunk of sorted X samples a *small* edge
     subset -> device-local graphs shrink and overlap less (Tables 5/7),
     which is simultaneously the load-balancing / straggler-mitigation
     mechanism (max shard size == straggler bound).

All of this runs once on host (numpy) during setup; the device code only
ever sees the resulting per-shard X slices and padded edge lists.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sampling import edge_hash, weight_to_threshold
from repro.graphs.structs import Graph, pad_to_multiple


@dataclasses.dataclass(frozen=True)
class SamplePartition:
    """Sample-space partition across ``mu`` shards.

    x_shards:    uint32[mu, J_loc]  per-shard X slices (sorted within shard).
    perm:        int32[R]           original-sim id for each (shard, slot).
    edge_index:  int32[mu, E_max]   per-shard device-local edge ids into the
                                    global padded edge arrays (padded with -1
                                    -> replaced by a sentinel edge id).
    edge_counts: int64[mu]          real edge count per shard (pre-padding).
    method:      "fasst" | "naive".
    """

    x_shards: np.ndarray
    perm: np.ndarray
    edge_index: np.ndarray
    edge_counts: np.ndarray
    method: str

    @property
    def mu(self) -> int:
        return self.x_shards.shape[0]

    @property
    def regs_per_shard(self) -> int:
        return self.x_shards.shape[1]


def partition_samples(x: np.ndarray, mu: int, *, method: str = "fasst") -> tuple[np.ndarray, np.ndarray]:
    """Split R samples into mu equal shards.

    fasst: sort X, contiguous chunks of the sorted vector per shard.
    naive: original order, strided chunks (the paper's baseline).
    Returns (x_shards[mu, J_loc], perm[R]) with perm[shard*J_loc + slot] =
    original simulation id.
    """
    r = x.shape[0]
    assert r % mu == 0, (r, mu)
    if method == "fasst":
        perm = np.argsort(x, kind="stable").astype(np.int32)
    elif method == "naive":
        perm = np.arange(r, dtype=np.int32)
    else:
        raise ValueError(method)
    x_shards = x[perm].reshape(mu, r // mu)
    return x_shards, perm


def _sampled_by_any(edge_h: np.ndarray, thr: np.ndarray, x_chunk: np.ndarray,
                    chunk_edges: int = 1 << 16, *, lo: np.ndarray | None = None,
                    predicate=None) -> np.ndarray:
    """bool[m]: edge live under at least one X value in x_chunk.

    ``lo``/``predicate`` are the diffusion-model hook (repro.diffusion);
    omitted, the legacy threshold compare is used."""
    from repro.core.sampling import fused_predicate

    if predicate is None:
        predicate = fused_predicate
    if lo is None:
        lo = np.zeros_like(thr, dtype=np.uint32)
    m = edge_h.shape[0]
    out = np.zeros(m, dtype=bool)
    for a in range(0, m, chunk_edges):
        b = min(a + chunk_edges, m)
        out[a:b] = predicate(edge_h[a:b, None], lo[a:b, None], thr[a:b, None],
                             x_chunk[None, :]).any(axis=1)
    return out


def build_partition(g: Graph, x: np.ndarray, mu: int, *, method: str = "fasst",
                    seed: int = 0, edge_block: int = 256,
                    model: str = "wc") -> SamplePartition:
    """Build per-shard device-local edge lists (paper §4, lines 1-3 of setup).

    Shards get exactly the edges at least one of their samples uses; the
    lists are padded to a common length (multiple of ``edge_block``) with a
    sentinel edge id pointing at the inert padding edge, so shard_map sees
    equal shapes. The common length *is* the paper's Table-7 metric.
    ``model`` selects the diffusion model whose fused predicate decides
    membership (default ``wc`` — the legacy threshold compare).
    """
    from repro.diffusion import resolve as _resolve_model

    x_shards, perm = partition_samples(x, mu, method=method)
    mdl = _resolve_model(model)
    ep = mdl.edge_params(g, seed=seed)
    eh, lo, thr = ep.h, ep.lo, ep.thr
    # the last padded edge is inert (thr == 0): use it as the pad target
    sentinel_edge = g.m - 1
    assert thr[sentinel_edge] == 0, "graph must carry at least one padding edge"

    masks = [_sampled_by_any(eh, thr, x_shards[t], lo=lo, predicate=mdl.predicate)
             for t in range(mu)]
    counts = np.array([int(msk.sum()) for msk in masks], dtype=np.int64)
    e_max = int(counts.max()) if counts.size else 0
    e_max = max(e_max, 1)
    e_max += (-e_max) % edge_block
    edge_index = np.full((mu, e_max), sentinel_edge, dtype=np.int32)
    for t, msk in enumerate(masks):
        ids = np.nonzero(msk)[0].astype(np.int32)
        edge_index[t, : ids.shape[0]] = ids
    return SamplePartition(x_shards=x_shards, perm=perm, edge_index=edge_index,
                           edge_counts=counts, method=method)


# ---------------------------------------------------------------------------
# Metrics (paper Tables 5, 6, 7)
# ---------------------------------------------------------------------------

def duplication_histogram(g: Graph, part: SamplePartition, *, seed: int = 0) -> np.ndarray:
    """Table 5: fraction of edges appearing in exactly k device-local graphs,
    k = 0..mu (real edges only)."""
    mu = part.mu
    appear = np.zeros(g.m, dtype=np.int32)
    eh = edge_hash(g.src, g.dst, seed=seed)
    thr = weight_to_threshold(g.weight)
    for t in range(mu):
        appear += _sampled_by_any(eh, thr, part.x_shards[t]).astype(np.int32)
    appear = appear[: g.m_real]
    hist = np.bincount(appear, minlength=mu + 1).astype(np.float64)
    return hist / max(g.m_real, 1)


def max_shard_fraction(g: Graph, part: SamplePartition) -> float:
    """Table 7: largest device-local edge count / total edges."""
    return float(part.edge_counts.max() / max(g.m_real, 1))


def lane_fill_rate(g: Graph, x_sorted_or_not: np.ndarray, *, lane_width: int = 128,
                   seed: int = 0, max_edges: int = 1 << 15) -> float:
    """Table 6 analogue: fraction of useful lanes per touched lane-tile.

    For each (edge, lane-tile) pair with >= 1 sampled lane, count sampled
    lanes / lane_width. The paper's warp (32 threads) becomes the VPU lane
    tile; pass lane_width=32 to reproduce the paper's exact metric.
    """
    r = x_sorted_or_not.shape[0]
    assert r % lane_width == 0
    eh = edge_hash(g.src[: g.m_real], g.dst[: g.m_real], seed=seed)[:max_edges]
    thr = weight_to_threshold(g.weight)[: g.m_real][:max_edges]
    sampled_slots = 0
    active_tiles = 0
    x = x_sorted_or_not
    chunk = max(1, (1 << 22) // r)
    for lo in range(0, eh.shape[0], chunk):
        hi = min(lo + chunk, eh.shape[0])
        mask = (eh[lo:hi, None] ^ x[None, :]) < thr[lo:hi, None]  # (c, R)
        tiles = mask.reshape(hi - lo, r // lane_width, lane_width)
        any_tile = tiles.any(axis=2)
        sampled_slots += int(tiles.sum())
        active_tiles += int(any_tile.sum())
    if active_tiles == 0:
        return 0.0
    return sampled_slots / (active_tiles * lane_width)
