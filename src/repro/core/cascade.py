"""Influence cascade (paper §3.3, Alg. 3 + Alg. 4 lines 15-19).

Committing a seed ``s`` marks ``M[s, :] = VISITED`` and closes the visited
set under sampled edges: any vertex reachable from the seed set through
j-sampled edges becomes VISITED in simulation j. Because the previous
visited set is already closed, re-closing after adding one seed only
explores the seed's newly-covered region — the same work the paper's
frontier queue does, expressed as masked dense sweeps with a fixpoint early
exit (DESIGN.md §2).

Same (h, lo, predicate) diffusion-model hook as core/simulate.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sketch import VISITED
from repro.kernels import ops


@partial(jax.jit, static_argnames=("seed", "impl", "edge_chunk", "max_iters",
                                   "predicate", "edge_block", "reg_tile"))
def cascade_from_seed(m, seed_vertex, src, dst, thr, x, h=None, lo=None, *,
                      seed: int = 0, impl: str = "ref", edge_chunk: int = 2048,
                      max_iters: int = 64, predicate=None,
                      edge_block: int = 0, reg_tile: int = 0):
    """Mark the seed visited in all sims and close under sampled edges.

    Returns (m, iters_used). ``edge_chunk``/``edge_block``/``reg_tile`` are
    performance-only tile knobs (see core.simulate.propagate_to_fixpoint).
    """
    m = m.at[seed_vertex, :].set(jnp.int8(VISITED))

    def cond(carry):
        _, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def body(carry):
        m_cur, _, it = carry
        m_new = ops.cascade_sweep(m_cur, src, dst, thr, x, seed=seed, impl=impl,
                                  edge_chunk=edge_chunk, h=h, lo=lo,
                                  predicate=predicate, edge_block=edge_block,
                                  reg_tile=reg_tile)
        changed = jnp.any(m_new != m_cur)
        return m_new, changed, it + 1

    m_out, _, iters = jax.lax.while_loop(cond, body, (m, jnp.bool_(True), jnp.int32(0)))
    return m_out, iters
