"""Hash-based fused sampling (paper §2.2).

An edge (u, v) belongs to sample r iff

    (X_r XOR h(u, v)) < w_uv * 2^32        (uint32 arithmetic)

so sampling costs one XOR + one compare per (edge, sample) — no stored
samples, no RNG state. ``X`` is a host-generated vector of R uniform uint32
values; ``h`` is a murmur3-style finalizer over the endpoint pair.

Everything here is dtype-pinned to uint32 and works identically in numpy
(host-side FASST partitioning) and jax.numpy (device kernels/refs).
"""
from __future__ import annotations

from typing import Union

import jax.numpy as jnp
import numpy as np

Array = Union[np.ndarray, jnp.ndarray]

# murmur3 / splitmix constants
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLD = 0x9E3779B9

UINT32_MAX = np.uint64(0xFFFFFFFF)


def _xp(x):
    return np if isinstance(x, np.ndarray) else jnp


def mix32(x: Array) -> Array:
    """Murmur3 fmix32 finalizer — full avalanche on uint32."""
    xp = _xp(x)
    x = x.astype(xp.uint32)
    x = x ^ (x >> 16)
    x = x * xp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * xp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def edge_hash(src: Array, dst: Array, seed: int = 0) -> Array:
    """h(u, v): order-sensitive 32-bit hash of an edge (paper eq. (1))."""
    xp = _xp(src)
    u = src.astype(xp.uint32)
    v = dst.astype(xp.uint32)
    h = mix32(u * xp.uint32(_GOLD) + xp.uint32(seed))
    return mix32(h ^ (v * xp.uint32(_M1) + xp.uint32(0x27D4EB2F)))


def register_hash(vertex: Array, reg: Array, seed: int = 0) -> Array:
    """h_j(u): per-register item hash used by the FM sketches (paper eq. (3))."""
    xp = _xp(vertex)
    u = vertex.astype(xp.uint32)
    j = reg.astype(xp.uint32)
    return mix32(mix32(u * xp.uint32(_GOLD) + xp.uint32(seed ^ 0x5BD1E995)) ^ (j * xp.uint32(_M2)))


def vertex_hash(vertex: Array, seed: int = 0) -> Array:
    """h(v): 32-bit hash of a single vertex — the per-destination hash the LT
    live-edge sampler uses (every in-edge of v shares it, so one uniform draw
    decides which in-edge, if any, is live)."""
    xp = _xp(vertex)
    v = vertex.astype(xp.uint32)
    return mix32(mix32(v * xp.uint32(_GOLD) + xp.uint32(seed ^ 0x165667B1)) ^ xp.uint32(0x27D4EB2F))


def fused_predicate(h: Array, lo: Array, width: Array, x: Array) -> Array:
    """The universal hash-fused edge-activation predicate of the model zoo:

        live(e, r)  <=>  ((X_r ^ h_e) - lo_e) mod 2^32  <  width_e

    one XOR + one subtract + one unsigned compare per (edge, sample), for
    every registered diffusion model:

      * threshold models (ic / wc / dic): lo = 0, width = w_eff * 2^32 —
        bit-identical to the paper's ``(X ^ h) < w * 2^32`` (§2.2, eq. (2));
      * interval models (lt) use the same operand layout but sample through
        ``remix_interval_predicate`` below — the raw XOR form here leaves
        cross-vertex selections too correlated for sound joint reachability.

    All operands must be uint32 (wraparound subtraction is the point);
    works identically for numpy and jnp, scalar or broadcast shapes, and is
    Pallas-kernel-safe (pure VPU ops).
    """
    return ((h ^ x) - lo) < width


def remix_interval_predicate(h: Array, lo: Array, width: Array, x: Array) -> Array:
    """Interval predicate with an avalanche remix of the per-(vertex, sample)
    uniform:  live  <=>  (mix32(X_r ^ h_v) - lo_e) mod 2^32 < width_e.

    The LT live-edge sampler needs joint path probabilities, not just
    marginals: the raw XOR ``X_r ^ h_v`` leaves interval membership across
    *different* vertices of one sample too correlated (the XOR of two such
    uniforms is the constant h_u ^ h_v), which measurably suppresses
    reachability. One extra fmix32 (shifts + multiplies, VPU-friendly,
    Pallas-safe) decorrelates vertices while keeping exclusivity: all
    in-edges of v still share one uniform per sample, so at most one fires.
    """
    return (mix32(h ^ x) - lo) < width


def weight_to_threshold(w: np.ndarray) -> np.ndarray:
    """Map probability w in [0,1] to a uint32 compare threshold w * 2^32."""
    thr = np.minimum(np.round(np.float64(w) * 4294967296.0), np.float64(UINT32_MAX))
    return thr.astype(np.uint32)


def make_x_vector(num_samples: int, seed: int = 0) -> np.ndarray:
    """The random vector X = {X_1..X_R} (host-side, uint32)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 32, size=num_samples, dtype=np.uint64).astype(np.uint32)


def sample_mask(edge_h: Array, thr: Array, x: Array) -> Array:
    """(E,) edge hashes × (R,) X values -> (E, R) bool sample membership.

    mask[e, r] = (X_r ^ h_e) < thr_e
    """
    xp = _xp(edge_h)
    return (edge_h[:, None] ^ x[None, :]) < thr.astype(xp.uint32)[:, None]


def clz32(x: Array) -> Array:
    """Count leading zeros of uint32 (vectorized, numpy path).

    jnp path should prefer jax.lax.clz; this exists for host-side numpy use
    and as a reference for the Pallas kernel body.
    """
    xp = _xp(x)
    x = x.astype(xp.uint32)
    n = xp.full(x.shape, 32, dtype=xp.int32)
    for shift in (16, 8, 4, 2, 1):
        big = x >= (xp.uint32(1) << xp.uint32(shift))
        n = xp.where(big, n - shift, n)
        x = xp.where(big, x >> xp.uint32(shift), x)
    return n - x.astype(xp.int32)  # x is now 0 or 1; subtract the found bit
