"""DiFuseR driver (paper Alg. 4), single-device path.

The whole seed-selection loop — fill, propagate-to-fixpoint, then K rounds
of {select, cascade, score, lazy-rebuild} — is one jitted JAX program:
``lax.scan`` over seed rounds, ``lax.while_loop`` fixpoints inside,
``lax.cond`` for the rebuild decision. The distributed runtime
(core/distributed.py) wraps the same building blocks in shard_map.

Diffusion model: ``DiFuserConfig.model`` selects a registered model from
repro.diffusion (``wc`` default — the legacy behaviour, bit-identical).
Host preprocessing lowers the model to per-edge ``(h, lo, thr)`` operands
once per build (hash once instead of once per sweep), and the model's fused
predicate is threaded through every kernel as a static hook.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import select as _select
from repro.core.cascade import cascade_from_seed
from repro.core.sampling import make_x_vector
from repro.core.simulate import propagate_to_fixpoint
from repro.core.sketch import VISITED, count_visited
# the constants leaf is importable mid-cycle (repro.diffusion's package init
# reaches back through repro.core); the full registry is not — hence the
# lazy resolve_model below
from repro.diffusion.constants import DEFAULT_MODEL
from repro.graphs.structs import Graph
from repro.kernels import ops
from repro.obs import trace
from repro.utils import roofline


def resolve_model(spec: str):
    """Lazy repro.diffusion.resolve — breaks the package-init cycle
    (diffusion/models.py imports repro.core.sampling)."""
    from repro.diffusion import resolve

    return resolve(spec)


@dataclasses.dataclass(frozen=True)
class DiFuserConfig:
    """Knobs of Alg. 4. Defaults follow the paper's experimental setup."""

    num_registers: int = 1024          # J == R (one register per simulation)
    seed: int = 0                      # global hash seed
    estimator: str = "hll"             # "hll" (eq. 7) | "fm_mean" (eq. 6)
    rebuild_threshold: float = 0.01    # e in Alg. 4 line 22
    max_propagate_iters: int = 64
    max_cascade_iters: int = 64
    edge_chunk: int = 2048
    impl: str = "ref"                  # "ref" | "pallas"
    sort_x: bool = True                # FASST ordering (§4.1)
    model: str = DEFAULT_MODEL         # diffusion model spec (repro.diffusion)
    # ---- performance-only tile knobs (repro.tune feeds measured winners
    # through these; 0 = follow the library default; results invariant) ----
    cascade_chunk: int = 0             # cascade-sweep scan chunk (0: edge_chunk)
    edge_block: int = 0                # pallas edge tile (0: kernels EDGE_BLOCK)
    reg_tile: int = 0                  # pallas register tile (0: kernels REG_TILE)


@dataclasses.dataclass
class InfluenceResult:
    seeds: np.ndarray          # int32[K]
    est_gains: np.ndarray      # float32[K] sketch-estimated marginal gains
    scores: np.ndarray         # float32[K] influence after committing seed i
    rebuilds: np.ndarray       # bool[K]   whether round i rebuilt sketches
    propagate_iters: int       # initial build fixpoint sweeps
    x: np.ndarray              # the random vector actually used (uint32[J])


def edge_operands(g: Graph, cfg: DiFuserConfig):
    """Lower ``cfg.model`` against ``g`` (must already be in serving edge
    order, i.e. dst-sorted) to device-ready jnp operands
    ``(src, dst, h, lo, thr)`` — everything the kernels consume besides the
    register matrix and x."""
    ep = resolve_model(cfg.model).edge_params(g, seed=cfg.seed)
    return (jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(ep.h),
            jnp.asarray(ep.lo), jnp.asarray(ep.thr))


def _init_registers(n_pad: int, n_real: int, num_regs: int) -> jnp.ndarray:
    m = jnp.zeros((n_pad, num_regs), jnp.int8)
    pad_rows = jnp.arange(n_pad)[:, None] >= n_real
    return jnp.where(pad_rows, jnp.int8(VISITED), m)


def _seed_rounds(m, src, dst, h, lo, thr, x, *, k, n_real, num_regs, seed,
                 estimator, impl, edge_chunk, max_prop, max_casc,
                 rebuild_threshold, predicate=None, cascade_chunk=0,
                 edge_block=0, reg_tile=0):
    """Alg. 4 lines 7-23: K rounds of {select, cascade, score, lazy-rebuild}
    starting from an already-propagated register matrix ``m``.

    Shared by the cold path (``find_seeds``) and the warm-start path
    (``find_seeds_warm`` / service.SketchStore) so both trace the identical
    round program — warm seeds are byte-identical to cold seeds.
    """

    def round_fn(carry, _):
        m, score, oldscore = carry
        sums = _select.local_sums(m, impl=impl)
        s, gain = _select.finish_select(sums, num_regs, n_real, estimator=estimator)
        m, _ = cascade_from_seed(m, s, src, dst, thr, x, h, lo, seed=seed,
                                 impl=impl,
                                 edge_chunk=cascade_chunk or edge_chunk,
                                 max_iters=max_casc, predicate=predicate,
                                 edge_block=edge_block, reg_tile=reg_tile)
        visited = count_visited(m, n_real).astype(jnp.float32)
        new_score = visited / jnp.float32(num_regs)
        rel = (new_score - oldscore) / jnp.maximum(new_score, 1e-9)
        do_rebuild = rel > rebuild_threshold

        def rebuild(m):
            m2 = ops.sketch_fill(m, reg_offset=0, seed=seed, impl=impl)
            m2, _ = propagate_to_fixpoint(m2, src, dst, thr, x, h, lo, seed=seed,
                                          impl=impl, edge_chunk=edge_chunk,
                                          max_iters=max_prop, predicate=predicate,
                                          edge_block=edge_block, reg_tile=reg_tile)
            return m2, new_score

        def keep(m):
            return m, oldscore

        m, oldscore = jax.lax.cond(do_rebuild, rebuild, keep, m)
        return (m, new_score, oldscore), (s, gain, new_score, do_rebuild)

    (_, _, _), outs = jax.lax.scan(round_fn, (m, jnp.float32(0.0), jnp.float32(0.0)),
                                   None, length=k)
    return outs  # (seeds, gains, scores, rebuilds)


def _build_matrix(src, dst, h, lo, thr, x, n_pad, *, n_real, num_regs, seed, impl,
                  edge_chunk, max_prop, reg_offset=0, predicate=None,
                  edge_block=0, reg_tile=0):
    """Alg. 4 lines 3-6: init + fill + propagate-to-fixpoint. Returns (m, iters)."""
    m = _init_registers(n_pad, n_real, num_regs)
    m = ops.sketch_fill(m, reg_offset=reg_offset, seed=seed, impl=impl)
    return propagate_to_fixpoint(
        m, src, dst, thr, x, h, lo, seed=seed, impl=impl, edge_chunk=edge_chunk,
        max_iters=max_prop, predicate=predicate, edge_block=edge_block,
        reg_tile=reg_tile)


def _find_seeds(src, dst, h, lo, thr, x, n_pad, *, k, n_real, num_regs, seed,
                estimator, impl, edge_chunk, max_prop, max_casc,
                rebuild_threshold, predicate=None, cascade_chunk=0,
                edge_block=0, reg_tile=0):
    m, build_iters = _build_matrix(
        src, dst, h, lo, thr, x, n_pad, n_real=n_real, num_regs=num_regs,
        seed=seed, impl=impl, edge_chunk=edge_chunk, max_prop=max_prop,
        predicate=predicate, edge_block=edge_block, reg_tile=reg_tile)
    seeds, gains, scores, rebuilds = _seed_rounds(
        m, src, dst, h, lo, thr, x, k=k, n_real=n_real, num_regs=num_regs,
        seed=seed, estimator=estimator, impl=impl, edge_chunk=edge_chunk,
        max_prop=max_prop, max_casc=max_casc,
        rebuild_threshold=rebuild_threshold, predicate=predicate,
        cascade_chunk=cascade_chunk, edge_block=edge_block, reg_tile=reg_tile)
    return seeds, gains, scores, rebuilds, build_iters


#: the performance-only tile statics shared by the jitted drivers
_TILE_STATICS = ("cascade_chunk", "edge_block", "reg_tile")

_find_seeds_jit = partial(jax.jit, static_argnames=(
    "k", "n_real", "n_pad", "num_regs", "seed", "estimator", "impl", "edge_chunk",
    "max_prop", "max_casc", "rebuild_threshold", "predicate") + _TILE_STATICS)(
    lambda src, dst, h, lo, thr, x, *, n_pad, **kw: _find_seeds(
        src, dst, h, lo, thr, x, n_pad, **kw))

_build_matrix_jit = partial(jax.jit, static_argnames=(
    "n_pad", "n_real", "num_regs", "seed", "impl", "edge_chunk", "max_prop",
    "reg_offset", "predicate", "edge_block", "reg_tile"))(
    lambda src, dst, h, lo, thr, x, *, n_pad, **kw: _build_matrix(
        src, dst, h, lo, thr, x, n_pad, **kw))

_seed_rounds_jit = partial(jax.jit, static_argnames=(
    "k", "n_real", "num_regs", "seed", "estimator", "impl", "edge_chunk",
    "max_prop", "max_casc", "rebuild_threshold", "predicate") + _TILE_STATICS)(
    _seed_rounds)


def _find_seeds_single(g: Graph, k: int, config: Optional[DiFuserConfig] = None,
                       x: Optional[np.ndarray] = None) -> InfluenceResult:
    """Single-device Alg. 4 driver (the ``single`` runtime backend's body).
    ``x`` overrides the random vector (the distributed tests use this to pin
    identical sample spaces)."""
    cfg = config or DiFuserConfig()
    g, x = normalize_inputs(g, cfg, x)
    src, dst, h, lo, thr = edge_operands(g, cfg)
    with trace.span("single.find_seeds", phase="select", k=k, n=g.n,
                    registers=cfg.num_registers, model=cfg.model) as sp:
        seeds, gains, scores, rebuilds, build_iters = sp.sync(_find_seeds_jit(
            src, dst, h, lo, thr, jnp.asarray(x),
            n_pad=g.n_pad, k=k, n_real=g.n, num_regs=cfg.num_registers,
            seed=cfg.seed, estimator=cfg.estimator, impl=cfg.impl,
            edge_chunk=cfg.edge_chunk, max_prop=cfg.max_propagate_iters,
            max_casc=cfg.max_cascade_iters,
            rebuild_threshold=cfg.rebuild_threshold,
            predicate=resolve_model(cfg.model).predicate,
            cascade_chunk=cfg.cascade_chunk, edge_block=cfg.edge_block,
            reg_tile=cfg.reg_tile))
    return InfluenceResult(
        seeds=np.asarray(seeds), est_gains=np.asarray(gains),
        scores=np.asarray(scores), rebuilds=np.asarray(rebuilds),
        propagate_iters=int(build_iters), x=np.asarray(x))


def find_seeds(g: Graph, k: int, config: Optional[DiFuserConfig] = None,
               x: Optional[np.ndarray] = None) -> InfluenceResult:
    """Deprecated entry point — prefer the unified runtime facade::

        from repro.runtime import InfluenceSession, RunSpec
        InfluenceSession(g, RunSpec.from_config(config)).find_seeds(k)

    Kept as a thin shim through the ``single`` backend; results are
    bit-identical to the historical direct call (golden-tested)."""
    from repro.runtime import run, warn_deprecated

    warn_deprecated("repro.core.difuser.find_seeds",
                    "repro.runtime.InfluenceSession.find_seeds")
    from repro.runtime.spec import RunSpec

    spec = RunSpec.from_config(config, backend="single")
    return run(g, k, spec, x=x).result


def normalize_x(cfg: DiFuserConfig, x: Optional[np.ndarray]) -> np.ndarray:
    """The x half of ``normalize_inputs`` (no graph work): default from the
    config seed, cast to uint32, FASST-sort."""
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
    x = np.asarray(x, dtype=np.uint32)
    return np.sort(x) if cfg.sort_x else x


def normalize_inputs(g: Graph, config: Optional[DiFuserConfig] = None,
                     x: Optional[np.ndarray] = None) -> tuple[Graph, np.ndarray]:
    """The host-side canonicalization ``find_seeds`` applies before tracing:
    FASST-sort the sample vector and lay edges out by destination. Idempotent,
    so callers that cache the results (service.SketchStore) and ``find_seeds``
    itself agree on the exact arrays."""
    cfg = config or DiFuserConfig()
    return g.sorted_by_dst(), normalize_x(cfg, x)


def build_sketch_matrix(g: Graph, config: Optional[DiFuserConfig] = None,
                        x: Optional[np.ndarray] = None, *, reg_offset: int = 0,
                        init_matrix=None, normalized: bool = False,
                        edges=None):
    """Run Alg. 4 lines 3-6 once: fill + propagate-to-fixpoint.

    Returns ``(matrix int8[n_pad, J], build_iters, x_used)`` where ``matrix``
    stays device-resident — the persistent index the service layer amortizes
    across queries. ``reg_offset`` offsets the register hash slots so a
    sample-space bank covering x[b*J_loc:(b+1)*J_loc] fills slots starting at
    b*J_loc (bank concatenation is bit-identical to one full build).
    ``init_matrix`` warm-starts the fixpoint from an existing matrix instead
    of a fresh fill — the monotone-insertion repair path (service.delta).
    ``normalized=True`` skips the host canonicalization when the caller
    already holds a dst-sorted graph and sorted x (per-bank store builds).
    ``edges``: optional precomputed ``(src, dst, h, lo, thr)`` device
    operands for the (already normalized) graph — multi-bank builds pass
    them so the O(m) model preprocessing runs once, not once per bank.
    """
    cfg = config or DiFuserConfig()
    if not normalized:
        g, x = normalize_inputs(g, cfg, x)
    src, dst, h, lo, thr = edges if edges is not None else edge_operands(g, cfg)
    predicate = resolve_model(cfg.model).predicate
    with trace.span("single.build_matrix", phase="build", n=g.n,
                    registers=int(x.shape[0]), reg_offset=reg_offset,
                    warm=init_matrix is not None) as sp:
        if init_matrix is None:
            m, iters = _build_matrix_jit(
                src, dst, h, lo, thr, jnp.asarray(x), n_pad=g.n_pad, n_real=g.n,
                num_regs=x.shape[0], seed=cfg.seed, impl=cfg.impl,
                edge_chunk=cfg.edge_chunk, max_prop=cfg.max_propagate_iters,
                reg_offset=reg_offset, predicate=predicate,
                edge_block=cfg.edge_block, reg_tile=cfg.reg_tile)
        else:
            m, iters = propagate_to_fixpoint(
                init_matrix, src, dst, thr, jnp.asarray(x), h, lo, seed=cfg.seed,
                impl=cfg.impl, edge_chunk=cfg.edge_chunk,
                max_iters=cfg.max_propagate_iters, predicate=predicate,
                edge_block=cfg.edge_block, reg_tile=cfg.reg_tile)
        sp.sync(m)
        sp.annotate(iters=int(iters))
    # bandwidth attribution: per sweep each real edge reads its ~20 B of
    # operands (src/dst/h/lo/thr) plus one int8 read + write per register
    nbytes = int(iters) * int(g.m_real) * (20 + 2 * int(x.shape[0]))
    roofline.annotate_bandwidth(sp, nbytes, sp.duration_s)
    return m, int(iters), x


def find_seeds_warm(g: Graph, k: int, config: Optional[DiFuserConfig] = None,
                    *, matrix, x: np.ndarray, edges=None) -> InfluenceResult:
    """Warm-start Alg. 4: skip fill + propagate and run the K seed rounds from
    an already-propagated register ``matrix`` (from ``build_sketch_matrix``
    with the same graph/config/x). The round loop is the identical traced
    program as ``find_seeds``'s, so the returned seed set is byte-identical
    to a cold run; only the build cost is amortized away.

    ``edges``: optional (src, dst, h, lo, thr) device arrays for an already
    dst-sorted ``g`` with ``x`` already normalized — the SketchStore fast
    path, skipping the per-query O(m log m) host sort and re-upload."""
    cfg = config or DiFuserConfig()
    if edges is None:
        g, x = normalize_inputs(g, cfg, x)
        edges = edge_operands(g, cfg)
    src, dst, h, lo, thr = edges
    with trace.span("single.warm_rounds", phase="select", k=k, n=g.n,
                    registers=int(x.shape[0])) as sp:
        seeds, gains, scores, rebuilds = sp.sync(_seed_rounds_jit(
            matrix, src, dst, h, lo, thr,
            jnp.asarray(x), k=k, n_real=g.n, num_regs=x.shape[0], seed=cfg.seed,
            estimator=cfg.estimator, impl=cfg.impl, edge_chunk=cfg.edge_chunk,
            max_prop=cfg.max_propagate_iters, max_casc=cfg.max_cascade_iters,
            rebuild_threshold=cfg.rebuild_threshold,
            predicate=resolve_model(cfg.model).predicate,
            cascade_chunk=cfg.cascade_chunk, edge_block=cfg.edge_block,
            reg_tile=cfg.reg_tile))
    return InfluenceResult(
        seeds=np.asarray(seeds), est_gains=np.asarray(gains),
        scores=np.asarray(scores), rebuilds=np.asarray(rebuilds),
        propagate_iters=0, x=np.asarray(x))
