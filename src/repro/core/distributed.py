"""Distributed DiFuseR (paper §4) on a JAX mesh, scaled past the paper.

Two partition modes, both SPMD under one ``shard_map``:

* ``sim`` — the paper's scheme. The sample space (registers) is sharded
  over the ``model`` axis; every shard holds all vertices plus its FASST
  device-local edge list. Zero communication in fill/propagate/cascade; one
  psum of the (2, n_pad) estimator statistics + one scalar psum per seed
  round (the paper's Fig. 3 reduction; its MPI BROADCAST disappears because
  every shard computes the identical argmax).

* ``2d`` — beyond the paper (its §6 names the O(n) reduction as the
  thousand-node blocker). Registers are sharded over ``model`` AND vertices
  over ``data``. Propagation needs remote registers, so each shard's edges
  are bucketed by the *read*-owner shard and a ring schedule walks the
  ``data`` axis: at step k the shard processes the bucket whose reads live
  in the register block that just arrived, then ``ppermute``s the block on.
  Compute overlaps communication; peak memory is two (n/P, J/S) blocks; the
  selection reduce shrinks from O(n) to O(n/P) + P scalars.

The host-side partition build lives in :mod:`repro.partition`: a
``PartitionPlan`` (``DistributedConfig.partition`` selects the strategy —
``block`` is the historical contiguous split, ``degree``/``edge`` balance
the per-shard work via a vertex relabeling permutation) feeds
``build_partition_2d``, which emits per-ring-step bucket arrays. The body
here stays plan-agnostic: it sweeps whatever buckets it is handed, and the
``owned_ids`` array (local row -> original vertex id) keeps register
hashes, validity masks, and the reported seeds in original-id space — so
seed sets are bit-identical across planners, and "un-permuting" on exit is
free.

The pod axis (multi-pod mesh) extends the sample space: ``pod × model``
shards form one flat sim axis (more simulations, same algorithm).

Bucket edges carry the precomputed fused-predicate operands (h, lo, thr) of
the configured diffusion model (hash once per edge instead of once per
sweep — legal for *every* registered model because h is sample-independent;
the fused decision still happens per (edge, register) on device through the
model's predicate).
"""
from __future__ import annotations

import dataclasses
import math
from time import perf_counter
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch
from repro.core.difuser import DiFuserConfig, InfluenceResult, resolve_model
from repro.core.sampling import fused_predicate
from repro.core.sampling import make_x_vector
from repro.core.sketch import VISITED
from repro.graphs.structs import Graph
from repro.obs import shardprof, trace
# host-side partition build moved to repro.partition; re-exported here for
# backward compatibility (tests and dryrun historically imported from core)
from repro.partition import (Partition2D, build_partition_2d,  # noqa: F401
                             plan_partition, sample_edge_sets)

# jax API drift guard (single source: utils/jax_compat.py, re-exported here):
# old containers ship a jax without jax.sharding.AxisType and its
# mesh/shard_map surface. Tests that need a multi-device mesh skip on this
# flag instead of erroring.
from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE  # noqa: F401

# ---------------------------------------------------------------------------
# Device-side shard_map body
# ---------------------------------------------------------------------------


def _bucket_sweep_propagate(acc, block, h, w, r, t, x_loc, lo=None, predicate=None):
    """Jacobi max-merge for one bucket: acc[w] <- max(acc[w], masked block[r])."""
    if lo is None:
        lo = jnp.zeros(t.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    mask = predicate(h[:, None].astype(jnp.uint32), lo[:, None].astype(jnp.uint32),
                     t[:, None].astype(jnp.uint32), x_loc[None, :].astype(jnp.uint32))
    vals = block[r]
    contrib = jnp.where(mask, vals, jnp.int8(VISITED))
    return acc.at[w].max(contrib)


def _bucket_sweep_cascade(acc_vis, block, h, w, r, t, x_loc, lo=None, predicate=None):
    if lo is None:
        lo = jnp.zeros(t.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    mask = predicate(h[:, None].astype(jnp.uint32), lo[:, None].astype(jnp.uint32),
                     t[:, None].astype(jnp.uint32), x_loc[None, :].astype(jnp.uint32))
    newly = jnp.logical_and(mask, block[r] == VISITED).astype(jnp.uint8)
    return acc_vis.at[w].max(newly)


def _make_distributed_fn(part: Partition2D, *, k: int, vertex_axis: str,
                         sim_axes: Sequence[str], estimator: str,
                         rebuild_threshold: float, max_prop: int, max_casc: int,
                         seed: int, schedule: str = "ring", local_sweeps: int = 0,
                         fuse_sweeps: bool = False,
                         predicate=None, warm: bool = False):
    """Returns the shard_map body running the full Alg. 4 loop.

    Bucket arrays arrive as per-ring-step tuples (``bh[kk]`` is step kk's
    bucket, possibly width 0 — those steps skip their merge at trace time
    but still forward the ring block).

    ``warm=True`` makes the body take each shard's already-propagated
    register block as its first argument and skip fill + the initial
    propagate fixpoint — the device twin of ``core.difuser.find_seeds_warm``
    (the K seed rounds are the identical program either way, so warm mesh
    seeds are bit-identical to cold mesh seeds, which are bit-identical to
    the single-device reference).
    """
    mu_v, mu_s = part.mu_v, part.mu_s
    n_loc, j_loc, n_real = part.n_loc, part.j_loc, part.n
    total_regs = mu_s * j_loc
    all_axes = (vertex_axis, *sim_axes)
    pred = predicate if predicate is not None else fused_predicate

    def local_sweep(m_loc, bh, bw, br, bt, bl, x_loc, merge):
        """Sweep only the k=0 bucket (reads own register block; no comm)."""
        init = m_loc if merge is _bucket_sweep_propagate else (m_loc == VISITED).astype(jnp.uint8)
        acc = init
        if bh[0].shape[0]:
            acc = merge(acc, m_loc, bh[0], bw[0], br[0], bt[0], x_loc, bl[0], pred)
        if merge is _bucket_sweep_propagate:
            return jnp.where(m_loc == VISITED, m_loc, acc)
        return jnp.where(acc.astype(bool), jnp.int8(VISITED), m_loc)

    def ring_sweep(m_loc, bh, bw, br, bt, bl, x_loc, merge):
        """One full sweep: mu_v ring steps over the data axis."""
        init = m_loc if merge is _bucket_sweep_propagate else (m_loc == VISITED).astype(jnp.uint8)
        acc = init
        if schedule == "allgather" and mu_v > 1:
            # baseline schedule: materialize all blocks, no overlap
            blocks = jax.lax.all_gather(m_loc, vertex_axis)  # (mu_v, n_loc, j_loc)
            me = jax.lax.axis_index(vertex_axis)
            for kk in range(mu_v):
                if bh[kk].shape[0] == 0:
                    continue
                owner = jax.lax.rem(me + kk, mu_v)
                acc = merge(acc, blocks[owner], bh[kk], bw[kk], br[kk], bt[kk],
                            x_loc, bl[kk], pred)
        else:
            block = m_loc
            for kk in range(mu_v):
                if bh[kk].shape[0]:
                    acc = merge(acc, block, bh[kk], bw[kk], br[kk], bt[kk],
                                x_loc, bl[kk], pred)
                if kk + 1 < mu_v:
                    perm = [(i, (i - 1) % mu_v) for i in range(mu_v)]
                    block = jax.lax.ppermute(block, vertex_axis, perm)
        if merge is _bucket_sweep_propagate:
            return jnp.where(m_loc == VISITED, m_loc, acc)
        return jnp.where(acc.astype(bool), jnp.int8(VISITED), m_loc)

    def fixpoint(m_loc, bh, bw, br, bt, bl, x_loc, merge, max_iters):
        def cond(c):
            return jnp.logical_and(c[1], c[2] < max_iters)

        def body(c):
            m_cur, _, it = c
            # block-Jacobi: drain intra-shard propagation before paying for
            # a ring exchange (edges FASST-placed mostly intra-shard, so a
            # few local sweeps kill most of the frontier; §Perf difuser)
            if fuse_sweeps and local_sweeps:
                # fused prologue: one rolled loop region instead of
                # local_sweeps unrolled program segments — the register
                # block stays loop-carried (resident) across every sweep
                m_cur = jax.lax.fori_loop(
                    0, local_sweeps,
                    lambda _i, mm: local_sweep(mm, bh, bw, br, bt, bl,
                                               x_loc, merge),
                    m_cur)
            else:
                for _ in range(local_sweeps):
                    m_cur = local_sweep(m_cur, bh, bw, br, bt, bl, x_loc, merge)
            m_new = ring_sweep(m_cur, bh, bw, br, bt, bl, x_loc, merge)
            changed = jax.lax.psum(jnp.any(m_new != m_cur).astype(jnp.int32), all_axes) > 0
            return m_new, changed, it + 1

        m_out, _, iters = jax.lax.while_loop(cond, body, (m_loc, jnp.bool_(True), jnp.int32(0)))
        return m_out, iters

    def body(*all_args):
        if warm:
            m_in, x_loc, owned, *bufs = all_args
        else:
            m_in = None
            x_loc, owned, *bufs = all_args

        # regroup the flat per-step bucket args: 10 fields x mu_v steps
        def grp(i):
            return tuple(bufs[i * mu_v + kk][0, 0] for kk in range(mu_v))

        ph, pw, pr, pt, pl = grp(0), grp(1), grp(2), grp(3), grp(4)
        ch, cw, cr, ct, cl = grp(5), grp(6), grp(7), grp(8), grp(9)
        x_loc = x_loc[0]
        owned = owned[0]                 # (n_loc,) original vertex ids
        # local shard coordinates; sim axes flatten row-major (pod major)
        si = jnp.int32(0)
        mult = 1
        for ax in reversed(sim_axes):
            si = si + jax.lax.axis_index(ax) * mult
            mult *= _axis_size(ax)
        reg_offset = si * j_loc
        valid_row = owned < n_real

        # ---- fill + initial propagate (Alg. 4 lines 3-6) ----
        # register hashes key on the ORIGINAL vertex id, so the sketch
        # content — and everything downstream — is independent of the plan's
        # relabeling permutation
        j_ids = (jnp.arange(j_loc, dtype=jnp.uint32)[None, :] + reg_offset.astype(jnp.uint32))
        from repro.core.sampling import register_hash

        fresh = jax.lax.clz(register_hash(owned.astype(jnp.uint32)[:, None], j_ids, seed=seed))

        def refill(m_cur):
            return jnp.where(m_cur == VISITED, m_cur, fresh.astype(jnp.int8))

        if warm:
            # warm start: the caller's block IS the propagated fixpoint
            # (fresh is still needed above for the lazy-rebuild refill)
            m_loc, build_iters = m_in, jnp.int32(0)
        else:
            m_loc = jnp.where(valid_row[:, None], fresh.astype(jnp.int8),
                              jnp.int8(VISITED))
            m_loc, build_iters = fixpoint(m_loc, ph, pw, pr, pt, pl, x_loc,
                                          _bucket_sweep_propagate, max_prop)

        # ---- K seed rounds ----
        def round_fn(carry, _):
            m_cur, score, oldscore = carry
            # selection: psum stats over sim axes -> exact for owned rows
            stats = jnp.stack([
                jnp.sum(jnp.where(m_cur != VISITED, jnp.exp2(-m_cur.astype(jnp.float32)), 0.0), axis=-1),
                jnp.sum(m_cur != VISITED, axis=-1).astype(jnp.float32)])
            stats = jax.lax.psum(stats, tuple(sim_axes)) if sim_axes else stats
            est = sketch.estimate_from_sums(stats, total_regs, estimator=estimator)
            est = jnp.where(valid_row, est, -1.0)
            # min-original-id tie-break: under a relabeling plan, ids are
            # scattered across shards, so plain argmax (lowest local row)
            # would break bit-identity between planners on est ties
            loc_best = jnp.max(est)
            loc_seed = jnp.min(jnp.where(est == loc_best, owned,
                                         jnp.int32(part.n_pad)))
            # cross-shard argmax: P scalars instead of the paper's O(n) vector
            bests = jax.lax.all_gather(loc_best, vertex_axis)        # (mu_v,)
            seeds_g = jax.lax.all_gather(loc_seed, vertex_axis)      # (mu_v,)
            gain = jnp.max(bests)
            s_global = jnp.min(jnp.where(bests == gain, seeds_g,
                                         jnp.int32(part.n_pad)))
            # commit + cascade
            m_cur = jnp.where((owned == s_global)[:, None], jnp.int8(VISITED), m_cur)
            m_cur, _ = fixpoint(m_cur, ch, cw, cr, ct, cl, x_loc,
                                _bucket_sweep_cascade, max_casc)
            visited = jnp.sum(jnp.logical_and(m_cur == VISITED, valid_row[:, None]).astype(jnp.int32))
            visited = jax.lax.psum(visited, all_axes).astype(jnp.float32)
            new_score = visited / jnp.float32(total_regs)
            rel = (new_score - oldscore) / jnp.maximum(new_score, 1e-9)

            def rebuild(mm):
                mm = refill(mm)
                mm, _ = fixpoint(mm, ph, pw, pr, pt, pl, x_loc,
                                 _bucket_sweep_propagate, max_prop)
                return mm, new_score

            def keep(mm):
                return mm, oldscore

            m_cur, oldscore = jax.lax.cond(rel > rebuild_threshold, rebuild, keep, m_cur)
            return (m_cur, new_score, oldscore), (s_global, gain, new_score, rel > rebuild_threshold)

        (_, _, _), outs = jax.lax.scan(round_fn, (m_loc, jnp.float32(0.0), jnp.float32(0.0)),
                                       None, length=k)
        seeds_out, gains, scores, rebuilds = outs
        return seeds_out, gains, scores, rebuilds, build_iters

    # helper resolved at trace time inside shard_map
    _axis_sizes: dict[str, int] = {}

    def _axis_size(ax: str) -> int:
        return _axis_sizes[ax]

    def with_sizes(mesh):
        for ax in (vertex_axis, *sim_axes):
            _axis_sizes[ax] = mesh.shape[ax]
        return body

    return with_sizes


@dataclasses.dataclass(frozen=True)
class DistributedConfig(DiFuserConfig):
    vertex_axis: str = "data"
    sim_axes: tuple = ("model",)
    schedule: str = "ring"          # "ring" | "allgather"
    fasst: bool = True              # False -> naive sample partition
    local_sweeps: int = 0           # extra comm-free sweeps per exchange
    fuse_sweeps: bool = False       # fused (rolled) local-sweep prologue
    lane_fill: int = 0              # fused-kernel register slab width
    #   (consumed by the kernels/fused_sweep launches; the shard_map body
    #   itself keeps full-width panes — its shards are already lane-sized)
    partition: str = "block"        # vertex-assignment strategy (repro.partition)
    pad_mode: str = "step"          # "step" | "global" bucket padding


def _publish_mesh_profile(part, *, phase: str, sweeps: int, wall_s: float,
                          span) -> None:
    """Measured-profile publication for the SPMD paths. Mesh shards execute
    in lockstep inside one XLA program, so per-shard wall time is not
    separable host-side — the profile carries exact per-(shard, ring step)
    bucket *bytes* (off the built partition's counts, scaled by the sweep
    count the fixpoint ran) plus the overall wall time
    (``per_step_timed=False``; the serial twin supplies measured times)."""
    if not shardprof.enabled():
        return
    from repro.utils import roofline

    prof = shardprof.profile_for_partition(part, backend="mesh", phase=phase)
    prof.add_partition_bytes(np.asarray(part.p_counts), part.j_loc, sweeps)
    predicted = part.plan.predicted if part.plan is not None else None
    mp = shardprof.publish(prof.finish(wall_s), predicted=predicted)
    roofline.annotate_bandwidth(span, int(mp.step_bytes.sum()), wall_s)


def _find_seeds_distributed(g: Graph, k: int, mesh,
                            config: Optional[DistributedConfig] = None,
                            x: Optional[np.ndarray] = None, plan=None):
    """shard_map Alg. 4 driver (the ``mesh`` runtime backend's body).
    Returns (InfluenceResult, Partition2D).

    Seeds/estimates come back in original vertex ids for every
    ``cfg.partition`` strategy (the relabeling is un-permuted on device via
    ``owned_ids``). ``plan`` overrides the ``cfg.partition``-derived
    :class:`PartitionPlan` (results are plan-invariant either way).
    """
    from jax.sharding import PartitionSpec as P

    cfg = config or DistributedConfig()
    mu_v = mesh.shape[cfg.vertex_axis]
    mu_s = math.prod(mesh.shape[ax] for ax in cfg.sim_axes)
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
    g = g.sorted_by_dst()
    method = "fasst" if cfg.fasst else "naive"
    # the O(m * mu_s) sampled-edge preprocessing feeds both the planner and
    # the bucket build — run it once
    sampled = sample_edge_sets(g, x, mu_s, seed=cfg.seed, model=cfg.model,
                               method=method)
    if plan is None:
        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy=cfg.partition,
                              seed=cfg.seed, model=cfg.model, method=method,
                              sampled=sampled)
    part = build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed, method=method,
                              model=cfg.model, plan=plan, pad_mode=cfg.pad_mode,
                              sampled=sampled)

    maker = _make_distributed_fn(
        part, k=k, vertex_axis=cfg.vertex_axis, sim_axes=tuple(cfg.sim_axes),
        estimator=cfg.estimator, rebuild_threshold=cfg.rebuild_threshold,
        max_prop=cfg.max_propagate_iters, max_casc=cfg.max_cascade_iters,
        seed=cfg.seed, schedule=cfg.schedule, local_sweeps=cfg.local_sweeps,
        fuse_sweeps=cfg.fuse_sweeps,
        predicate=resolve_model(cfg.model).predicate)
    body = maker(mesh)

    sim_spec = cfg.sim_axes if len(cfg.sim_axes) > 1 else cfg.sim_axes[0]
    bucket_spec = P(cfg.vertex_axis, sim_spec, None)
    n_buckets = 10 * part.mu_v
    in_specs = (P(sim_spec, None), P(cfg.vertex_axis, None)) + (bucket_spec,) * n_buckets
    out_specs = (P(), P(), P(), P(), P())

    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False))
    # reshape x_shards so sim axes shard dim 0: (mu_s, j_loc)
    args = [jnp.asarray(part.x_shards), jnp.asarray(part.owned_ids)]
    for field in (part.p_h, part.p_w, part.p_r, part.p_t, part.p_l,
                  part.c_h, part.c_w, part.c_r, part.c_t, part.c_l):
        for step in field:
            args.append(jnp.asarray(step))
    t0 = perf_counter()
    with trace.span("mesh.find_seeds", phase="select", k=k, mu_v=mu_v,
                    mu_s=mu_s, schedule=cfg.schedule) as sp:
        seeds, gains, scores, rebuilds, build_iters = sp.sync(fn(*args))
    _publish_mesh_profile(part, phase="select", sweeps=int(build_iters),
                          wall_s=perf_counter() - t0, span=sp)
    res = InfluenceResult(
        seeds=np.asarray(seeds), est_gains=np.asarray(gains), scores=np.asarray(scores),
        rebuilds=np.asarray(rebuilds), propagate_iters=int(build_iters),
        x=np.sort(x) if cfg.fasst else x)
    return res, part


def find_seeds_distributed(g: Graph, k: int, mesh,
                           config: Optional[DistributedConfig] = None,
                           x: Optional[np.ndarray] = None):
    """Deprecated entry point — prefer the unified runtime facade::

        from repro.runtime import InfluenceSession, RunSpec
        InfluenceSession(g, RunSpec.from_config(config), mesh=mesh).find_seeds(k)

    Kept as a thin shim through the ``mesh`` backend; results are
    bit-identical to the historical direct call (golden-tested). Returns
    (InfluenceResult, Partition2D) like before."""
    from repro.runtime import run, warn_deprecated
    from repro.runtime.spec import RunSpec

    warn_deprecated("repro.core.distributed.find_seeds_distributed",
                    "repro.runtime.InfluenceSession.find_seeds")
    spec = RunSpec.from_config(config or DistributedConfig(), backend="mesh")
    report = run(g, k, spec, x=x, mesh=mesh)
    return report.result, report.partition


# ---------------------------------------------------------------------------
# Build-only shard_map path (store banks on a mesh)
# ---------------------------------------------------------------------------


def _make_build_matrix_fn(part: Partition2D, *, vertex_axis: str,
                          sim_axes: Sequence[str], max_prop: int, seed: int,
                          schedule: str = "ring", local_sweeps: int = 0,
                          fuse_sweeps: bool = False,
                          predicate=None, reg_offset: int = 0):
    """Returns the shard_map body running only Alg. 4 lines 3-6 (fill +
    propagate-to-fixpoint) and handing back each shard's register block.

    The sweep/fixpoint machinery mirrors ``_make_distributed_fn`` (its
    device twin is the full loop); ``reg_offset`` offsets the register hash
    slots so sample-space store banks concatenate bit-identically to one
    monolithic build (same contract as ``ops.sketch_fill``).
    """
    mu_v = part.mu_v
    j_loc, n_real = part.j_loc, part.n
    pred = predicate if predicate is not None else fused_predicate

    def ring_sweep(m_loc, bh, bw, br, bt, bl, x_loc):
        acc = m_loc
        if schedule == "allgather" and mu_v > 1:
            blocks = jax.lax.all_gather(m_loc, vertex_axis)
            me = jax.lax.axis_index(vertex_axis)
            for kk in range(mu_v):
                if bh[kk].shape[0] == 0:
                    continue
                owner = jax.lax.rem(me + kk, mu_v)
                acc = _bucket_sweep_propagate(acc, blocks[owner], bh[kk], bw[kk],
                                              br[kk], bt[kk], x_loc, bl[kk], pred)
        else:
            block = m_loc
            for kk in range(mu_v):
                if bh[kk].shape[0]:
                    acc = _bucket_sweep_propagate(acc, block, bh[kk], bw[kk],
                                                  br[kk], bt[kk], x_loc, bl[kk],
                                                  pred)
                if kk + 1 < mu_v:
                    perm = [(i, (i - 1) % mu_v) for i in range(mu_v)]
                    block = jax.lax.ppermute(block, vertex_axis, perm)
        return jnp.where(m_loc == VISITED, m_loc, acc)

    def local_sweep(m_loc, bh, bw, br, bt, bl, x_loc):
        acc = m_loc
        if bh[0].shape[0]:
            acc = _bucket_sweep_propagate(acc, m_loc, bh[0], bw[0], br[0],
                                          bt[0], x_loc, bl[0], pred)
        return jnp.where(m_loc == VISITED, m_loc, acc)

    def body(x_loc, owned, *bufs):
        def grp(i):
            return tuple(bufs[i * mu_v + kk][0, 0] for kk in range(mu_v))

        ph, pw, pr, pt, pl = grp(0), grp(1), grp(2), grp(3), grp(4)
        x_loc = x_loc[0]
        owned = owned[0]
        all_axes = (vertex_axis, *sim_axes)
        si = jnp.int32(0)
        mult = 1
        for ax in reversed(sim_axes):
            si = si + jax.lax.axis_index(ax) * mult
            mult *= _axis_sizes[ax]
        valid_row = owned < n_real
        from repro.core.sampling import register_hash

        j_ids = (jnp.arange(j_loc, dtype=jnp.uint32)[None, :]
                 + (si * j_loc + reg_offset).astype(jnp.uint32))
        fresh = jax.lax.clz(register_hash(owned.astype(jnp.uint32)[:, None],
                                          j_ids, seed=seed))
        m_loc = jnp.where(valid_row[:, None], fresh.astype(jnp.int8),
                          jnp.int8(VISITED))

        def cond(c):
            return jnp.logical_and(c[1], c[2] < max_prop)

        def loop_body(c):
            m_cur, _, it = c
            if fuse_sweeps and local_sweeps:
                m_cur = jax.lax.fori_loop(
                    0, local_sweeps,
                    lambda _i, mm: local_sweep(mm, ph, pw, pr, pt, pl, x_loc),
                    m_cur)
            else:
                for _ in range(local_sweeps):
                    m_cur = local_sweep(m_cur, ph, pw, pr, pt, pl, x_loc)
            m_new = ring_sweep(m_cur, ph, pw, pr, pt, pl, x_loc)
            changed = jax.lax.psum(jnp.any(m_new != m_cur).astype(jnp.int32),
                                   all_axes) > 0
            return m_new, changed, it + 1

        m_loc, _, iters = jax.lax.while_loop(
            cond, loop_body, (m_loc, jnp.bool_(True), jnp.int32(0)))
        return m_loc, iters

    _axis_sizes: dict[str, int] = {}

    def with_sizes(mesh):
        for ax in (vertex_axis, *sim_axes):
            _axis_sizes[ax] = mesh.shape[ax]
        return body

    return with_sizes


def build_matrix_distributed(g: Graph, mesh,
                             config: Optional[DistributedConfig] = None,
                             x: Optional[np.ndarray] = None, *,
                             reg_offset: int = 0, plan=None):
    """Alg. 4 lines 3-6 under shard_map: fill + propagate-to-fixpoint on the
    2-D partition, gathered back to the canonical layout.

    Expects ``g`` dst-sorted and ``x`` canonical (sorted when FASST) — the
    normalized inputs the store/backend layer already holds. Returns
    ``(matrix int8[g.n_pad, len(x)], iters, Partition2D)`` where ``matrix``
    rows are in original-id order (the plan's relabeling is un-permuted on
    host), bit-identical to the single-device ``build_sketch_matrix``.
    """
    from jax.sharding import PartitionSpec as P

    cfg = config or DistributedConfig()
    mu_v = mesh.shape[cfg.vertex_axis]
    mu_s = math.prod(mesh.shape[ax] for ax in cfg.sim_axes)
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
        if cfg.fasst:
            x = np.sort(x)
    x = np.asarray(x, dtype=np.uint32)
    method = "fasst" if cfg.fasst else "naive"
    sampled = sample_edge_sets(g, x, mu_s, seed=cfg.seed, model=cfg.model,
                               method=method)
    if plan is None:
        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy=cfg.partition,
                              seed=cfg.seed, model=cfg.model, method=method,
                              sampled=sampled)
    part = build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed, method=method,
                              model=cfg.model, plan=plan, pad_mode=cfg.pad_mode,
                              sampled=sampled)
    maker = _make_build_matrix_fn(
        part, vertex_axis=cfg.vertex_axis, sim_axes=tuple(cfg.sim_axes),
        max_prop=cfg.max_propagate_iters, seed=cfg.seed, schedule=cfg.schedule,
        local_sweeps=cfg.local_sweeps, fuse_sweeps=cfg.fuse_sweeps,
        predicate=resolve_model(cfg.model).predicate, reg_offset=reg_offset)
    body = maker(mesh)

    sim_spec = cfg.sim_axes if len(cfg.sim_axes) > 1 else cfg.sim_axes[0]
    bucket_spec = P(cfg.vertex_axis, sim_spec, None)
    in_specs = ((P(sim_spec, None), P(cfg.vertex_axis, None))
                + (bucket_spec,) * (5 * part.mu_v))
    out_specs = (P(cfg.vertex_axis, sim_spec), P())
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False))
    args = [jnp.asarray(part.x_shards), jnp.asarray(part.owned_ids)]
    for field in (part.p_h, part.p_w, part.p_r, part.p_t, part.p_l):
        for step in field:
            args.append(jnp.asarray(step))
    t0 = perf_counter()
    with trace.span("mesh.build_matrix", phase="build", mu_v=mu_v,
                    mu_s=mu_s, reg_offset=reg_offset) as sp:
        m_planned, iters = sp.sync(fn(*args))
        # un-permute planned rows back to original-id (canonical) order
        m_canon = sp.sync(m_planned[jnp.asarray(part.plan.perm[: g.n_pad])])
    _publish_mesh_profile(part, phase="build", sweeps=int(iters),
                          wall_s=perf_counter() - t0, span=sp)
    return m_canon, int(iters), part


# ---------------------------------------------------------------------------
# Device-resident serving paths: warm seed rounds + shard-restricted repair
# on a plan-order matrix that already lives on the mesh (docs/service.md,
# "Sharded serving"). Both consume the matrix through an
# ``in_specs=P(vertex_axis, sim_spec)`` slot, so a bank placed with
# ``NamedSharding`` is used where it sits — no gather to host order.
# ---------------------------------------------------------------------------


def _sim_spec(sim_axes):
    """PartitionSpec entry for the sample-space dim: the axis tuple, a bare
    axis name, or None for a vertex-only serving mesh."""
    if len(sim_axes) > 1:
        return tuple(sim_axes)
    return sim_axes[0] if sim_axes else None


def _partition_for_plan(g: Graph, mesh, cfg: DistributedConfig,
                        x: np.ndarray, plan):
    """Build the bucket arrays of ``plan`` for ``mesh``'s shard grid."""
    mu_v = mesh.shape[cfg.vertex_axis]
    mu_s = math.prod(mesh.shape[ax] for ax in cfg.sim_axes)
    if plan.mu_v != mu_v:
        raise ValueError(f"plan has mu_v={plan.mu_v} but the mesh's "
                         f"{cfg.vertex_axis!r} axis is {mu_v}-way")
    x = np.asarray(x, dtype=np.uint32)
    method = "fasst" if cfg.fasst else "naive"
    sampled = sample_edge_sets(g, x, mu_s, seed=cfg.seed, model=cfg.model,
                               method=method)
    return build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed, method=method,
                              model=cfg.model, plan=plan,
                              pad_mode=cfg.pad_mode, sampled=sampled)


def find_seeds_warm_distributed(g: Graph, k: int, mesh,
                                config: Optional[DistributedConfig],
                                planned_m, plan,
                                x: np.ndarray, *,
                                part: Optional[Partition2D] = None
                                ) -> InfluenceResult:
    """Warm-start Alg. 4 under shard_map: skip fill + propagate and run the
    K seed rounds from an already-propagated plan-order register matrix
    (``StoreEntry.planned_matrix()``) sharded — or shardable — over the
    mesh's vertex axis. The round program is the warm twin of
    ``_find_seeds_distributed``'s, so seeds are bit-identical to the
    single-device ``find_seeds_warm`` (backend-invariance contract).
    ``part`` passes a pre-built bucket partition of the same (graph, plan,
    x) in — the O(m · mu_s) host preprocessing is the dominant warm-serving
    cost, so repeat callers (the store's device TopKSeeds path) cache it
    against the entry version instead of paying it per query.
    """
    from jax.sharding import PartitionSpec as P

    cfg = config or DistributedConfig()
    if part is None:
        part = _partition_for_plan(g, mesh, cfg, x, plan)
    x = np.asarray(x, dtype=np.uint32)
    maker = _make_distributed_fn(
        part, k=k, vertex_axis=cfg.vertex_axis, sim_axes=tuple(cfg.sim_axes),
        estimator=cfg.estimator, rebuild_threshold=cfg.rebuild_threshold,
        max_prop=cfg.max_propagate_iters, max_casc=cfg.max_cascade_iters,
        seed=cfg.seed, schedule=cfg.schedule, local_sweeps=cfg.local_sweeps,
        fuse_sweeps=cfg.fuse_sweeps,
        predicate=resolve_model(cfg.model).predicate, warm=True)
    body = maker(mesh)

    sim_spec = _sim_spec(cfg.sim_axes)
    bucket_spec = P(cfg.vertex_axis, sim_spec, None)
    in_specs = ((P(cfg.vertex_axis, sim_spec), P(sim_spec, None),
                 P(cfg.vertex_axis, None)) + (bucket_spec,) * (10 * part.mu_v))
    out_specs = (P(), P(), P(), P(), P())
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False))
    args = [jnp.asarray(planned_m, jnp.int8), jnp.asarray(part.x_shards),
            jnp.asarray(part.owned_ids)]
    for field in (part.p_h, part.p_w, part.p_r, part.p_t, part.p_l,
                  part.c_h, part.c_w, part.c_r, part.c_t, part.c_l):
        for step in field:
            args.append(jnp.asarray(step))
    with trace.span("mesh.warm_rounds", phase="select", k=k,
                    mu_v=part.mu_v, mu_s=part.mu_s) as sp:
        seeds, gains, scores, rebuilds, _ = sp.sync(fn(*args))
    return InfluenceResult(
        seeds=np.asarray(seeds), est_gains=np.asarray(gains),
        scores=np.asarray(scores), rebuilds=np.asarray(rebuilds),
        propagate_iters=0, x=np.sort(x) if cfg.fasst else x)


def _make_repair_fn(part: Partition2D, *, vertex_axis: str,
                    sim_axes: Sequence[str], max_prop: int, predicate=None):
    """Returns the shard_map body of the frontier-restricted repair — the
    device twin of ``partition.serial._RingState.sweep_propagate_restricted``.

    Carries a replicated ``dirty`` bool[mu_v] vector: each ring-step merge
    is applied only when the block being read belongs to a dirty shard
    (sound, because starting from a lower bound of the fixpoint, changes can
    only originate at rows the dirtied shards feed); the per-sweep changed
    flags (one psum over the sim axes + one all_gather over the vertex axis)
    become the next sweep's dirty set, so the repair widens exactly where
    changes actually spread and stops when nothing moved.
    """
    mu_v = part.mu_v
    pred = predicate if predicate is not None else fused_predicate

    def body(m_in, dirty0, x_loc, *bufs):
        def grp(i):
            return tuple(bufs[i * mu_v + kk][0, 0] for kk in range(mu_v))

        ph, pw, pr, pt, pl = grp(0), grp(1), grp(2), grp(3), grp(4)
        x_loc = x_loc[0]
        me = jax.lax.axis_index(vertex_axis)

        def cond(c):
            _, dirty, _, it = c
            return jnp.logical_and(jnp.any(dirty), it < max_prop)

        def sweep(c):
            m_cur, dirty, swept, it = c
            swept = jnp.logical_or(swept, dirty)
            acc = m_cur
            block = m_cur
            for kk in range(mu_v):
                if ph[kk].shape[0]:
                    owner = jax.lax.rem(me + kk, mu_v)
                    merged = _bucket_sweep_propagate(
                        acc, block, ph[kk], pw[kk], pr[kk], pt[kk], x_loc,
                        pl[kk], pred)
                    acc = jnp.where(dirty[owner], merged, acc)
                if kk + 1 < mu_v:
                    perm = [(i, (i - 1) % mu_v) for i in range(mu_v)]
                    block = jax.lax.ppermute(block, vertex_axis, perm)
            m_new = jnp.where(m_cur == VISITED, m_cur, acc)
            changed = jnp.any(m_new != m_cur).astype(jnp.int32)
            if sim_axes:   # OR across this vertex shard's sim siblings
                changed = jax.lax.psum(changed, tuple(sim_axes))
            dirty_new = jax.lax.all_gather(changed > 0, vertex_axis)
            return m_new, dirty_new, swept, it + 1

        zeros = jnp.zeros((mu_v,), jnp.bool_)
        m_out, _, swept, sweeps = jax.lax.while_loop(
            cond, sweep, (m_in, dirty0, zeros, jnp.int32(0)))
        return m_out, swept, sweeps

    return body


def repair_plan_shards_distributed(g: Graph, mesh,
                                   config: Optional[DistributedConfig],
                                   x: np.ndarray, planned_m, plan, touched):
    """Shard-restricted monotone insertion repair under shard_map — the
    ``mesh`` backend's twin of ``partition.serial.repair_plan_shards``.

    ``planned_m`` is the pre-delta plan-order matrix (device-resident banks
    pass straight through; the in_spec matches their ``NamedSharding`` row
    placement so no cross-host gather happens), ``g`` the post-delta
    dst-sorted graph, ``touched`` the plan shards the delta's endpoints land
    in. Returns ``(planned_matrix, sweeps, shards_swept)`` with the matrix
    still sharded over the vertex axis, bit-identical to a full rebuild (and
    to the serial repair) by fixpoint uniqueness above a sound lower bound.
    """
    from jax.sharding import PartitionSpec as P

    cfg = config or DistributedConfig()
    part = _partition_for_plan(g, mesh, cfg, x, plan)
    body = _make_repair_fn(
        part, vertex_axis=cfg.vertex_axis, sim_axes=tuple(cfg.sim_axes),
        max_prop=cfg.max_propagate_iters,
        predicate=resolve_model(cfg.model).predicate)

    sim_spec = _sim_spec(cfg.sim_axes)
    bucket_spec = P(cfg.vertex_axis, sim_spec, None)
    in_specs = ((P(cfg.vertex_axis, sim_spec), P(None), P(sim_spec, None))
                + (bucket_spec,) * (5 * part.mu_v))
    out_specs = (P(cfg.vertex_axis, sim_spec), P(), P())
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False))
    dirty0 = np.zeros(part.mu_v, dtype=bool)
    dirty0[np.asarray(list(touched), dtype=np.int64)] = True
    args = [jnp.asarray(planned_m, jnp.int8), jnp.asarray(dirty0),
            jnp.asarray(part.x_shards)]
    for field in (part.p_h, part.p_w, part.p_r, part.p_t, part.p_l):
        for step in field:
            args.append(jnp.asarray(step))
    with trace.span("mesh.repair", phase="repair",
                    touched=len(tuple(touched))) as sp:
        m_out, swept, sweeps = sp.sync(fn(*args))
        sp.annotate(sweeps=int(sweeps))
    swept_t = tuple(int(v) for v in np.nonzero(np.asarray(swept))[0])
    return m_out, int(sweeps), swept_t
