"""Distributed DiFuseR (paper §4) on a JAX mesh, scaled past the paper.

Two partition modes, both SPMD under one ``shard_map``:

* ``sim`` — the paper's scheme. The sample space (registers) is sharded
  over the ``model`` axis; every shard holds all vertices plus its FASST
  device-local edge list. Zero communication in fill/propagate/cascade; one
  psum of the (2, n_pad) estimator statistics + one scalar psum per seed
  round (the paper's Fig. 3 reduction; its MPI BROADCAST disappears because
  every shard computes the identical argmax).

* ``2d`` — beyond the paper (its §6 names the O(n) reduction as the
  thousand-node blocker). Registers are sharded over ``model`` AND vertices
  over ``data``. Propagation needs remote registers, so each shard's edges
  are bucketed by the *read*-owner shard and a ring schedule walks the
  ``data`` axis: at step k the shard processes the bucket whose reads live
  in the register block that just arrived, then ``ppermute``s the block on.
  Compute overlaps communication; peak memory is two (n/P, J/S) blocks; the
  selection reduce shrinks from O(n) to O(n/P) + P scalars.

The pod axis (multi-pod mesh) extends the sample space: ``pod × model``
shards form one flat sim axis (more simulations, same algorithm).

Bucket edges carry the precomputed fused-predicate operands (h, lo, thr) of
the configured diffusion model (hash once per edge instead of once per
sweep — legal for *every* registered model because h is sample-independent;
the fused decision still happens per (edge, register) on device through the
model's predicate).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch
from repro.core.difuser import DiFuserConfig, InfluenceResult, resolve_model
from repro.core.fasst import partition_samples
from repro.core.sampling import fused_predicate, make_x_vector
from repro.core.sketch import VISITED
from repro.graphs.structs import Graph

# jax API drift guard (single source: utils/jax_compat.py, re-exported here):
# old containers ship a jax without jax.sharding.AxisType and its
# mesh/shard_map surface. Tests that need a multi-device mesh skip on this
# flag instead of erroring.
from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE  # noqa: F401

# ---------------------------------------------------------------------------
# Host-side partition build
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Everything the shard_map body consumes, already bucketed + padded.

    Bucket arrays have shape (mu_v, mu_s, mu_v, B): [write-owner shard,
    sim shard, ring step k, slot]. At ring step k, vertex-shard v reads the
    register block of shard (v + k) % mu_v.
    """

    n: int
    n_pad: int                 # padded so mu_v | n_pad
    n_loc: int
    j_loc: int
    mu_v: int
    mu_s: int
    x_shards: np.ndarray       # uint32[mu_s, j_loc] (FASST-sorted chunks)
    # propagate buckets: write row = src (local id), read row = dst (block id)
    p_h: np.ndarray            # uint32[mu_v, mu_s, mu_v, Bp] edge hash
    p_w: np.ndarray            # int32 — local write row
    p_r: np.ndarray            # int32 — row within the read block
    p_t: np.ndarray            # uint32 — sampling threshold / interval width
    p_l: np.ndarray            # uint32 — interval low endpoint (model zoo)
    # cascade buckets: write row = dst (local id), read row = src (block id)
    c_h: np.ndarray
    c_w: np.ndarray
    c_r: np.ndarray
    c_t: np.ndarray
    c_l: np.ndarray
    edge_counts: np.ndarray    # int64[mu_v, mu_s] real (unpadded) edges per shard
    comm_bytes_per_sweep: int  # ring traffic per device per sweep (both phases equal)


def _bucketize(ids: np.ndarray, w_own: np.ndarray, k: np.ndarray,
               eh: np.ndarray, wrow: np.ndarray, rrow: np.ndarray, thr: np.ndarray,
               elo: np.ndarray, mu_v: int, b_max: int):
    """Scatter per-edge data into (mu_v, mu_v, B) padded buckets."""
    h_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)
    w_out = np.zeros((mu_v, mu_v, b_max), dtype=np.int32)
    r_out = np.zeros((mu_v, mu_v, b_max), dtype=np.int32)
    t_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)  # thr=0 padding is inert
    l_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)
    order = np.lexsort((ids, k, w_own))
    w_s, k_s = w_own[order], k[order]
    eh_s, wr_s, rr_s, th_s, lo_s = (eh[order], wrow[order], rrow[order],
                                    thr[order], elo[order])
    keys = w_s.astype(np.int64) * mu_v + k_s
    boundaries = np.searchsorted(keys, np.arange(mu_v * mu_v + 1))
    for b in range(mu_v * mu_v):
        lo, hi = boundaries[b], boundaries[b + 1]
        if hi == lo:
            continue
        v, kk = divmod(b, mu_v)
        cnt = hi - lo
        h_out[v, kk, :cnt] = eh_s[lo:hi]
        w_out[v, kk, :cnt] = wr_s[lo:hi]
        r_out[v, kk, :cnt] = rr_s[lo:hi]
        t_out[v, kk, :cnt] = th_s[lo:hi]
        l_out[v, kk, :cnt] = lo_s[lo:hi]
    return h_out, w_out, r_out, t_out, l_out


def build_partition_2d(g: Graph, x: np.ndarray, mu_v: int, mu_s: int, *,
                       seed: int = 0, method: str = "fasst",
                       edge_block: int = 256, model: str = "wc") -> Partition2D:
    """FASST sample-space split × contiguous vertex split, fully bucketed."""
    r = x.shape[0]
    assert r % mu_s == 0
    x_shards, _ = partition_samples(x, mu_s, method=method)
    j_loc = r // mu_s

    n_pad = g.n_pad + ((-g.n_pad) % mu_v)
    n_loc = n_pad // mu_v
    mdl = resolve_model(model)
    ep = mdl.edge_params(g, seed=seed)
    eh_all, lo_all, thr_all = ep.h, ep.lo, ep.thr
    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    own_src = (src // n_loc).astype(np.int32)
    own_dst = (dst // n_loc).astype(np.int32)

    # per sim-shard sampled-by-any masks (FASST device-local edge sets)
    from repro.core.fasst import _sampled_by_any

    p_parts, c_parts, counts = [], [], np.zeros((mu_v, mu_s), dtype=np.int64)
    bp_sizes, bc_sizes = [], []
    masks = [np.nonzero(_sampled_by_any(eh_all, thr_all, x_shards[s], lo=lo_all,
                                        predicate=mdl.predicate))[0]
             for s in range(mu_s)]
    # compute global max bucket sizes first so every shard pads identically
    for s in range(mu_s):
        ids = masks[s]
        kp = (own_dst[ids] - own_src[ids]) % mu_v
        kc = (own_src[ids] - own_dst[ids]) % mu_v
        bp = np.bincount(own_src[ids].astype(np.int64) * mu_v + kp, minlength=mu_v * mu_v)
        bc = np.bincount(own_dst[ids].astype(np.int64) * mu_v + kc, minlength=mu_v * mu_v)
        bp_sizes.append(bp.max() if bp.size else 0)
        bc_sizes.append(bc.max() if bc.size else 0)
    b_max = int(max(max(bp_sizes), max(bc_sizes), 1))
    b_max += (-b_max) % edge_block

    for s in range(mu_s):
        ids = masks[s]
        e_h, e_t, e_l = eh_all[ids], thr_all[ids], lo_all[ids]
        wsrc, wdst = own_src[ids], own_dst[ids]
        kp = (wdst - wsrc) % mu_v
        kc = (wsrc - wdst) % mu_v
        src_loc = (src[ids] % n_loc).astype(np.int32)
        dst_loc = (dst[ids] % n_loc).astype(np.int32)
        p_parts.append(_bucketize(ids, wsrc, kp, e_h, src_loc, dst_loc, e_t, e_l,
                                  mu_v, b_max))
        c_parts.append(_bucketize(ids, wdst, kc, e_h, dst_loc, src_loc, e_t, e_l,
                                  mu_v, b_max))
        for v in range(mu_v):
            counts[v, s] = int((wsrc == v).sum())

    def stack(parts, i):
        return np.stack([p[i] for p in parts], axis=1)  # -> (mu_v, mu_s, mu_v, B)

    comm = (mu_v - 1) * n_loc * j_loc  # int8 register block ring traffic / sweep
    return Partition2D(
        n=g.n, n_pad=n_pad, n_loc=n_loc, j_loc=j_loc, mu_v=mu_v, mu_s=mu_s,
        x_shards=x_shards,
        p_h=stack(p_parts, 0), p_w=stack(p_parts, 1), p_r=stack(p_parts, 2),
        p_t=stack(p_parts, 3), p_l=stack(p_parts, 4),
        c_h=stack(c_parts, 0), c_w=stack(c_parts, 1), c_r=stack(c_parts, 2),
        c_t=stack(c_parts, 3), c_l=stack(c_parts, 4),
        edge_counts=counts, comm_bytes_per_sweep=comm)


# ---------------------------------------------------------------------------
# Device-side shard_map body
# ---------------------------------------------------------------------------


def _bucket_sweep_propagate(acc, block, h, w, r, t, x_loc, lo=None, predicate=None):
    """Jacobi max-merge for one bucket: acc[w] <- max(acc[w], masked block[r])."""
    if lo is None:
        lo = jnp.zeros(t.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    mask = predicate(h[:, None].astype(jnp.uint32), lo[:, None].astype(jnp.uint32),
                     t[:, None].astype(jnp.uint32), x_loc[None, :].astype(jnp.uint32))
    vals = block[r]
    contrib = jnp.where(mask, vals, jnp.int8(VISITED))
    return acc.at[w].max(contrib)


def _bucket_sweep_cascade(acc_vis, block, h, w, r, t, x_loc, lo=None, predicate=None):
    if lo is None:
        lo = jnp.zeros(t.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    mask = predicate(h[:, None].astype(jnp.uint32), lo[:, None].astype(jnp.uint32),
                     t[:, None].astype(jnp.uint32), x_loc[None, :].astype(jnp.uint32))
    newly = jnp.logical_and(mask, block[r] == VISITED).astype(jnp.uint8)
    return acc_vis.at[w].max(newly)


def _make_distributed_fn(part: Partition2D, *, k: int, vertex_axis: str,
                         sim_axes: Sequence[str], estimator: str,
                         rebuild_threshold: float, max_prop: int, max_casc: int,
                         seed: int, schedule: str = "ring", local_sweeps: int = 0,
                         predicate=None):
    """Returns the shard_map body running the full Alg. 4 loop."""
    mu_v, mu_s = part.mu_v, part.mu_s
    n_loc, j_loc, n_real = part.n_loc, part.j_loc, part.n
    total_regs = mu_s * j_loc
    all_axes = (vertex_axis, *sim_axes)
    pred = predicate if predicate is not None else fused_predicate

    def local_sweep(m_loc, bh, bw, br, bt, bl, x_loc, merge):
        """Sweep only the k=0 bucket (reads own register block; no comm)."""
        init = m_loc if merge is _bucket_sweep_propagate else (m_loc == VISITED).astype(jnp.uint8)
        acc = merge(init, m_loc, bh[0], bw[0], br[0], bt[0], x_loc, bl[0], pred)
        if merge is _bucket_sweep_propagate:
            return jnp.where(m_loc == VISITED, m_loc, acc)
        return jnp.where(acc.astype(bool), jnp.int8(VISITED), m_loc)

    def ring_sweep(m_loc, bh, bw, br, bt, bl, x_loc, merge):
        """One full sweep: mu_v ring steps over the data axis."""
        init = m_loc if merge is _bucket_sweep_propagate else (m_loc == VISITED).astype(jnp.uint8)
        acc = init
        if schedule == "allgather" and mu_v > 1:
            # baseline schedule: materialize all blocks, no overlap
            blocks = jax.lax.all_gather(m_loc, vertex_axis)  # (mu_v, n_loc, j_loc)
            me = jax.lax.axis_index(vertex_axis)
            for kk in range(mu_v):
                owner = jax.lax.rem(me + kk, mu_v)
                acc = merge(acc, blocks[owner], bh[kk], bw[kk], br[kk], bt[kk],
                            x_loc, bl[kk], pred)
        else:
            block = m_loc
            for kk in range(mu_v):
                acc = merge(acc, block, bh[kk], bw[kk], br[kk], bt[kk], x_loc,
                            bl[kk], pred)
                if kk + 1 < mu_v:
                    perm = [(i, (i - 1) % mu_v) for i in range(mu_v)]
                    block = jax.lax.ppermute(block, vertex_axis, perm)
        if merge is _bucket_sweep_propagate:
            return jnp.where(m_loc == VISITED, m_loc, acc)
        return jnp.where(acc.astype(bool), jnp.int8(VISITED), m_loc)

    def fixpoint(m_loc, bh, bw, br, bt, bl, x_loc, merge, max_iters):
        def cond(c):
            return jnp.logical_and(c[1], c[2] < max_iters)

        def body(c):
            m_cur, _, it = c
            # block-Jacobi: drain intra-shard propagation before paying for
            # a ring exchange (edges FASST-placed mostly intra-shard, so a
            # few local sweeps kill most of the frontier; §Perf difuser)
            for _ in range(local_sweeps):
                m_cur = local_sweep(m_cur, bh, bw, br, bt, bl, x_loc, merge)
            m_new = ring_sweep(m_cur, bh, bw, br, bt, bl, x_loc, merge)
            changed = jax.lax.psum(jnp.any(m_new != m_cur).astype(jnp.int32), all_axes) > 0
            return m_new, changed, it + 1

        m_out, _, iters = jax.lax.while_loop(cond, body, (m_loc, jnp.bool_(True), jnp.int32(0)))
        return m_out, iters

    def body(x_loc, ph, pw, pr, pt, pl, ch, cw, cr, ct, cl):
        # local shard coordinates; sim axes flatten row-major (pod major)
        vi = jax.lax.axis_index(vertex_axis)
        si = jnp.int32(0)
        mult = 1
        for ax in reversed(sim_axes):
            si = si + jax.lax.axis_index(ax) * mult
            mult *= _axis_size(ax)
        reg_offset = si * j_loc
        row0 = vi * n_loc
        rows = row0 + jnp.arange(n_loc, dtype=jnp.int32)
        valid_row = rows < n_real

        ph, pw, pr, pt, pl = ph[0, 0], pw[0, 0], pr[0, 0], pt[0, 0], pl[0, 0]
        ch, cw, cr, ct, cl = ch[0, 0], cw[0, 0], cr[0, 0], ct[0, 0], cl[0, 0]
        x_loc = x_loc[0]

        # ---- fill + initial propagate (Alg. 4 lines 3-6) ----
        j_ids = (jnp.arange(j_loc, dtype=jnp.uint32)[None, :] + reg_offset.astype(jnp.uint32))
        from repro.core.sampling import register_hash

        fresh = jax.lax.clz(register_hash(rows.astype(jnp.uint32)[:, None], j_ids, seed=seed))
        m_loc = jnp.where(valid_row[:, None], fresh.astype(jnp.int8), jnp.int8(VISITED))

        def refill(m_cur):
            return jnp.where(m_cur == VISITED, m_cur, fresh.astype(jnp.int8))

        m_loc, build_iters = fixpoint(m_loc, ph, pw, pr, pt, pl, x_loc,
                                      _bucket_sweep_propagate, max_prop)

        # ---- K seed rounds ----
        def round_fn(carry, _):
            m_cur, score, oldscore = carry
            # selection: psum stats over sim axes -> exact for owned rows
            stats = jnp.stack([
                jnp.sum(jnp.where(m_cur != VISITED, jnp.exp2(-m_cur.astype(jnp.float32)), 0.0), axis=-1),
                jnp.sum(m_cur != VISITED, axis=-1).astype(jnp.float32)])
            stats = jax.lax.psum(stats, tuple(sim_axes)) if sim_axes else stats
            est = sketch.estimate_from_sums(stats, total_regs, estimator=estimator)
            est = jnp.where(valid_row, est, -1.0)
            loc_arg = jnp.argmax(est)
            loc_best = est[loc_arg]
            loc_seed = rows[loc_arg]
            # cross-shard argmax: P scalars instead of the paper's O(n) vector
            bests = jax.lax.all_gather(loc_best, vertex_axis)        # (mu_v,)
            seeds_g = jax.lax.all_gather(loc_seed, vertex_axis)      # (mu_v,)
            win = jnp.argmax(bests)
            s_global = seeds_g[win]
            gain = bests[win]
            # commit + cascade
            m_cur = jnp.where((rows == s_global)[:, None], jnp.int8(VISITED), m_cur)
            m_cur, _ = fixpoint(m_cur, ch, cw, cr, ct, cl, x_loc,
                                _bucket_sweep_cascade, max_casc)
            visited = jnp.sum(jnp.logical_and(m_cur == VISITED, valid_row[:, None]).astype(jnp.int32))
            visited = jax.lax.psum(visited, all_axes).astype(jnp.float32)
            new_score = visited / jnp.float32(total_regs)
            rel = (new_score - oldscore) / jnp.maximum(new_score, 1e-9)

            def rebuild(mm):
                mm = refill(mm)
                mm, _ = fixpoint(mm, ph, pw, pr, pt, pl, x_loc,
                                 _bucket_sweep_propagate, max_prop)
                return mm, new_score

            def keep(mm):
                return mm, oldscore

            m_cur, oldscore = jax.lax.cond(rel > rebuild_threshold, rebuild, keep, m_cur)
            return (m_cur, new_score, oldscore), (s_global, gain, new_score, rel > rebuild_threshold)

        (_, _, _), outs = jax.lax.scan(round_fn, (m_loc, jnp.float32(0.0), jnp.float32(0.0)),
                                       None, length=k)
        seeds_out, gains, scores, rebuilds = outs
        return seeds_out, gains, scores, rebuilds, build_iters

    # helper resolved at trace time inside shard_map
    _axis_sizes: dict[str, int] = {}

    def _axis_size(ax: str) -> int:
        return _axis_sizes[ax]

    def with_sizes(mesh):
        for ax in (vertex_axis, *sim_axes):
            _axis_sizes[ax] = mesh.shape[ax]
        return body

    return with_sizes


@dataclasses.dataclass(frozen=True)
class DistributedConfig(DiFuserConfig):
    vertex_axis: str = "data"
    sim_axes: tuple = ("model",)
    schedule: str = "ring"          # "ring" | "allgather"
    fasst: bool = True              # False -> naive sample partition
    local_sweeps: int = 0           # extra comm-free sweeps per exchange


def find_seeds_distributed(g: Graph, k: int, mesh, config: Optional[DistributedConfig] = None,
                           x: Optional[np.ndarray] = None):
    """Run distributed DiFuseR on ``mesh``. Returns (InfluenceResult, Partition2D)."""
    from jax.sharding import PartitionSpec as P

    cfg = config or DistributedConfig()
    mu_v = mesh.shape[cfg.vertex_axis]
    mu_s = math.prod(mesh.shape[ax] for ax in cfg.sim_axes)
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
    g = g.sorted_by_dst()
    part = build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed,
                              method="fasst" if cfg.fasst else "naive",
                              model=cfg.model)

    maker = _make_distributed_fn(
        part, k=k, vertex_axis=cfg.vertex_axis, sim_axes=tuple(cfg.sim_axes),
        estimator=cfg.estimator, rebuild_threshold=cfg.rebuild_threshold,
        max_prop=cfg.max_propagate_iters, max_casc=cfg.max_cascade_iters,
        seed=cfg.seed, schedule=cfg.schedule, local_sweeps=cfg.local_sweeps,
        predicate=resolve_model(cfg.model).predicate)
    body = maker(mesh)

    sim_spec = cfg.sim_axes if len(cfg.sim_axes) > 1 else cfg.sim_axes[0]
    bucket_spec = P(cfg.vertex_axis, sim_spec, None, None)
    in_specs = (P(sim_spec, None),) + (bucket_spec,) * 10
    out_specs = (P(), P(), P(), P(), P())

    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False))
    # reshape x_shards so sim axes shard dim 0: (mu_s, j_loc)
    args = [jnp.asarray(part.x_shards)]
    for a in (part.p_h, part.p_w, part.p_r, part.p_t, part.p_l,
              part.c_h, part.c_w, part.c_r, part.c_t, part.c_l):
        args.append(jnp.asarray(a))
    seeds, gains, scores, rebuilds, build_iters = fn(*args)
    res = InfluenceResult(
        seeds=np.asarray(seeds), est_gains=np.asarray(gains), scores=np.asarray(scores),
        rebuilds=np.asarray(rebuilds), propagate_iters=int(build_iters),
        x=np.sort(x) if cfg.fasst else x)
    return res, part
