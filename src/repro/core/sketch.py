"""Flajolet–Martin sketch state and estimators (paper §2.3, §3.1).

State layout: a single ``int8[n_pad, J]`` matrix ``M``. ``M[u, j]`` is the FM
register of vertex ``u`` for simulation slot ``j``:

  * ``M[u, j] in [0, 32]`` — max clz over the (sampled-)reachable set of u in
    simulation j;
  * ``M[u, j] == VISITED (-1)`` — u is already activated by the committed seed
    set in simulation j (paper's visited-in-register encoding, §3.1).

The visited sentinel is the *bottom* element of the max-merge lattice, which
is what keeps pull-merges idempotent and atomics-free; a ``where`` guard keeps
it sticky (a visited register never becomes unvisited).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling import register_hash

VISITED = np.int8(-1)
REG_DTYPE = jnp.int8

# Flajolet–Martin correction factor (paper eq. (6)), J >= 16.
PHI_FM = 0.77351

# Harmonic-mean correction for *full-stream* FM registers (every register
# sees every item through its own hash h_j — unlike HyperLogLog's stochastic
# averaging, so HLL's alpha_m does NOT apply). For M = max clz over n items,
# E[n * 2^-M] -> 1/ln 2 (verified numerically at n = 50..5e4, std err < 2%),
# giving  n_hat = C_HARMONIC * J / sum_j 2^-M_j.
C_HARMONIC = 1.4426950408889634  # = 1 / ln 2


def hll_alpha(j: int) -> float:
    """Kept for reference/tests of classic HLL behavior (unused by the
    estimator below — see C_HARMONIC)."""
    if j >= 128:
        return 0.7213 / (1.0 + 1.079 / j)
    if j >= 64:
        return 0.709
    if j >= 32:
        return 0.697
    return 0.673


def fill_registers(n_pad: int, num_regs: int, *, reg_offset: int = 0, seed: int = 0,
                   visited: jnp.ndarray | None = None) -> jnp.ndarray:
    """FILL-SKETCHES (paper Alg. 1): M[u, j] = clz(h_{reg_offset + j}(u)).

    ``reg_offset`` is the distributed register-slot offset (tau * J / mu).
    ``visited`` — optional (n_pad, J) bool; visited entries stay VISITED
    (the Alg. 1 line-5 early exit).
    """
    u = jnp.arange(n_pad, dtype=jnp.uint32)[:, None]
    j = jnp.arange(num_regs, dtype=jnp.uint32)[None, :] + jnp.uint32(reg_offset)
    h = register_hash(u, j, seed=seed)
    m = jax.lax.clz(h).astype(REG_DTYPE)
    if visited is not None:
        m = jnp.where(visited, jnp.int8(VISITED), m)
    return m


def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Sketch union (paper eq. (5)) with sticky visited."""
    return jnp.where(a == VISITED, a, jnp.maximum(a, b))


def estimate_cardinality(m: jnp.ndarray, *, estimator: str = "hll") -> jnp.ndarray:
    """Per-vertex expected *marginal* influence from registers (paper eqs. 6/7).

    Registers with VISITED contribute zero marginal gain (their simulation is
    already covered). Returns float32[n_pad].

    estimator:
      * "hll": harmonic-mean aggregation (paper eq. (7) / HyperLogLog [18]) —
        robust to outlier registers.
      * "fm_mean": 2^mean / phi (paper eq. (6), classic FM).
    """
    num_regs = m.shape[-1]
    valid = m != VISITED
    j_valid = jnp.sum(valid, axis=-1).astype(jnp.float32)
    frac_valid = j_valid / jnp.float32(num_regs)
    mf = m.astype(jnp.float32)
    if estimator == "hll":
        denom = jnp.sum(jnp.where(valid, jnp.exp2(-mf), 0.0), axis=-1)
        est = jnp.float32(C_HARMONIC) * j_valid / jnp.maximum(denom, 1e-30)
    elif estimator == "fm_mean":
        mean = jnp.sum(jnp.where(valid, mf, 0.0), axis=-1) / jnp.maximum(j_valid, 1.0)
        est = jnp.exp2(mean) / jnp.float32(PHI_FM)
    else:
        raise ValueError(f"unknown estimator: {estimator}")
    # scale by the fraction of simulations where the vertex is still free —
    # visited sims contribute zero marginal gain.
    return jnp.where(j_valid > 0, est * frac_valid, 0.0)


def partial_sums(m: jnp.ndarray, *, estimator: str = "hll") -> jnp.ndarray:
    """Shard-local additive statistics for distributed seed selection.

    The estimators are nonlinear, but their sufficient statistics are sums
    over registers, so shards psum these and every shard finishes the
    estimate locally (paper's Fig. 3 reduction, SPMD form).

    Returns float32[2, n_pad]: [sum-statistic, valid-count].
    """
    valid = m != VISITED
    j_valid = jnp.sum(valid, axis=-1).astype(jnp.float32)
    mf = m.astype(jnp.float32)
    if estimator == "hll":
        stat = jnp.sum(jnp.where(valid, jnp.exp2(-mf), 0.0), axis=-1)
    elif estimator == "fm_mean":
        stat = jnp.sum(jnp.where(valid, mf, 0.0), axis=-1)
    else:
        raise ValueError(f"unknown estimator: {estimator}")
    return jnp.stack([stat, j_valid])


def estimate_from_sums(sums: jnp.ndarray, total_regs: int, *, estimator: str = "hll") -> jnp.ndarray:
    """Finish the cardinality estimate from psum'd ``partial_sums``."""
    stat, j_valid = sums[0], sums[1]
    frac_valid = j_valid / jnp.float32(total_regs)
    if estimator == "hll":
        est = jnp.float32(C_HARMONIC) * j_valid / jnp.maximum(stat, 1e-30)
    elif estimator == "fm_mean":
        mean = stat / jnp.maximum(j_valid, 1.0)
        est = jnp.exp2(mean) / jnp.float32(PHI_FM)
    else:
        raise ValueError(f"unknown estimator: {estimator}")
    return jnp.where(j_valid > 0, est * frac_valid, 0.0)


def count_visited(m: jnp.ndarray, n_real: int) -> jnp.ndarray:
    """Number of (vertex, sim) pairs activated by the seed set (real rows only)."""
    return jnp.sum((m[:n_real] == VISITED).astype(jnp.int32))


def exact_distinct_reference(items: np.ndarray, num_regs: int, seed: int = 0) -> float:
    """Host-side FM estimate of |set(items)| — used by estimator-accuracy tests."""
    u = np.asarray(items, dtype=np.uint32)[:, None]
    j = np.arange(num_regs, dtype=np.uint32)[None, :]
    h = register_hash(u, j, seed=seed)
    # numpy clz via bit twiddling (see sampling.clz32)
    from repro.core.sampling import clz32

    regs = clz32(h).max(axis=0)  # (J,)
    denom = np.sum(np.exp2(-regs.astype(np.float64)))
    return float(C_HARMONIC * num_regs / denom)
