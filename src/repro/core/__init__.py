"""DiFuseR core: the paper's contribution as composable JAX modules."""
from repro.core.difuser import DiFuserConfig, InfluenceResult, find_seeds
from repro.core.distributed import DistributedConfig, find_seeds_distributed
