"""Seed selection (paper Alg. 4 lines 8-14 and Fig. 3/4).

Selection reduces per-vertex *additive* estimator statistics (shard-local
``partial_sums``), finishes the nonlinear harmonic-mean estimate after the
reduction, masks padding rows, and takes the argmax. In SPMD every shard
computes the identical argmax, so the paper's explicit BROADCAST disappears.

Beyond-paper (paper §6's own suggestion): ``topk_candidates`` communicates
only the top-C per-shard candidates instead of the full O(n) vector — the
compressed-selection path used by the distributed runtime when
``select_top_c > 0``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import sketch
from repro.kernels import ops


def local_sums(m: jnp.ndarray, *, impl: str = "ref") -> jnp.ndarray:
    """float32[2, n_pad] shard-local additive statistics (kernel-backed)."""
    return ops.cardinality_stats(m, impl=impl)


@partial(jax.jit, static_argnames=("total_regs", "n_real", "estimator"))
def finish_select(sums: jnp.ndarray, total_regs: int, n_real: int,
                  *, estimator: str = "hll") -> tuple[jnp.ndarray, jnp.ndarray]:
    """(reduced sums) -> (seed vertex, its estimated marginal gain)."""
    est = sketch.estimate_from_sums(sums, total_regs, estimator=estimator)
    n_pad = est.shape[0]
    valid_row = jnp.arange(n_pad) < n_real
    est = jnp.where(valid_row, est, -1.0)
    s = jnp.argmax(est)
    return s.astype(jnp.int32), est[s]


def topk_candidates(sums: jnp.ndarray, total_regs: int, n_real: int, c: int,
                    *, estimator: str = "hll") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-shard top-C pre-filter (compressed selection, paper §6).

    Returns (vertex ids int32[c], estimates float32[c]) of the shard's best
    local candidates; the runtime all-gathers these O(C·mu) values instead
    of psumming O(n). Exactness caveat (documented in DESIGN.md): with
    per-shard statistics the local estimate is computed from the shard's
    registers only, so the pre-filter is approximate; the runtime re-scores
    the gathered candidate union exactly before the argmax.
    """
    est = sketch.estimate_from_sums(sums, total_regs, estimator=estimator)
    n_pad = est.shape[0]
    valid_row = jnp.arange(n_pad) < n_real
    est = jnp.where(valid_row, est, -1.0)
    vals, idx = jax.lax.top_k(est, c)
    return idx.astype(jnp.int32), vals
