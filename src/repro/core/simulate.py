"""Sketch propagation to fixpoint (paper Alg. 2 + the Alg. 4 lines 5-6 loop).

One sweep max-merges every vertex's registers with its sampled out-
neighbors'; repeating until nothing changes yields, for each simulation j,
``M[u, j] = max clz over the j-sampled reachability set of u``. The sweep
count is bounded by the max diameter of the sampled graphs — for the
power-law graphs the paper targets this is small; ``max_iters`` caps the
pathological case (paper §6 concedes the same limitation for road-type
networks).

The optional (h, lo, predicate) triple is the diffusion-model hook threaded
down to kernels/ops.py; omitted, the legacy weighted-cascade sampling is
reproduced bit-for-bit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops


@partial(jax.jit, static_argnames=("seed", "impl", "edge_chunk", "max_iters",
                                   "predicate", "edge_block", "reg_tile"))
def propagate_to_fixpoint(m, src, dst, thr, x, h=None, lo=None, *, seed: int = 0,
                          impl: str = "ref", edge_chunk: int = 2048,
                          max_iters: int = 64, predicate=None,
                          edge_block: int = 0, reg_tile: int = 0):
    """Run SIMULATE sweeps until convergence. Returns (m, iters_used).

    ``edge_chunk`` (ref impl) and ``edge_block``/``reg_tile`` (pallas impl,
    0 = kernel default) are performance-only tile knobs — repro.tune feeds
    measured winners through them; results are invariant."""

    def cond(carry):
        _, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def body(carry):
        m_cur, _, it = carry
        m_new = ops.propagate_sweep(m_cur, src, dst, thr, x, seed=seed, impl=impl,
                                    edge_chunk=edge_chunk, h=h, lo=lo,
                                    predicate=predicate, edge_block=edge_block,
                                    reg_tile=reg_tile)
        changed = jnp.any(m_new != m_cur)
        return m_new, changed, it + 1

    m_out, _, iters = jax.lax.while_loop(cond, body, (m, jnp.bool_(True), jnp.int32(0)))
    return m_out, iters
