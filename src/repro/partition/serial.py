"""Serial-ring executor: the 2-D distributed algorithm on one host.

Runs the exact bucketed ring schedule the ``shard_map`` runtime executes —
fill, ring propagate to fixpoint, K rounds of {select, cascade, score,
lazy-rebuild} — but serially over the ``(mu_v, mu_s)`` shard grid in numpy.
Three jobs:

  * **planner invariance tests** — seed sets and spread estimates must be
    identical across every :mod:`repro.partition.plan` strategy (and any
    random relabeling), and they must match the single-device ``find_seeds``
    path; this executor makes that testable without a multi-device mesh
    (old-jax containers skip the ``shard_map`` suite entirely);
  * **benchmarks** — ``benchmarks/partition_balance.py`` times real bucket
    sweeps per planner without device multiplexing noise;
  * **reference** — a readable spelling of the ring schedule (the
    ``shard_map`` body in ``core/distributed.py`` is its device twin).

Numerics mirror the device path: int8 registers, float32 estimator sums
accumulated per sim shard in shard order (the psum), min-original-id
tie-breaking in selection.
"""
from __future__ import annotations

from time import perf_counter
from typing import Optional

import numpy as np

from repro.core.difuser import DiFuserConfig, InfluenceResult, resolve_model
from repro.core.sampling import clz32, make_x_vector, register_hash
from repro.core.sketch import C_HARMONIC, PHI_FM, VISITED
from repro.graphs.structs import Graph
from repro.obs import shardprof, trace
from repro.partition.builder import Partition2D, build_partition_2d
from repro.partition.plan import (PartitionPlan, plan_partition,
                                  sample_edge_sets)
from repro.utils import roofline


def _est_from_sums_np(stat, cnt, total_regs: int, estimator: str):
    """numpy float32 mirror of ``sketch.estimate_from_sums``."""
    frac = cnt / np.float32(total_regs)
    if estimator == "hll":
        est = np.float32(C_HARMONIC) * cnt / np.maximum(stat, np.float32(1e-30))
    elif estimator == "fm_mean":
        mean = stat / np.maximum(cnt, np.float32(1.0))
        est = np.exp2(mean) / np.float32(PHI_FM)
    else:
        raise ValueError(f"unknown estimator: {estimator}")
    return np.where(cnt > 0, est * frac, np.float32(0.0))


class _RingState:
    """Shard-grid register state + the bucket sweeps over it.

    ``reg_offset`` offsets the register hash slots (sample-space store
    banks — same contract as ``ops.sketch_fill``); ``matrix`` warm-starts
    the state from an existing ``(mu_v, mu_s, n_loc, j_loc)`` grid instead
    of a fresh fill (the shard-restricted delta-repair path).
    """

    def __init__(self, part: Partition2D, g: Graph, cfg: DiFuserConfig, *,
                 reg_offset: int = 0, matrix: Optional[np.ndarray] = None):
        self.part, self.cfg = part, cfg
        #: Optional :class:`repro.obs.shardprof.ShardProfiler`: when set,
        #: every (shard, ring step) bucket merge is individually timed —
        #: the serial ring is the one executor where per-shard time is
        #: physically separable, so this is the measured ground truth the
        #: predicted PlanStats are checked against.
        self.profiler = None
        #: Extra comm-free sweeps before each full ring sweep (the serial
        #: twin of ``DistributedConfig.local_sweeps``): only the kk=0
        #: self-shard buckets are merged, so no cross-shard block is read.
        #: Result-invariant — extra monotone sweeps toward the unique
        #: max-merge fixpoint (repro.tune may raise it; 0 = historical).
        self.local_sweeps = 0
        #: Fused prologue routing (the serial twin of
        #: ``DistributedConfig.fuse_sweeps``/``lane_fill``): when on, the
        #: comm-free prologue runs all ``local_sweeps`` iterations through
        #: one ``ops.fused_sweep`` launch per shard (kernels/fused_sweep —
        #: register block resident across sweeps) instead of re-running the
        #: numpy ``sweep_local`` merge per sweep. Bit-identical by the
        #: kernel contract; repro.tune flips these from measured winners.
        self.fuse_sweeps = False
        self.lane_fill = 0
        self.pred = resolve_model(cfg.model).predicate
        self.owned = part.owned_ids                        # (mu_v, n_loc)
        self.valid = self.owned < g.n                      # padding rows
        mu_v, mu_s = part.mu_v, part.mu_s
        n_loc, j_loc = part.n_loc, part.j_loc
        grid_shape = (mu_v, mu_s, n_loc, j_loc)
        if matrix is not None:
            # warm start (shard-restricted repair): the O(n_pad * J) fresh
            # hash fill is only needed by refill(), which the repair path
            # never calls — skip the dominant cost of a small repair
            assert matrix.shape == grid_shape, (matrix.shape, grid_shape)
            self.fresh = None
            self.m = np.array(matrix, dtype=np.int8)
            return
        fresh = np.empty(grid_shape, dtype=np.int8)
        for v in range(mu_v):
            for s in range(mu_s):
                j_ids = (np.arange(j_loc, dtype=np.uint32)
                         + np.uint32(s * j_loc + reg_offset))
                h = register_hash(self.owned[v].astype(np.uint32)[:, None],
                                  j_ids[None, :], seed=cfg.seed)
                fresh[v, s] = clz32(h).astype(np.int8)
        self.fresh = fresh
        self.m = np.where(self.valid[:, None, :, None], fresh,
                          np.int8(VISITED))

    def canonical_matrix(self, n_pad: int) -> np.ndarray:
        """Un-permute the shard grid to the canonical single-device layout:
        ``int8[n_pad, mu_s * j_loc]`` with rows in original-id order and
        columns in canonical x order (sim-shard blocks are contiguous chunks
        of the sorted sample vector)."""
        p = self.part
        planned = self.m.transpose(0, 2, 1, 3).reshape(
            p.mu_v * p.n_loc, p.mu_s * p.j_loc)
        return planned[p.plan.perm[:n_pad]]

    def _mask(self, kk: int, v: int, s: int, bufs):
        bh = bufs[0][kk][v, s]
        bl, bt = bufs[4][kk][v, s], bufs[3][kk][v, s]
        return self.pred(bh[:, None], bl[:, None], bt[:, None],
                         self.part.x_shards[s][None, :])

    def sweep_local(self) -> bool:
        """One comm-free propagate sweep: merge only the kk=0 buckets (edges
        whose read block is the writing shard's own rows). The device twin
        is the ``local_sweeps`` prologue of the shard_map ring body."""
        p = self.part
        bufs = (p.p_h, p.p_w, p.p_r, p.p_t, p.p_l)
        if bufs[0][0].shape[-1] == 0:
            return False
        out = self.m.copy()
        for v in range(p.mu_v):
            for s in range(p.mu_s):
                acc = self.m[v, s].copy()
                bw, br = bufs[1][0][v, s], bufs[2][0][v, s]
                contrib = np.where(self._mask(0, v, s, bufs),
                                   self.m[v, s][br], np.int8(VISITED))
                np.maximum.at(acc, bw, contrib)
                out[v, s] = np.where(self.m[v, s] == VISITED, self.m[v, s], acc)
        changed = bool((out != self.m).any())
        self.m = out
        return changed

    def sweep_local_fused(self, num_sweeps: int) -> bool:
        """The fused spelling of ``num_sweeps`` x :meth:`sweep_local`: per
        (vertex, sim) shard, one :func:`ops.fused_sweep` launch runs every
        prologue sweep over the kk=0 bucket with the shard's register block
        resident between sweeps. Results are bit-identical to the looped
        numpy path (Jacobi max-merge; the fused kernel's contract) — only
        the launch/traffic pattern changes."""
        import jax.numpy as jnp

        from repro.kernels import ops

        p = self.part
        bufs = (p.p_h, p.p_w, p.p_r, p.p_t, p.p_l)
        if num_sweeps <= 0 or bufs[0][0].shape[-1] == 0:
            return False
        changed = False
        for v in range(p.mu_v):
            for s in range(p.mu_s):
                out = np.asarray(ops.fused_sweep(
                    jnp.asarray(self.m[v, s]),
                    jnp.asarray(bufs[1][0][v, s]),      # bw: write rows
                    jnp.asarray(bufs[2][0][v, s]),      # br: read rows
                    jnp.asarray(bufs[3][0][v, s]),      # thr (interval width)
                    jnp.asarray(p.x_shards[s]),
                    h=jnp.asarray(bufs[0][0][v, s]),
                    lo=jnp.asarray(bufs[4][0][v, s]),
                    num_sweeps=int(num_sweeps), impl=self.cfg.impl,
                    edge_chunk=self.cfg.edge_chunk,
                    lane_fill=int(self.lane_fill), predicate=self.pred))
                changed = changed or bool((out != self.m[v, s]).any())
                self.m[v, s] = out
        return changed

    def sweep_propagate(self) -> bool:
        if self.fuse_sweeps and self.local_sweeps:
            self.sweep_local_fused(self.local_sweeps)
        else:
            for _ in range(self.local_sweeps):   # comm-free prologue (tunable)
                if not self.sweep_local():
                    break
        p = self.part
        prof = self.profiler
        bufs = (p.p_h, p.p_w, p.p_r, p.p_t, p.p_l)
        out = self.m.copy()
        for v in range(p.mu_v):
            for s in range(p.mu_s):
                acc = self.m[v, s].copy()
                for kk in range(p.mu_v):
                    if bufs[0][kk].shape[-1] == 0:
                        continue
                    t0 = perf_counter() if prof is not None else 0.0
                    bw, br = bufs[1][kk][v, s], bufs[2][kk][v, s]
                    block = self.m[(v + kk) % p.mu_v, s]
                    contrib = np.where(self._mask(kk, v, s, bufs), block[br],
                                       np.int8(VISITED))
                    np.maximum.at(acc, bw, contrib)
                    if prof is not None:
                        prof.record(v, kk, perf_counter() - t0,
                                    shardprof.bucket_bytes(
                                        p.p_counts[v, s, kk], p.j_loc))
                out[v, s] = np.where(self.m[v, s] == VISITED, self.m[v, s], acc)
        if prof is not None:
            prof.count_sweep()
        changed = bool((out != self.m).any())
        self.m = out
        return changed

    def sweep_propagate_restricted(self, read_dirty) -> set:
        """One propagate sweep over only the buckets whose *read* block
        belongs to a shard in ``read_dirty``; returns the set of vertex
        shards whose rows changed (the next sweep's dirty set).

        This is the frontier-restricted repair sweep: starting from a sound
        lower bound of the fixpoint (e.g. the pre-delta matrix), changes can
        only originate at rows the dirtied shards feed, so sweeping buckets
        that read from clean shards is provably a no-op and skipped.
        """
        p = self.part
        bufs = (p.p_h, p.p_w, p.p_r, p.p_t, p.p_l)
        read_dirty = set(int(v) for v in read_dirty)
        out = self.m.copy()
        for v in range(p.mu_v):
            for s in range(p.mu_s):
                acc = self.m[v, s].copy()
                hit = False
                for kk in range(p.mu_v):
                    if (v + kk) % p.mu_v not in read_dirty:
                        continue
                    if bufs[0][kk].shape[-1] == 0:
                        continue
                    hit = True
                    bw, br = bufs[1][kk][v, s], bufs[2][kk][v, s]
                    block = self.m[(v + kk) % p.mu_v, s]
                    contrib = np.where(self._mask(kk, v, s, bufs), block[br],
                                       np.int8(VISITED))
                    np.maximum.at(acc, bw, contrib)
                if hit:
                    out[v, s] = np.where(self.m[v, s] == VISITED,
                                         self.m[v, s], acc)
        changed = {v for v in range(p.mu_v)
                   if (out[v] != self.m[v]).any()}
        self.m = out
        return changed

    def sweep_cascade(self) -> bool:
        p = self.part
        prof = self.profiler
        bufs = (p.c_h, p.c_w, p.c_r, p.c_t, p.c_l)
        out = self.m.copy()
        for v in range(p.mu_v):
            for s in range(p.mu_s):
                acc = (self.m[v, s] == VISITED).astype(np.uint8)
                for kk in range(p.mu_v):
                    if bufs[0][kk].shape[-1] == 0:
                        continue
                    t0 = perf_counter() if prof is not None else 0.0
                    bw, br = bufs[1][kk][v, s], bufs[2][kk][v, s]
                    block = self.m[(v + kk) % p.mu_v, s]
                    newly = (self._mask(kk, v, s, bufs)
                             & (block[br] == VISITED)).astype(np.uint8)
                    np.maximum.at(acc, bw, newly)
                    if prof is not None:
                        prof.record(v, kk, perf_counter() - t0,
                                    shardprof.bucket_bytes(
                                        p.c_counts[v, s, kk], p.j_loc))
                out[v, s] = np.where(acc.astype(bool), np.int8(VISITED),
                                     self.m[v, s])
        if prof is not None:
            prof.count_sweep()
        changed = bool((out != self.m).any())
        self.m = out
        return changed

    def fixpoint(self, sweep, max_iters: int) -> int:
        it, changed = 0, True
        while changed and it < max_iters:
            changed = sweep()
            it += 1
        return it

    def select(self, total_regs: int, n_big: int):
        """Min-original-id tie-broken argmax over finished estimates."""
        m = self.m
        vld = m != VISITED
        stat = np.zeros(m.shape[:1] + m.shape[2:3], dtype=np.float32)
        cnt = np.zeros_like(stat)
        for s in range(self.part.mu_s):   # psum over sim shards, shard order
            mf = m[:, s].astype(np.float32)
            if self.cfg.estimator == "hll":
                term = np.where(vld[:, s], np.exp2(-mf), np.float32(0.0))
            else:
                term = np.where(vld[:, s], mf, np.float32(0.0))
            stat += term.sum(axis=-1, dtype=np.float32)
            cnt += vld[:, s].sum(axis=-1).astype(np.float32)
        est = _est_from_sums_np(stat, cnt, total_regs, self.cfg.estimator)
        est = np.where(self.valid, est, np.float32(-1.0))
        best = est.max()
        seed_v = int(np.where(est == best, self.owned, n_big).min())
        return seed_v, np.float32(best)

    def commit(self, seed_v: int) -> None:
        hit = self.owned == seed_v                        # (mu_v, n_loc)
        self.m = np.where(hit[:, None, :, None], np.int8(VISITED), self.m)

    def visited_count(self) -> int:
        return int(((self.m == VISITED) & self.valid[:, None, :, None]).sum())

    def refill(self) -> None:
        assert self.fresh is not None, "refill() needs a cold-started state"
        self.m = np.where(self.m == VISITED, self.m, self.fresh)


def _find_seeds_ring_serial(g: Graph, k: int,
                            config: Optional[DiFuserConfig] = None,
                            *, mu_v: int = 2, mu_s: int = 2,
                            strategy: str = "block",
                            plan: Optional[PartitionPlan] = None,
                            x: Optional[np.ndarray] = None,
                            pad_mode: str = "step", local_sweeps: int = 0,
                            fuse_sweeps: bool = False, lane_fill: int = 0):
    """Serial-ring Alg. 4 driver (the ``serial`` runtime backend's body).

    Returns ``(InfluenceResult, Partition2D)`` like the distributed path;
    seeds are original vertex ids regardless of the plan's relabeling.
    """
    cfg = config or DiFuserConfig()
    g = g.sorted_by_dst()
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
    x = np.asarray(x, dtype=np.uint32)
    sampled = sample_edge_sets(g, x, mu_s, seed=cfg.seed, model=cfg.model)
    if plan is None:
        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy=strategy,
                              seed=cfg.seed, model=cfg.model, sampled=sampled)
    part = build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed, model=cfg.model,
                              plan=plan, pad_mode=pad_mode, sampled=sampled)
    st = _RingState(part, g, cfg)
    st.local_sweeps = int(local_sweeps)
    st.fuse_sweeps = bool(fuse_sweeps)
    st.lane_fill = int(lane_fill)
    if shardprof.enabled():
        st.profiler = shardprof.profile_for_partition(
            part, backend="serial", phase="fixpoint")
    total_regs = part.mu_s * part.j_loc
    with trace.span("serial.build_fixpoint", phase="fixpoint",
                    mu_v=mu_v, mu_s=mu_s) as sp:
        build_iters = st.fixpoint(st.sweep_propagate, cfg.max_propagate_iters)
        sp.annotate(iters=build_iters)
    if st.profiler is not None:
        # null spans report duration 0.0 (tracing off) -> let the profiler
        # fall back to its own wall clock
        prof = shardprof.publish(st.profiler.finish(sp.duration_s or None),
                                 predicted=plan.predicted)
        roofline.annotate_bandwidth(sp, int(prof.step_bytes.sum()),
                                    prof.wall_s)
        st.profiler = None   # rounds reuse the state; profile is the build's

    seeds = np.zeros(k, dtype=np.int32)
    gains = np.zeros(k, dtype=np.float32)
    scores = np.zeros(k, dtype=np.float32)
    rebuilds = np.zeros(k, dtype=bool)
    oldscore = np.float32(0.0)
    for i in range(k):
        with trace.span("serial.round", phase="select", round=i) as rsp:
            s_v, gain = st.select(total_regs, part.n_pad)
            st.commit(s_v)
            with trace.span("serial.cascade_fixpoint", phase="ring", round=i):
                st.fixpoint(st.sweep_cascade, cfg.max_cascade_iters)
            new_score = np.float32(st.visited_count()) / np.float32(total_regs)
            rel = (new_score - oldscore) / np.maximum(new_score,
                                                      np.float32(1e-9))
            do_rebuild = bool(rel > np.float32(cfg.rebuild_threshold))
            if do_rebuild:
                with trace.span("serial.rebuild", phase="build", round=i):
                    st.refill()
                    st.fixpoint(st.sweep_propagate, cfg.max_propagate_iters)
                oldscore = new_score
            rsp.annotate(seed=s_v, rebuild=do_rebuild)
        seeds[i], gains[i], scores[i], rebuilds[i] = s_v, gain, new_score, do_rebuild
    res = InfluenceResult(seeds=seeds, est_gains=gains, scores=scores,
                          rebuilds=rebuilds, propagate_iters=build_iters,
                          x=np.sort(x))
    return res, part


def find_seeds_ring_serial(g: Graph, k: int,
                           config: Optional[DiFuserConfig] = None,
                           *, mu_v: int = 2, mu_s: int = 2,
                           strategy: str = "block",
                           plan: Optional[PartitionPlan] = None,
                           x: Optional[np.ndarray] = None,
                           pad_mode: str = "step"):
    """Deprecated entry point — prefer the unified runtime facade::

        from repro.runtime import InfluenceSession, RunSpec
        spec = RunSpec(backend="serial", mu_v=2, mu_s=2, partition=strategy)
        InfluenceSession(g, spec).find_seeds(k)

    Kept as a thin shim through the ``serial`` backend; results are
    bit-identical to the historical direct call (golden-tested). Returns
    (InfluenceResult, Partition2D) like before."""
    from repro.runtime import run, warn_deprecated
    from repro.runtime.spec import RunSpec

    warn_deprecated("repro.partition.serial.find_seeds_ring_serial",
                    "repro.runtime.InfluenceSession.find_seeds")
    spec = RunSpec.from_config(config, backend="serial", mu_v=mu_v, mu_s=mu_s,
                               partition=strategy, pad_mode=pad_mode)
    report = run(g, k, spec, x=x, plan=plan)
    return report.result, report.partition


def build_matrix_ring_serial(g: Graph, config: Optional[DiFuserConfig] = None,
                             x: Optional[np.ndarray] = None, *,
                             mu_v: int = 2, mu_s: int = 1,
                             strategy: str = "block",
                             plan: Optional[PartitionPlan] = None,
                             pad_mode: str = "step", reg_offset: int = 0,
                             local_sweeps: int = 0, fuse_sweeps: bool = False,
                             lane_fill: int = 0):
    """Alg. 4 lines 3-6 on the serial ring: fill + propagate-to-fixpoint.

    Expects ``g`` dst-sorted and ``x`` canonical (sorted). Returns
    ``(matrix int8[g.n_pad, len(x)], iters, Partition2D)`` with ``matrix``
    in the canonical single-device layout — bit-identical to
    ``core.difuser.build_sketch_matrix`` with the same ``reg_offset``, which
    is what lets :class:`~repro.service.store.SketchStore` banks build
    through the ``serial`` backend.
    """
    cfg = config or DiFuserConfig()
    if x is None:
        x = make_x_vector(cfg.num_registers, seed=cfg.seed)
        x = np.sort(np.asarray(x, dtype=np.uint32))
    x = np.asarray(x, dtype=np.uint32)
    sampled = sample_edge_sets(g, x, mu_s, seed=cfg.seed, model=cfg.model)
    if plan is None:
        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy=strategy,
                              seed=cfg.seed, model=cfg.model, sampled=sampled)
    part = build_partition_2d(g, x, mu_v, mu_s, seed=cfg.seed, model=cfg.model,
                              plan=plan, pad_mode=pad_mode, sampled=sampled)
    with trace.span("serial.build_matrix", phase="build", mu_v=mu_v,
                    mu_s=mu_s, reg_offset=reg_offset) as sp:
        st = _RingState(part, g, cfg, reg_offset=reg_offset)
        st.local_sweeps = int(local_sweeps)
        st.fuse_sweeps = bool(fuse_sweeps)
        st.lane_fill = int(lane_fill)
        if shardprof.enabled():
            st.profiler = shardprof.profile_for_partition(
                part, backend="serial", phase="build")
        iters = st.fixpoint(st.sweep_propagate, cfg.max_propagate_iters)
        sp.annotate(iters=iters)
    if st.profiler is not None:
        prof = shardprof.publish(st.profiler.finish(sp.duration_s or None),
                                 predicted=plan.predicted)
        roofline.annotate_bandwidth(sp, int(prof.step_bytes.sum()),
                                    prof.wall_s)
    return st.canonical_matrix(g.n_pad), iters, part


def repair_plan_shards(g: Graph, config: DiFuserConfig, x: np.ndarray,
                       planned_m: np.ndarray, plan: PartitionPlan,
                       touched, *, pad_mode: str = "step"):
    """Shard-restricted monotone insertion repair on the serial ring.

    ``planned_m`` is the pre-delta register matrix in the plan's row order
    (``StoreEntry.planned_matrix()``), a sound lower bound of the post-delta
    fixpoint; ``g`` is the *new* (post-delta, dst-sorted) graph; ``touched``
    is ``DeltaReport.plan_shards_touched`` — the vertex shards the delta's
    endpoints land in.

    Sweeps start restricted to buckets reading from the touched shards and
    widen only as changes actually spread (``sweep_propagate_restricted``),
    so a localized delta re-propagates exactly its ``plan_shards_touched``
    and leaves every other shard's buckets un-swept. Returns
    ``(planned_matrix, sweeps, shards_swept)`` with the matrix bit-identical
    to a full rebuild (max-merge fixpoints are unique above a sound lower
    bound — the same soundness argument as service.delta's repair).
    """
    x = np.asarray(x, dtype=np.uint32)
    part = build_partition_2d(g, x, plan.mu_v, plan.mu_s, seed=config.seed,
                              model=config.model, plan=plan, pad_mode=pad_mode)
    grid = np.asarray(planned_m, dtype=np.int8).reshape(
        plan.mu_v, plan.n_loc, part.mu_s, part.j_loc).transpose(0, 2, 1, 3)
    st = _RingState(part, g, config, matrix=grid)
    dirty = set(int(v) for v in touched)
    sweeps = 0
    swept: set = set()
    with trace.span("serial.repair", phase="repair",
                    touched=len(dirty)) as sp:
        while dirty and sweeps < config.max_propagate_iters:
            swept |= dirty
            with trace.span("serial.repair_sweep", dirty=len(dirty),
                            sweep=sweeps):
                dirty = st.sweep_propagate_restricted(dirty)
            sweeps += 1
        sp.annotate(sweeps=sweeps, shards_swept=len(swept))
    planned = st.m.transpose(0, 2, 1, 3).reshape(
        plan.mu_v * plan.n_loc, part.mu_s * part.j_loc)
    return planned, sweeps, tuple(sorted(swept))
