"""Load-balanced partition planner for the 2-D distributed runtime.

Layering:

  * :mod:`repro.partition.plan`    — ``PartitionPlan`` + pluggable
    vertex-assignment strategies (``block`` / ``degree`` / ``edge`` /
    ``random``), all realized as host-side relabeling permutations;
  * :mod:`repro.partition.cost`    — the cost model (edge/bucket imbalance,
    pad waste, ring bytes), predicted at plan time and measured post-build;
  * :mod:`repro.partition.builder` — ``build_partition_2d``: plan ->
    bucketed, per-step-padded device arrays (``Partition2D``);
  * :mod:`repro.partition.serial`  — the serial-ring executor (mesh-free
    reference twin of the ``shard_map`` runtime, used by tests/benchmarks).

``core/distributed.py`` consumes these; seeds/estimates come back in
original vertex ids no matter which plan relabeled the rows.
"""
from repro.partition.builder import Partition2D, build_partition_2d
from repro.partition.cost import PlanStats, measure_partition
from repro.partition.plan import (PartitionPlan, SampledEdges,
                                  available_strategies, plan_partition,
                                  register_strategy, sample_edge_sets)
from repro.partition.serial import find_seeds_ring_serial

__all__ = [
    "Partition2D",
    "PartitionPlan",
    "PlanStats",
    "SampledEdges",
    "available_strategies",
    "build_partition_2d",
    "find_seeds_ring_serial",
    "measure_partition",
    "plan_partition",
    "register_strategy",
    "sample_edge_sets",
]
