"""Load-balanced partition planning — vertex-assignment strategies for the
2-D distributed runtime.

The paper's multi-GPU speedup rests on its "smart load-balancing mechanism":
on power-law graphs a contiguous block split (``own = v // n_loc``) hands one
shard the hubs, and every ring sweep then waits on that shard. A
``PartitionPlan`` fixes this *entirely on host*: it is a relabeling
permutation of the vertex ids such that the runtime's unchanged contiguous
split over the *relabeled* ids balances the per-shard edge work. Device
kernels never see the strategy — they consume the same bucketed arrays plus
one extra ``owned_ids`` vector (relabeled row -> original vertex id) that
keeps register hashes, validity masks, and reported seeds in original-id
space, so results are bit-independent of the plan (see
``repro.partition.serial`` tests).

Strategies (registry, pluggable like the diffusion model zoo):

  * ``block``  — today's scheme: identity permutation, bit-compatible with
                 the pre-planner partition (the default-off baseline).
  * ``degree`` — greedy weighted bin-packing (LPT with per-bin capacity
                 ``n_loc``) on the sampled out+in degree of each vertex —
                 the paper's balancing analogue; cf. the kernel-balancing of
                 Göktürk & Kaya (arXiv:2008.03095).
  * ``edge``   — balance the per-(write-shard, ring-step) bucket loads
                 directly: greedy placement minimizing the peak bucket a
                 vertex's already-placed neighborhood would create.
  * ``random`` — seeded balanced random assignment (test/baseline aid:
                 results must be invariant under any relabeling).

Vertex weights honor the sample space: when ``x`` is given, each edge counts
once per sim shard whose FASST chunk samples it (exactly the multiplicity
the bucket arrays will carry); without ``x`` every real edge counts once.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.graphs.structs import Graph
from repro.obs import metrics, trace
from repro.partition.cost import PlanStats, predicted_stats


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A vertex relabeling that the 2-D partition builder keys on.

    ``perm`` maps original ids to relabeled ids over the padded id space
    ``[0, n_pad)`` (``n_pad`` is rounded so ``mu_v | n_pad``); shard
    ``v`` owns relabeled rows ``[v * n_loc, (v+1) * n_loc)``. ``inv_perm``
    is the inverse (relabeled -> original); padding ids (>= n) fill the
    leftover slots so every shard owns exactly ``n_loc`` rows.
    """

    strategy: str
    n: int
    n_pad: int
    n_loc: int
    mu_v: int
    mu_s: int
    perm: np.ndarray       # int32[n_pad] original id -> relabeled id
    inv_perm: np.ndarray   # int32[n_pad] relabeled id -> original id
    predicted: Optional[PlanStats] = None

    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning vertex-shard of each original vertex id."""
        return (self.perm[np.asarray(ids, dtype=np.int64)] // self.n_loc).astype(np.int32)

    def local_row_of(self, ids: np.ndarray) -> np.ndarray:
        """Row within the owning shard's register block."""
        return (self.perm[np.asarray(ids, dtype=np.int64)] % self.n_loc).astype(np.int32)

    def owned_ids(self) -> np.ndarray:
        """int32[mu_v, n_loc] original vertex id per (shard, local row)."""
        return self.inv_perm.reshape(self.mu_v, self.n_loc)

    def validate(self, g: Graph) -> None:
        if g.n != self.n:
            raise ValueError(f"plan built for n={self.n}, graph has n={g.n}")

    @staticmethod
    def from_permutation(n: int, mu_v: int, mu_s: int, perm: np.ndarray,
                         *, strategy: str = "custom") -> "PartitionPlan":
        """Rebuild a plan from a persisted/explicit permutation (the store
        snapshot path). ``perm`` must be a permutation of [0, len(perm))
        with mu_v | len(perm)."""
        perm = np.asarray(perm, dtype=np.int32)
        n_pad = perm.shape[0]
        if n_pad % mu_v != 0:
            raise ValueError(f"len(perm)={n_pad} not divisible by mu_v={mu_v}")
        inv = np.empty_like(perm)
        inv[perm] = np.arange(n_pad, dtype=np.int32)
        return PartitionPlan(strategy=strategy, n=n, n_pad=n_pad,
                             n_loc=n_pad // mu_v, mu_v=mu_v, mu_s=mu_s,
                             perm=perm, inv_perm=inv)


# ---------------------------------------------------------------------------
# Shared plan/build preprocessing + vertex weights (sampled out+in degree)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SampledEdges:
    """The O(m * mu_s) host preprocessing both the planner and the bucket
    builder need: model edge operands, FASST sample chunks, and each sim
    shard's sampled edge set. Compute once (``sample_edge_sets``) and pass
    to both ``plan_partition`` and ``build_partition_2d``."""

    ep: object             # diffusion EdgeParams (h, lo, thr)
    x_shards: np.ndarray   # uint32[mu_s, j_loc]
    masks: tuple           # per sim shard: int64 indices of its sampled edges


def sample_edge_sets(g: Graph, x: np.ndarray, mu_s: int, *, seed: int = 0,
                     model: str = "wc", method: str = "fasst") -> SampledEdges:
    from repro.core.fasst import _sampled_by_any, partition_samples
    from repro.diffusion import resolve as resolve_model

    mdl = resolve_model(model)
    ep = mdl.edge_params(g, seed=seed)
    x_shards, _ = partition_samples(np.asarray(x, dtype=np.uint32), mu_s,
                                    method=method)
    masks = tuple(
        np.nonzero(_sampled_by_any(ep.h, ep.thr, x_shards[s], lo=ep.lo,
                                   predicate=mdl.predicate))[0]
        for s in range(mu_s))
    return SampledEdges(ep=ep, x_shards=x_shards, masks=masks)


def _edge_multiplicity(g: Graph, x: Optional[np.ndarray], mu_s: int, *,
                       seed: int, model: str, method: str,
                       sampled: Optional[SampledEdges]) -> np.ndarray:
    """int64[m_real] per-edge weight: how many sim shards sample the edge
    (the multiplicity the bucket arrays will carry), or 1 per real edge when
    no sample vector is given."""
    if sampled is None:
        if x is None:
            return np.ones(g.m_real, dtype=np.int64)
        sampled = sample_edge_sets(g, x, mu_s, seed=seed, model=model,
                                   method=method)
    c = np.bincount(np.concatenate(sampled.masks), minlength=g.m)
    return c[: g.m_real].astype(np.int64)


def _vertex_weights(g: Graph, c_e: np.ndarray) -> np.ndarray:
    """int64[n] sampled out+in degree (the per-vertex write work a shard
    inherits by owning the vertex: propagate writes by src, cascade by dst)."""
    src = g.src[: g.m_real].astype(np.int64)
    dst = g.dst[: g.m_real].astype(np.int64)
    w = np.bincount(src, weights=c_e, minlength=g.n)
    w += np.bincount(dst, weights=c_e, minlength=g.n)
    return w.astype(np.int64)


# ---------------------------------------------------------------------------
# Assignment strategies: each returns int32[n] owner per real vertex
# ---------------------------------------------------------------------------


def _assign_block(g: Graph, c_e, w_v, mu_v: int, n_loc: int, seed: int) -> np.ndarray:
    return (np.arange(g.n, dtype=np.int64) // n_loc).astype(np.int32)


def _assign_random(g: Graph, c_e, w_v, mu_v: int, n_loc: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    shuffled = rng.permutation(g.n)
    owner = np.empty(g.n, dtype=np.int32)
    owner[shuffled] = (np.arange(g.n, dtype=np.int64) // n_loc).astype(np.int32)
    return owner


def _assign_degree(g: Graph, c_e, w_v, mu_v: int, n_loc: int, seed: int) -> np.ndarray:
    """LPT bin-packing with per-bin capacity: heaviest vertex first into the
    lightest non-full bin. Deterministic (ties break by bin index)."""
    owner = np.empty(g.n, dtype=np.int32)
    counts = np.zeros(mu_v, dtype=np.int64)
    heap = [(0, b) for b in range(mu_v)]  # (load, bin)
    heapq.heapify(heap)
    order = np.argsort(-w_v, kind="stable")
    for v in order:
        while True:
            load, b = heapq.heappop(heap)
            if counts[b] < n_loc:
                break  # a full bin stays full — drop its entry for good
        owner[v] = b
        counts[b] += 1
        heapq.heappush(heap, (load + int(w_v[v]), b))
    return owner


def _assign_edge(g: Graph, c_e, w_v, mu_v: int, n_loc: int, seed: int) -> np.ndarray:
    """Balance the per-(write-shard, ring-step) bucket loads directly.

    Greedy over vertices in descending weight: place each vertex in the
    non-full bin that minimizes the peak load across every bucket the
    vertex's already-placed neighborhood touches — its own write buckets
    (propagate by out-edges, cascade by in-edges) AND the neighbors' write
    buckets its placement lands in. O(n * mu_v^2 + m)."""
    n = g.n
    src = g.src[: g.m_real].astype(np.int64)
    dst = g.dst[: g.m_real].astype(np.int64)
    out_order = np.argsort(src, kind="stable")
    out_ptr = np.concatenate([[0], np.cumsum(np.bincount(src, minlength=n))]).astype(np.int64)
    out_nbr = dst[out_order]
    out_w = c_e[out_order].astype(np.float64)
    in_order = np.argsort(dst, kind="stable")
    in_ptr = np.concatenate([[0], np.cumsum(np.bincount(dst, minlength=n))]).astype(np.int64)
    in_nbr = src[in_order]
    in_w = c_e[in_order].astype(np.float64)

    owner = np.full(n, -1, dtype=np.int32)
    counts = np.zeros(mu_v, dtype=np.int64)
    prop = np.zeros((mu_v, mu_v), dtype=np.float64)  # [write shard, ring step]
    casc = np.zeros((mu_v, mu_v), dtype=np.float64)
    steps = np.arange(mu_v)
    # owner o sits at ring step (o - b) % mu_v of bin b's sweep; precompute
    # both index tables once
    own_at_step = (steps[:, None] + steps[None, :]) % mu_v   # [b, k] -> o
    step_of_bin = (steps[None, :] - steps[:, None]) % mu_v   # [o, b] -> k

    for v in np.argsort(-w_v, kind="stable"):
        oo = owner[out_nbr[out_ptr[v]: out_ptr[v + 1]]]
        ow = out_w[out_ptr[v]: out_ptr[v + 1]]
        sel = oo >= 0
        out_by = np.bincount(oo[sel], weights=ow[sel], minlength=mu_v)
        io = owner[in_nbr[in_ptr[v]: in_ptr[v + 1]]]
        iw = in_w[in_ptr[v]: in_ptr[v + 1]]
        sel = io >= 0
        in_by = np.bincount(io[sel], weights=iw[sel], minlength=mu_v)

        # own write rows if v lands in bin b: bucket (b, k) gains the edges
        # to/from neighbors owned by (b + k) % mu_v
        peak_own = np.maximum(prop + out_by[own_at_step],
                              casc + in_by[own_at_step]).max(axis=1)
        # neighbors' write rows: owner o's bucket at step (b - o) % mu_v
        # gains in_by[o] (propagate, u->v writes at owner[u]) resp. out_by[o]
        peak_other = np.maximum(prop[steps[:, None], step_of_bin] + in_by[:, None],
                                casc[steps[:, None], step_of_bin] + out_by[:, None]).max(axis=0)
        peak = np.maximum(peak_own, peak_other)
        tie = prop.sum(axis=1) + casc.sum(axis=1)  # prefer the lighter bin
        peak[counts >= n_loc] = np.inf
        b = int(np.lexsort((steps, tie, peak))[0])

        owner[v] = b
        counts[b] += 1
        prop[b] += out_by[own_at_step[b]]
        casc[b] += in_by[own_at_step[b]]
        np.add.at(prop, (steps, step_of_bin[:, b]), in_by)
        np.add.at(casc, (steps, step_of_bin[:, b]), out_by)
    return owner


_STRATEGIES: Dict[str, Callable] = {}


def register_strategy(name: str, fn: Callable) -> None:
    """Register a vertex-assignment strategy: ``fn(g, c_e, w_v, mu_v, n_loc,
    seed) -> int32[n] owner per real vertex`` (< n_loc vertices per owner)."""
    if name in _STRATEGIES:
        raise ValueError(f"partition strategy {name!r} already registered")
    _STRATEGIES[name] = fn


def available_strategies() -> Tuple[str, ...]:
    return tuple(_STRATEGIES)


register_strategy("block", _assign_block)
register_strategy("degree", _assign_degree)
register_strategy("edge", _assign_edge)
register_strategy("random", _assign_random)


# ---------------------------------------------------------------------------
# Planner entry point
# ---------------------------------------------------------------------------


def plan_partition(g: Graph, mu_v: int, *, mu_s: int = 1,
                   strategy: str = "block", x: Optional[np.ndarray] = None,
                   seed: int = 0, model: str = "wc", method: str = "fasst",
                   sampled: Optional[SampledEdges] = None) -> PartitionPlan:
    """Build a :class:`PartitionPlan` for a ``(mu_v, mu_s)`` device grid.

    ``x`` (the sample vector) sharpens the vertex weights to the edges the
    sim shards actually sample; without it plain degrees are used.
    ``sampled`` passes the :func:`sample_edge_sets` preprocessing in when
    the caller also builds the partition (it is the dominant host cost —
    don't pay it twice). The returned plan carries ``predicted`` cost-model
    stats (edge/bucket imbalance and ring bytes) so callers can compare
    strategies before paying for the full bucket build.
    """
    fn = _STRATEGIES.get(strategy)
    if fn is None:
        raise KeyError(f"unknown partition strategy {strategy!r}; "
                       f"registered: {sorted(_STRATEGIES)}")
    with trace.span("partition.plan", phase="plan", strategy=strategy,
                    mu_v=mu_v, mu_s=mu_s, n=g.n):
        n_pad = g.n_pad + ((-g.n_pad) % mu_v)
        n_loc = n_pad // mu_v
        c_e = _edge_multiplicity(g, x, mu_s, seed=seed, model=model,
                                 method=method, sampled=sampled)
        w_v = _vertex_weights(g, c_e)
        owner = np.asarray(fn(g, c_e, w_v, mu_v, n_loc, seed), dtype=np.int64)
        if owner.shape[0] != g.n:
            raise ValueError(f"strategy {strategy!r} assigned {owner.shape[0]} "
                             f"vertices, expected {g.n}")
        counts = np.bincount(owner, minlength=mu_v)
        if counts.max(initial=0) > n_loc:
            raise ValueError(f"strategy {strategy!r} overfilled a shard: "
                             f"{counts.tolist()} vs capacity {n_loc}")
        # padding ids fill the leftover slots, ascending id into ascending shard
        free = n_loc - counts
        pad_owner = np.repeat(np.arange(mu_v, dtype=np.int64), free)
        owner_all = np.concatenate([owner, pad_owner])
        # stable sort groups ids by owner, keeping ascending original id within
        # each shard — block's identity assignment relabels to the identity
        inv_perm = np.argsort(owner_all, kind="stable").astype(np.int32)
        perm = np.empty_like(inv_perm)
        perm[inv_perm] = np.arange(n_pad, dtype=np.int32)

        if sampled is not None:
            j_loc = int(sampled.x_shards.shape[1])
        else:
            j_loc = (np.asarray(x).shape[0] // mu_s) if x is not None else 0
        stats = predicted_stats(g, strategy, perm, c_e, mu_v, mu_s, n_loc, j_loc)
    metrics.gauge("partition.ring_bytes_per_sweep",
                  strategy=strategy).set(stats.ring_bytes_per_sweep)
    metrics.gauge("partition.edge_imbalance",
                  strategy=strategy).set(stats.edge_imbalance)
    metrics.gauge("partition.bucket_imbalance",
                  strategy=strategy).set(stats.bucket_imbalance)
    return PartitionPlan(strategy=strategy, n=g.n, n_pad=n_pad, n_loc=n_loc,
                         mu_v=mu_v, mu_s=mu_s, perm=perm, inv_perm=inv_perm,
                         predicted=stats)
