"""Host-side 2-D partition build: plan -> bucketed, padded device arrays.

Moved out of ``core/distributed.py`` so the planning layer (``plan.py``) and
the device runtime are decoupled: the builder consumes a
:class:`~repro.partition.plan.PartitionPlan` (vertex relabeling) and emits
the fixed-shape bucket arrays the ``shard_map`` body sweeps over. Two
padding modes:

  * ``"global"`` — every bucket padded to one global ``b_max`` (the
    pre-planner behaviour, kept bit-compatible for the golden test);
  * ``"step"``   — each ring step padded to its own rounded max across
    shards (dead-slot work shrinks to what the *widest shard of that step*
    needs; empty steps collapse to width 0 and the runtime skips them).

Bucket arrays are per-step tuples: ``p_h[k]`` has shape
``(mu_v, mu_s, B_k)`` — [write-owner shard, sim shard, slot]. At ring step
``k`` vertex-shard ``v`` reads the register block of shard
``(v + k) % mu_v``. ``owned_ids[v, i]`` is the *original* vertex id of
shard ``v``'s local row ``i``; register hashes, validity masks, and
reported seeds all go through it, which is what makes results independent
of the plan's relabeling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.graphs.structs import Graph
from repro.obs import trace
from repro.partition.plan import (PartitionPlan, SampledEdges, plan_partition,
                                  sample_edge_sets)


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Everything the shard_map body consumes, already bucketed + padded."""

    n: int
    n_pad: int                 # padded so mu_v | n_pad
    n_loc: int
    j_loc: int
    mu_v: int
    mu_s: int
    x_shards: np.ndarray       # uint32[mu_s, j_loc] (FASST-sorted chunks)
    owned_ids: np.ndarray      # int32[mu_v, n_loc] original vertex id per row
    # propagate buckets: write row = src (local id), read row = dst (block id)
    p_h: Tuple[np.ndarray, ...]  # k -> uint32[mu_v, mu_s, B_k] edge hash
    p_w: Tuple[np.ndarray, ...]  # int32 — local write row
    p_r: Tuple[np.ndarray, ...]  # int32 — row within the read block
    p_t: Tuple[np.ndarray, ...]  # uint32 — sampling threshold / interval width
    p_l: Tuple[np.ndarray, ...]  # uint32 — interval low endpoint (model zoo)
    # cascade buckets: write row = dst (local id), read row = src (block id)
    c_h: Tuple[np.ndarray, ...]
    c_w: Tuple[np.ndarray, ...]
    c_r: Tuple[np.ndarray, ...]
    c_t: Tuple[np.ndarray, ...]
    c_l: Tuple[np.ndarray, ...]
    edge_counts: np.ndarray    # int64[mu_v, mu_s] real (unpadded) edges per shard
    p_counts: np.ndarray       # int64[mu_v, mu_s, mu_v] real edges per bucket
    c_counts: np.ndarray
    comm_bytes_per_sweep: int  # ring traffic per device per sweep (both phases equal)
    plan: Optional[PartitionPlan] = None
    pad_mode: str = "step"

    def stats(self):
        """Measured cost-model stats (see ``repro.partition.cost``)."""
        from repro.partition.cost import measure_partition

        return measure_partition(self)


def _bucketize_steps(ids: np.ndarray, w_own: np.ndarray, k: np.ndarray,
                     eh: np.ndarray, wrow: np.ndarray, rrow: np.ndarray,
                     thr: np.ndarray, elo: np.ndarray, mu_v: int,
                     widths: np.ndarray):
    """Scatter per-edge data into per-step padded buckets.

    Returns, for each ring step ``k``, five ``(mu_v, widths[k])`` arrays
    ``(h, w, r, t, l)``. In-bucket order is ascending original edge id —
    identical to the historical single-``b_max`` layout, so ``"global"``
    padding reproduces it bit-for-bit."""
    steps = []
    order = np.lexsort((ids, w_own, k))
    w_s, k_s = w_own[order], k[order]
    eh_s, wr_s, rr_s, th_s, lo_s = (eh[order], wrow[order], rrow[order],
                                    thr[order], elo[order])
    keys = k_s.astype(np.int64) * mu_v + w_s
    boundaries = np.searchsorted(keys, np.arange(mu_v * mu_v + 1))
    for kk in range(mu_v):
        b_k = int(widths[kk])
        h_out = np.zeros((mu_v, b_k), dtype=np.uint32)
        w_out = np.zeros((mu_v, b_k), dtype=np.int32)
        r_out = np.zeros((mu_v, b_k), dtype=np.int32)
        t_out = np.zeros((mu_v, b_k), dtype=np.uint32)  # thr=0 padding is inert
        l_out = np.zeros((mu_v, b_k), dtype=np.uint32)
        for v in range(mu_v):
            lo, hi = boundaries[kk * mu_v + v], boundaries[kk * mu_v + v + 1]
            cnt = hi - lo
            if cnt == 0:
                continue
            h_out[v, :cnt] = eh_s[lo:hi]
            w_out[v, :cnt] = wr_s[lo:hi]
            r_out[v, :cnt] = rr_s[lo:hi]
            t_out[v, :cnt] = th_s[lo:hi]
            l_out[v, :cnt] = lo_s[lo:hi]
        steps.append((h_out, w_out, r_out, t_out, l_out))
    return steps


def _round_up(v: np.ndarray, block: int) -> np.ndarray:
    return v + (-v) % block


@trace.traced("partition.build_buckets", phase="plan")
def build_partition_2d(g: Graph, x: np.ndarray, mu_v: int, mu_s: int, *,
                       seed: int = 0, method: str = "fasst",
                       edge_block: int = 256, model: str = "wc",
                       plan: Optional[PartitionPlan] = None,
                       pad_mode: str = "step",
                       sampled: Optional[SampledEdges] = None) -> Partition2D:
    """FASST sample-space split × planned vertex split, fully bucketed.

    ``plan=None`` builds the bit-compatible ``block`` plan (identity
    relabeling). ``pad_mode="global"`` additionally restores the historical
    one-``b_max``-for-everything padding. ``sampled`` passes in the
    :func:`~repro.partition.plan.sample_edge_sets` preprocessing when the
    caller already ran it for the planner.
    """
    if pad_mode not in ("global", "step"):
        raise ValueError(f"pad_mode must be 'global' or 'step', got {pad_mode!r}")
    r = x.shape[0]
    assert r % mu_s == 0
    if sampled is None:
        sampled = sample_edge_sets(g, x, mu_s, seed=seed, model=model,
                                   method=method)
    x_shards, masks = sampled.x_shards, sampled.masks
    j_loc = r // mu_s

    if plan is None:
        plan = plan_partition(g, mu_v, mu_s=mu_s, strategy="block", seed=seed,
                              model=model)
    plan.validate(g)
    if plan.mu_v != mu_v:
        raise ValueError(f"plan built for mu_v={plan.mu_v}, asked for {mu_v}")
    n_pad, n_loc = plan.n_pad, plan.n_loc
    ep = sampled.ep
    eh_all, lo_all, thr_all = ep.h, ep.lo, ep.thr
    rows = plan.perm[g.src.astype(np.int64)].astype(np.int64)
    cols = plan.perm[g.dst.astype(np.int64)].astype(np.int64)
    own_src = (rows // n_loc).astype(np.int32)
    own_dst = (cols // n_loc).astype(np.int32)
    # bucket counts first so every shard pads identically
    counts_p = np.zeros((mu_v, mu_s, mu_v), dtype=np.int64)
    counts_c = np.zeros((mu_v, mu_s, mu_v), dtype=np.int64)
    counts = np.zeros((mu_v, mu_s), dtype=np.int64)
    for s in range(mu_s):
        ids = masks[s]
        kp = (own_dst[ids] - own_src[ids]) % mu_v
        kc = (own_src[ids] - own_dst[ids]) % mu_v
        bp = np.bincount(own_src[ids].astype(np.int64) * mu_v + kp,
                         minlength=mu_v * mu_v).reshape(mu_v, mu_v)
        bc = np.bincount(own_dst[ids].astype(np.int64) * mu_v + kc,
                         minlength=mu_v * mu_v).reshape(mu_v, mu_v)
        counts_p[:, s, :] = bp
        counts_c[:, s, :] = bc
        counts[:, s] = bp.sum(axis=1)
    if pad_mode == "global":
        b_max = int(max(counts_p.max(initial=0), counts_c.max(initial=0), 1))
        b_max += (-b_max) % edge_block
        widths_p = np.full(mu_v, b_max, dtype=np.int64)
        widths_c = widths_p
    else:
        # per-step padding: each ring step pays for its own widest bucket
        widths_p = _round_up(counts_p.max(axis=(0, 1)), edge_block)
        widths_c = _round_up(counts_c.max(axis=(0, 1)), edge_block)

    p_parts, c_parts = [], []
    for s in range(mu_s):
        ids = masks[s]
        e_h, e_t, e_l = eh_all[ids], thr_all[ids], lo_all[ids]
        wsrc, wdst = own_src[ids], own_dst[ids]
        kp = (wdst - wsrc) % mu_v
        kc = (wsrc - wdst) % mu_v
        src_loc = (rows[ids] % n_loc).astype(np.int32)
        dst_loc = (cols[ids] % n_loc).astype(np.int32)
        p_parts.append(_bucketize_steps(ids, wsrc, kp, e_h, src_loc, dst_loc,
                                        e_t, e_l, mu_v, widths_p))
        c_parts.append(_bucketize_steps(ids, wdst, kc, e_h, dst_loc, src_loc,
                                        e_t, e_l, mu_v, widths_c))

    def stack(parts, i):
        # parts[s][k][i] is (mu_v, B_k); stack sim shards -> (mu_v, mu_s, B_k)
        return tuple(np.stack([parts[s][k][i] for s in range(mu_s)], axis=1)
                     for k in range(mu_v))

    comm = (mu_v - 1) * n_loc * j_loc  # int8 register block ring traffic / sweep
    return Partition2D(
        n=g.n, n_pad=n_pad, n_loc=n_loc, j_loc=j_loc, mu_v=mu_v, mu_s=mu_s,
        x_shards=x_shards, owned_ids=plan.owned_ids(),
        p_h=stack(p_parts, 0), p_w=stack(p_parts, 1), p_r=stack(p_parts, 2),
        p_t=stack(p_parts, 3), p_l=stack(p_parts, 4),
        c_h=stack(c_parts, 0), c_w=stack(c_parts, 1), c_r=stack(c_parts, 2),
        c_t=stack(c_parts, 3), c_l=stack(c_parts, 4),
        edge_counts=counts, p_counts=counts_p, c_counts=counts_c,
        comm_bytes_per_sweep=comm, plan=plan, pad_mode=pad_mode)
