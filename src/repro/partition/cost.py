"""Partition cost model: the numbers a planner is judged by.

Three quantities decide whether a 2-D partition is balanced:

  * **edge imbalance** — max/mean of per-device sampled-edge counts; the
    busiest device bounds every sweep (straggler bound, paper Tables 5/7).
  * **bucket imbalance** — max/mean of per-(write-shard, ring-step) bucket
    loads; with per-step padding the widest bucket of a step sets that
    step's padded width for *every* device.
  * **pad waste** — fraction of padded bucket slots holding no real edge;
    dead slots still cost full predicate + gather work on device.

``predicted_stats`` runs at plan time from the relabeling alone (no bucket
build); ``measure_partition`` reads the same stats off a finished
``Partition2D`` so predicted-vs-actual drift is visible in benchmarks
(``benchmarks/partition_balance.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import metrics


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Cost-model summary for one partition (predicted or measured)."""

    source: str                  # "predicted" | "measured"
    strategy: str
    mu_v: int
    mu_s: int
    edges_per_shard: np.ndarray  # int64[mu_v] sampled edges written per vertex-shard
    edge_imbalance: float        # max/mean of per-device edge counts
    bucket_imbalance: float      # max/mean of per-(shard, step) bucket loads
    pad_waste_frac: float        # dead padded slots / total padded slots
    ring_bytes_per_sweep: int    # int8 register-block ppermute traffic per device

    def describe(self) -> str:
        return (f"[{self.source}:{self.strategy}] "
                f"edge_imb={self.edge_imbalance:.2f} "
                f"bucket_imb={self.bucket_imbalance:.2f} "
                f"pad_waste={self.pad_waste_frac * 100:.1f}% "
                f"ring_B={self.ring_bytes_per_sweep}")


def _imbalance(loads: np.ndarray) -> float:
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    mean = loads.mean() if loads.size else 0.0
    return float(loads.max(initial=0.0) / mean) if mean > 0 else 1.0


def predicted_stats(g, strategy: str, perm: np.ndarray, c_e: np.ndarray,
                    mu_v: int, mu_s: int, n_loc: int, j_loc: int) -> PlanStats:
    """Plan-time stats from the relabeling permutation and per-edge sample
    multiplicities (edge e counted once per sim shard sampling it)."""
    src = g.src[: g.m_real].astype(np.int64)
    dst = g.dst[: g.m_real].astype(np.int64)
    own_src = perm[src].astype(np.int64) // n_loc
    own_dst = perm[dst].astype(np.int64) // n_loc
    edges = np.bincount(own_src, weights=c_e, minlength=mu_v).astype(np.int64)
    kp = (own_dst - own_src) % mu_v
    kc = (own_src - own_dst) % mu_v
    bp = np.bincount(own_src * mu_v + kp, weights=c_e, minlength=mu_v * mu_v)
    bc = np.bincount(own_dst * mu_v + kc, weights=c_e, minlength=mu_v * mu_v)
    return PlanStats(
        source="predicted", strategy=strategy, mu_v=mu_v, mu_s=mu_s,
        edges_per_shard=edges, edge_imbalance=_imbalance(edges),
        bucket_imbalance=_imbalance(np.concatenate([bp, bc])),
        pad_waste_frac=0.0,
        ring_bytes_per_sweep=(mu_v - 1) * n_loc * j_loc)


def measure_partition(part) -> PlanStats:
    """Measured stats off a built :class:`repro.partition.Partition2D`."""
    counts_p = part.p_counts.astype(np.int64)   # (mu_v, mu_s, mu_v)
    counts_c = part.c_counts.astype(np.int64)
    real = int(counts_p.sum() + counts_c.sum())
    padded = 0
    for arrs in (part.p_h, part.c_h):
        for step in arrs:                        # (mu_v, mu_s, B_k)
            padded += step.size
    strategy = part.plan.strategy if part.plan is not None else "block"
    per_shard = counts_p.sum(axis=(1, 2))
    stats = PlanStats(
        source="measured", strategy=strategy, mu_v=part.mu_v, mu_s=part.mu_s,
        edges_per_shard=per_shard,
        edge_imbalance=_imbalance(part.edge_counts),
        bucket_imbalance=_imbalance(
            np.concatenate([counts_p.reshape(-1), counts_c.reshape(-1)])),
        pad_waste_frac=float(1.0 - real / padded) if padded else 0.0,
        ring_bytes_per_sweep=part.comm_bytes_per_sweep)
    metrics.gauge("partition.pad_waste_frac",
                  strategy=strategy).set(stats.pad_waste_frac)
    metrics.gauge("partition.measured_bucket_imbalance",
                  strategy=strategy).set(stats.bucket_imbalance)
    return stats
