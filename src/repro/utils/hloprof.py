"""Tiny HLO dot-flop profiler for the perf loop.

compiled.as_text() doesn't inline operand shapes, so we build a def-table
(every ``%name = dtype[shape]``) and resolve dot contractions from it.
Groups flops by the jax op_name suffix — enough to answer "which einsum
dominates" during hillclimbing without a real profiler.
"""
from __future__ import annotations

import re
from collections import Counter

_DEF_RE = re.compile(r"%([\w.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(r"%[\w.\-]+ = [a-z0-9]+\[[0-9,]*\][^\n]*? dot\(%([\w.\-]+), %([\w.\-]+)\)")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]+)\}")
_NAME_RE = re.compile(r'op_name="([^"]+)"')


def _shape(dims: str) -> list[int]:
    return [int(d) for d in dims.split(",")] if dims else []


def dot_flop_profile(hlo_text: str, top: int = 12):
    """Returns (total_flops, [(share, flops, count, op_name), ...])."""
    defs: dict[str, list[int]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        defs[m.group(1)] = _shape(m.group(3))
    agg: Counter = Counter()
    cnt: Counter = Counter()
    for line in hlo_text.splitlines():
        if " dot(" not in line:
            continue
        md = _DOT_RE.search(line)
        out = _DEF_RE.search(line)
        if not md or not out:
            continue
        out_n = 1
        for d in _shape(out.group(3)):
            out_n *= d
        lhs = defs.get(md.group(1), [])
        cd = _CDIM_RE.search(line)
        contract = 1
        if cd and lhs:
            for idx in cd.group(1).split(","):
                i = int(idx)
                if i < len(lhs):
                    contract *= lhs[i]
        name = "?"
        nm = _NAME_RE.search(line)
        if nm:
            path = nm.group(1)
            es = re.search(r"([a-zA-Z.,]+->[a-zA-Z.]+)", path)  # einsum spec
            tags = [p for p in ("transpose", "jvp", "remat") if p in path]
            name = (es.group(1) if es else path.split("/")[-1])[:48]
            if tags:
                name += " [" + "+".join(tags) + "]"
        agg[name] += 2 * out_n * contract
        cnt[name] += 1
    total = sum(agg.values())
    rows = [(v / max(total, 1), v, cnt[k], k) for k, v in agg.most_common(top)]
    return total, rows


def print_profile(hlo_text: str, top: int = 12) -> None:
    total, rows = dot_flop_profile(hlo_text, top)
    print(f"total dot flops/device: {total:.4g}")
    for share, v, c, name in rows:
        print(f"{share*100:5.1f}% {v:11.4g} x{c:<3d} {name}")
