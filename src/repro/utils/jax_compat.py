"""jax API drift guards (leaf module — import this from anywhere without
pulling heavy packages in).

Old containers ship a jax without ``jax.sharding.AxisType`` (and the
mesh/shard_map surface that goes with it). ``core.distributed`` re-exports
the flag for tests; ``launch.mesh`` uses it to build version-appropriate
mesh kwargs. Drop this module when the container's jax is bumped.
"""
from __future__ import annotations

import jax

JAX_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
