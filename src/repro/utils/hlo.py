"""Collective-traffic extraction from compiled (post-SPMD) HLO text.

``compiled.as_text()`` is the per-device module after partitioning; every
collective op line carries its result shape and replica groups. We classify
each op and convert payload size to *wire bytes per device* with the
standard ring-algorithm formulas:

    all-reduce       2 * B * (N-1)/N      (reduce-scatter + all-gather)
    all-gather       B_out * (N-1)/N
    reduce-scatter   B_in  * (N-1)/N
    all-to-all       B * (N-1)/N
    collective-permute  B                 (point-to-point)

B = full (result) tensor bytes, N = replica-group size parsed from the op.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0               # per device
    by_kind: dict = dataclasses.field(default_factory=dict)
    op_count: int = 0

    def to_dict(self) -> dict:
        return {"wire_bytes": self.wire_bytes, "by_kind": dict(self.by_kind),
                "op_count": self.op_count}


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats(by_kind=defaultdict(float))
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-done(" in line:   # async pair: count only the -start
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        payload = _shape_bytes(dtype, dims)
        n = _group_size(line)
        if kind == "all-reduce":
            wire = 2.0 * payload * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            wire = payload * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = payload * (n - 1)  # result shape is the shard: input = out*n
        elif kind == "all-to-all":
            wire = payload * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = float(payload)
        stats.wire_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.op_count += 1
    return stats


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if _PAIRS_RE.search(line):
        return 2
    return 2
