"""Three-term roofline model (TPU v5e-like constants fixed by the
assignment): compute / memory / collective times from the compiled dry-run
artifact, the dominant bottleneck, and the useful-FLOPs ratio."""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # B/s per chip
ICI_BW = 50e9             # B/s per link (1 link assumed per transfer — conservative)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_total: float          # 6·N·D (train) or 2·N·D (inference)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak FLOP/s achieved at the bound, counting
        only useful (model) FLOPs: (model_flops/chips / t_bound) / peak."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops_total / self.chips / self.t_bound) / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def annotate_bandwidth(sp, nbytes: int, seconds: float) -> float:
    """Attach achieved GB/s and fraction-of-roof (vs :data:`HBM_BW`) to a
    trace span, so Perfetto lanes carry bandwidth attribution next to the
    wall time. ``sp`` may be a null span (tracing disabled) — ``annotate``
    is then a no-op and only the return value (GB/s) is meaningful. Returns
    0.0 for degenerate timings instead of raising."""
    if seconds <= 0 or nbytes <= 0:
        return 0.0
    gbps = nbytes / seconds / 1e9
    sp.annotate(achieved_gbps=round(gbps, 3),
                frac_of_roof=round(gbps * 1e9 / HBM_BW, 6))
    return gbps


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS for the cell: 6·N_active·D for training, 2·N_active·D for
    inference (D = tokens processed in the lowered step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
