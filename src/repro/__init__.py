"""repro: DiFuseR (distributed sketch-based influence maximization) on TPU/JAX,
plus the assigned LM-architecture zoo sharing the same launch/mesh substrate."""
__version__ = "1.0.0"
