"""repro: DiFuseR — distributed sketch-based influence maximization on
TPU/JAX.

Public (IM-only) surface:

  * :mod:`repro.runtime`   — the unified execution API: ``RunSpec``,
    ``InfluenceSession``, the ``Backend`` registry (``single`` / ``serial``
    / ``mesh``); start here (docs/runtime.md);
  * :mod:`repro.core`      — the Alg. 4 drivers and kernels behind it;
  * :mod:`repro.diffusion` — the diffusion model zoo (wc / ic / lt / dic);
  * :mod:`repro.partition` — the 2-D partition planner + serial-ring
    executor;
  * :mod:`repro.service`   — persistent SketchStore, batched query engine,
    graph-delta repair;
  * :mod:`repro.graphs`, :mod:`repro.baselines`, :mod:`repro.launch`
    (``python -m repro`` front door).

Quarantined: the LM seed-template modules (``repro.models``,
``repro.train``, ``repro.serve``, the per-arch ``repro.configs`` entries,
``launch/{train,serve,specs}.py``) are NOT part of the public API. They are
kept only because legacy tier-1 tests still import them directly; nothing
in the IM pipeline depends on them, they are excluded from ``make lint``'s
import surface, and they may be removed wholesale once those tests are
retired.
"""
__version__ = "1.0.0"

#: Modules that make up the supported API surface (see the docstring).
IM_API_MODULES = (
    "repro.runtime",
    "repro.core",
    "repro.diffusion",
    "repro.partition",
    "repro.service",
    "repro.graphs",
    "repro.baselines",
    "repro.launch.common",
)

#: Quarantined LM seed-template modules — imported by legacy tests only,
#: never by IM code. Not covered by lint's import check; slated for removal.
QUARANTINED_MODULES = (
    "repro.models",
    "repro.train",
    "repro.serve",
    "repro.launch.train",
    "repro.launch.serve",
    "repro.launch.specs",
)
