"""repro: DiFuseR — distributed sketch-based influence maximization on
TPU/JAX.

Public (IM-only) surface:

  * :mod:`repro.runtime`   — the unified execution API: ``RunSpec``,
    ``InfluenceSession``, the ``Backend`` registry (``single`` / ``serial``
    / ``mesh``); start here (docs/runtime.md);
  * :mod:`repro.core`      — the Alg. 4 drivers and kernels behind it;
  * :mod:`repro.diffusion` — the diffusion model zoo (wc / ic / lt / dic);
  * :mod:`repro.partition` — the 2-D partition planner + serial-ring
    executor;
  * :mod:`repro.service`   — persistent SketchStore (host- or
    device-resident banks), batched query engine, graph-delta repair;
  * :mod:`repro.graphs`, :mod:`repro.baselines`, :mod:`repro.configs`
    (IM workload presets), :mod:`repro.launch` (``python -m repro`` front
    door).

The LM seed-template modules (``repro.models``/``train``/``serve``, the
per-arch configs, ``launch/{train,serve,specs}.py``) were quarantined in
PR 4 — nothing in the IM pipeline imported them — and are deleted.
"""
__version__ = "1.0.0"

#: Modules that make up the supported API surface (see the docstring).
IM_API_MODULES = (
    "repro.obs",
    "repro.runtime",
    "repro.core",
    "repro.diffusion",
    "repro.partition",
    "repro.service",
    "repro.tune",
    "repro.graphs",
    "repro.baselines",
    "repro.configs",
    "repro.launch.common",
)
