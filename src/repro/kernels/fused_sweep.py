"""Pallas kernel: ``num_sweeps`` fused SIMULATE sweeps in one launch.

The single-sweep kernel (kernels/sketch_propagate.py) keeps its register
panes VMEM-resident across edge blocks, but the ``local_sweeps`` prologues
of the ring executors re-launch it per sweep — every extra comm-free sweep
round-trips the whole register matrix through HBM. This kernel runs the
sweep loop *inside* the launch: per register-lane tile, the current and
accumulator panes stay in VMEM for all ``num_sweeps`` iterations and HBM
sees the matrix exactly twice (load + final store).

Schedule: grid = (J / lane_tile,), the edge operands broadcast whole to
every grid instance (each tile loops all edges ``num_sweeps`` times — the
fused trade: re-reading the small edge list buys register-pane residency).
``lane_tile`` is the model-aware FASST lane-fill knob surfaced by
``repro.tune`` as ``KernelConfig.lane_fill``: per-register-column
independence of the Jacobi max-merge makes any tile width bit-identical, so
density is purely a performance choice (``lt``'s remixed vertex hash
changes which lanes are live per edge, shifting the optimum).

VMEM working set per instance: two ``(n_pad, lane_tile)`` int8 panes (the
ping-pong pair) plus the edge operands — at n_pad = 64Ki and lane_tile =
128 that is 2 x 8 MiB panes, the same budget as the single-sweep kernel.

The ping-pong pair is expressed as a second *output* pane rather than
``scratch_shapes`` so the kernel also runs under old-jax interpret mode;
the scratch pane is discarded by the wrapper.

Jacobi semantics: every sweep gathers from the previous sweep's pane only,
so results are bit-identical to ``num_sweeps`` applications of
kernels/ref.py's single sweep for any edge order and any lane tile.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import edge_hash, fused_predicate
from repro.kernels.common import REG_TILE, clamp_block
from repro.kernels.sketch_propagate import (pad_edge_operands,
                                            pad_register_axis)

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def _fused_sweep_kernel(src_ref, dst_ref, h_ref, lo_ref, thr_ref, x_ref,
                        m_ref, out_ref, buf_ref, *, num_edges: int,
                        num_sweeps: int, predicate):
    src = src_ref[...]
    dst = dst_ref[...]
    h = h_ref[...].astype(jnp.uint32)
    lo = lo_ref[...].astype(jnp.uint32)
    thr = thr_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)

    buf_ref[...] = m_ref[...]          # "current" pane (previous sweep)

    for _ in range(num_sweeps):        # static unroll: panes stay in VMEM
        out_ref[...] = buf_ref[...]

        def body(i, _):
            u = src[i]
            v = dst[i]
            mask = predicate(h[i], lo[i], thr[i], x)  # fused sampling
            pulled = pl.load(buf_ref, (v, slice(None)))  # Jacobi gather
            contrib = jnp.where(mask, pulled, jnp.full_like(pulled, VISITED))
            cur = pl.load(out_ref, (u, slice(None)))
            # sticky visited: a VISITED register never resurrects
            new = jnp.where(cur == VISITED, cur, jnp.maximum(cur, contrib))
            pl.store(out_ref, (u, slice(None)), new)
            return 0

        jax.lax.fori_loop(0, num_edges, body, 0)
        buf_ref[...] = out_ref[...]    # ping-pong: next sweep reads this


@partial(jax.jit, static_argnames=("seed", "num_sweeps", "lane_tile",
                                   "interpret", "predicate"))
def fused_sweep_pallas(m, src, dst, thr, x, h=None, lo=None, *, seed: int = 0,
                       num_sweeps: int = 1, lane_tile: int = REG_TILE,
                       interpret: bool = True, predicate=None):
    if h is None:
        h = edge_hash(src, dst, seed=seed)
    if lo is None:
        lo = jnp.zeros(thr.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    if num_sweeps <= 0:
        return m
    n_pad, num_regs = m.shape
    num_edges = int(src.shape[0])
    lane_tile = clamp_block(num_regs, lane_tile)
    # edge padding keeps prime/odd edge counts legal on tiled backends
    # (predicate-dead filler; see common.pad_amount) — the in-kernel loop
    # still visits every padded slot, which is a no-op by construction
    src, dst, h, lo, thr = pad_edge_operands(src, dst, h, lo, thr, 8)
    e_pad = int(src.shape[0])
    m_in, x = pad_register_axis(m, x, lane_tile)
    regs_pad = x.shape[0]
    grid = (regs_pad // lane_tile,)
    out, _scratch = pl.pallas_call(
        partial(_fused_sweep_kernel, num_edges=e_pad, num_sweeps=num_sweeps,
                predicate=predicate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((e_pad,), lambda r: (0,)),
            pl.BlockSpec((e_pad,), lambda r: (0,)),
            pl.BlockSpec((e_pad,), lambda r: (0,)),
            pl.BlockSpec((e_pad,), lambda r: (0,)),
            pl.BlockSpec((e_pad,), lambda r: (0,)),
            pl.BlockSpec((lane_tile,), lambda r: (r,)),
            pl.BlockSpec((n_pad, lane_tile), lambda r: (0, r)),
        ],
        out_specs=(pl.BlockSpec((n_pad, lane_tile), lambda r: (0, r)),
                   pl.BlockSpec((n_pad, lane_tile), lambda r: (0, r))),
        out_shape=(jax.ShapeDtypeStruct((n_pad, regs_pad), jnp.int8),
                   jax.ShapeDtypeStruct((n_pad, regs_pad), jnp.int8)),
        interpret=interpret,
    )(src, dst, h, lo, thr, x, m_in)
    return out[:, :num_regs] if regs_pad != num_regs else out
