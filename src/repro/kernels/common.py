"""Shared helpers for the Pallas kernel bodies.

Everything here must be expressible inside a Pallas TPU kernel: uint32
vector arithmetic, shift-based clz (TPU Mosaic has no clz primitive we rely
on), and the murmur-style mixers duplicated from repro.core.sampling so the
kernel bodies have no external dependencies.
"""
from __future__ import annotations

import jax.numpy as jnp

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLD = 0x9E3779B9

# Default TPU tiling. Registers ride the lane dimension (128 lanes per
# vreg); edge blocks are sized so (edge_block x reg_tile) uint32 scratch
# stays well under VMEM.
REG_TILE = 128
EDGE_BLOCK = 512
VERTEX_BLOCK = 256


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (block-shape helper)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def kmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 (kernel-local copy of sampling.mix32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def kregister_hash(vertex: jnp.ndarray, reg: jnp.ndarray, seed: int) -> jnp.ndarray:
    u = vertex.astype(jnp.uint32)
    j = reg.astype(jnp.uint32)
    return kmix32(kmix32(u * jnp.uint32(_GOLD) + jnp.uint32(seed ^ 0x5BD1E995)) ^ (j * jnp.uint32(_M2)))


def kclz32(x: jnp.ndarray) -> jnp.ndarray:
    """clz via 5-step binary search — pure shifts/compares (VPU friendly)."""
    x = x.astype(jnp.uint32)
    n = jnp.full(x.shape, 32, dtype=jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        big = x >= (jnp.uint32(1) << jnp.uint32(shift))
        n = jnp.where(big, n - shift, n)
        x = jnp.where(big, x >> jnp.uint32(shift), x)
    return n - x.astype(jnp.int32)
