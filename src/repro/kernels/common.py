"""Shared helpers for the Pallas kernel bodies.

Everything here must be expressible inside a Pallas TPU kernel: uint32
vector arithmetic, shift-based clz (TPU Mosaic has no clz primitive we rely
on), and the murmur-style mixers duplicated from repro.core.sampling so the
kernel bodies have no external dependencies.
"""
from __future__ import annotations

import jax.numpy as jnp

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLD = 0x9E3779B9

# Default TPU tiling. Registers ride the lane dimension (128 lanes per
# vreg); edge blocks are sized so (edge_block x reg_tile) uint32 scratch
# stays well under VMEM.
REG_TILE = 128
EDGE_BLOCK = 512
VERTEX_BLOCK = 256


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (block-shape helper).

    Divisor search degrades badly on near-prime ``n`` (worst case block=1 —
    scalar grid steps). The edge-dimension kernels therefore no longer use
    it: they clamp the block with :func:`clamp_block` and pad operands up to
    a block multiple with predicate-dead filler (``pad_amount``). Kept for
    the vertex-dimension kernels (sketch_fill / cardinality_stats), whose
    ``n_pad`` is already padded by the graph layer.
    """
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def clamp_block(n: int, block: int) -> int:
    """Block size actually used for an ``n``-long axis: at least 1, at most
    ``n`` (a block larger than the axis is one full-axis block)."""
    return max(1, min(int(block), int(n)))


def pad_amount(n: int, block: int) -> int:
    """Trailing padding that rounds ``n`` up to a multiple of ``block``.

    Edge operands padded this way use width-0 filler (``thr = 0``): the
    universal interval predicate ``((X ^ h) - lo) mod 2^32 < thr`` can never
    fire with ``thr == 0``, so a padded edge contributes the max-merge
    identity (VISITED) to propagate sweeps and never marks anything in
    cascade sweeps — results are bit-identical to the unpadded axis.
    """
    return (-int(n)) % int(block)


def kmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 (kernel-local copy of sampling.mix32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def kregister_hash(vertex: jnp.ndarray, reg: jnp.ndarray, seed: int) -> jnp.ndarray:
    u = vertex.astype(jnp.uint32)
    j = reg.astype(jnp.uint32)
    return kmix32(kmix32(u * jnp.uint32(_GOLD) + jnp.uint32(seed ^ 0x5BD1E995)) ^ (j * jnp.uint32(_M2)))


def kclz32(x: jnp.ndarray) -> jnp.ndarray:
    """clz via 5-step binary search — pure shifts/compares (VPU friendly)."""
    x = x.astype(jnp.uint32)
    n = jnp.full(x.shape, 32, dtype=jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        big = x >= (jnp.uint32(1) << jnp.uint32(shift))
        n = jnp.where(big, n - shift, n)
        x = jnp.where(big, x >> jnp.uint32(shift), x)
    return n - x.astype(jnp.int32)
