"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

``impl`` selects the backend:
  * "ref"     — pure-jnp oracle (kernels/ref.py). Fast under XLA:CPU; the
                default everywhere in this container.
  * "pallas"  — Pallas body in interpret mode (CPU) — used by the kernel
                equivalence tests; on a real TPU the same call sites flip
                ``interpret=False``.

All wrappers take the padded fixed-shape arrays produced by repro.graphs.

Diffusion-model hook (shared by both backends): optional per-edge ``h``
(sample-independent hash) and ``lo`` (interval low endpoint) operands plus a
static ``predicate`` callable. Omitting them reproduces the legacy
weighted-cascade behaviour bit-for-bit — h is then hashed from (src, dst,
seed) on the fly and the predicate is the threshold compare.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.common import EDGE_BLOCK, REG_TILE
from repro.kernels.cascade_step import cascade_sweep_pallas
from repro.kernels.fused_sample import fused_sample_pallas
from repro.kernels.fused_sweep import fused_sweep_pallas
from repro.kernels.sketch_cardinality import cardinality_stats_pallas
from repro.kernels.sketch_fill import sketch_fill_pallas
from repro.kernels.sketch_propagate import propagate_sweep_pallas

_INTERPRET = True  # flipped to False on real TPU deployments


def fused_sample(src, dst, thr, x, *, seed: int = 0, impl: str = "ref",
                 h=None, lo=None, predicate=None,
                 edge_block: int = 0, reg_tile: int = 0):
    if impl == "ref":
        return _ref.fused_sample_ref(src, dst, thr, x, h, lo, seed=seed,
                                     predicate=predicate)
    return fused_sample_pallas(src, dst, thr, x, h, lo, seed=seed,
                               predicate=predicate, interpret=_INTERPRET,
                               edge_block=edge_block or EDGE_BLOCK,
                               reg_tile=reg_tile or REG_TILE)


def sketch_fill(m, *, reg_offset: int = 0, seed: int = 0, impl: str = "ref"):
    if impl == "ref":
        return _ref.sketch_fill_ref(m, reg_offset=reg_offset, seed=seed)
    return sketch_fill_pallas(m, reg_offset=reg_offset, seed=seed, interpret=_INTERPRET)


def propagate_sweep(m, src, dst, thr, x, *, seed: int = 0, impl: str = "ref",
                    edge_chunk: int = 2048, h=None, lo=None, predicate=None,
                    edge_block: int = 0, reg_tile: int = 0):
    if impl == "ref":
        return _ref.propagate_sweep_ref(
            m, src, dst, thr, x, h, lo, seed=seed, predicate=predicate,
            edge_chunk=edge_chunk)
    return propagate_sweep_pallas(m, src, dst, thr, x, h, lo, seed=seed,
                                  predicate=predicate, interpret=_INTERPRET,
                                  edge_block=edge_block or EDGE_BLOCK,
                                  reg_tile=reg_tile or REG_TILE)


def fused_sweep(m, src, dst, thr, x, *, num_sweeps: int = 1, seed: int = 0,
                impl: str = "ref", edge_chunk: int = 2048, h=None, lo=None,
                predicate=None, lane_fill: int = 0, reg_tile: int = 0):
    """``num_sweeps`` propagate sweeps fused into one launch — bit-identical
    to ``num_sweeps`` calls of :func:`propagate_sweep` on the same operands.
    ``lane_fill`` is the register-slab width (0 = full width / library
    default); see kernels/fused_sweep.py for the VMEM residency argument."""
    if impl == "ref":
        return _ref.fused_sweep_ref(
            m, src, dst, thr, x, h, lo, num_sweeps=num_sweeps, seed=seed,
            predicate=predicate, edge_chunk=edge_chunk, lane_fill=lane_fill)
    return fused_sweep_pallas(m, src, dst, thr, x, h, lo, seed=seed,
                              num_sweeps=num_sweeps, predicate=predicate,
                              interpret=_INTERPRET,
                              lane_tile=lane_fill or reg_tile or REG_TILE)


def cascade_sweep(m, src, dst, thr, x, *, seed: int = 0, impl: str = "ref",
                  edge_chunk: int = 2048, h=None, lo=None, predicate=None,
                  edge_block: int = 0, reg_tile: int = 0):
    if impl == "ref":
        return _ref.cascade_sweep_ref(
            m, src, dst, thr, x, h, lo, seed=seed, predicate=predicate,
            edge_chunk=edge_chunk)
    return cascade_sweep_pallas(m, src, dst, thr, x, h, lo, seed=seed,
                                predicate=predicate, interpret=_INTERPRET,
                                edge_block=edge_block or EDGE_BLOCK,
                                reg_tile=reg_tile or REG_TILE)


def cardinality_stats(m, *, impl: str = "ref"):
    if impl == "ref":
        stat, count = _ref.cardinality_stats_ref(m)
    else:
        stat, count = cardinality_stats_pallas(m, interpret=_INTERPRET)
    return jnp.stack([stat, count])
