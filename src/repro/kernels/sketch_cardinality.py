"""Pallas kernel: per-vertex cardinality sufficient statistics.

For seed selection every vertex needs its estimator statistics
(sum_j 2^-M[u,j] over valid registers, and the valid count). These are the
shard-local *additive* halves of the harmonic-mean estimator (paper eq. (7)
/ Fig. 3): shards psum them and finish the estimate replicated.

TPU tiling: grid over vertex blocks; each step reads a (VERTEX_BLOCK, J)
int8 pane (J <= 1024 -> <=256 KiB VMEM) and reduces along lanes into two
(VERTEX_BLOCK,) float32 vectors. Register-dim reduction = lane reduction,
the cheap direction on the VPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import VERTEX_BLOCK, pick_block

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def _cardinality_kernel(m_ref, stat_ref, count_ref):
    m = m_ref[...]
    valid = m != VISITED
    mf = m.astype(jnp.float32)
    stat_ref[...] = jnp.sum(jnp.where(valid, jnp.exp2(-mf), 0.0), axis=-1)
    count_ref[...] = jnp.sum(valid, axis=-1).astype(jnp.float32)


@partial(jax.jit, static_argnames=("vertex_block", "interpret"))
def cardinality_stats_pallas(m, *, vertex_block: int = VERTEX_BLOCK, interpret: bool = True):
    n_pad, num_regs = m.shape
    vertex_block = pick_block(n_pad, vertex_block)
    assert n_pad % vertex_block == 0
    grid = (n_pad // vertex_block,)
    return pl.pallas_call(
        _cardinality_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((vertex_block, num_regs), lambda v: (v, 0))],
        out_specs=[
            pl.BlockSpec((vertex_block,), lambda v: (v,)),
            pl.BlockSpec((vertex_block,), lambda v: (v,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(m)
