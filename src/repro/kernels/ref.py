"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: the Pallas bodies (interpret=True on
CPU, TPU BlockSpecs for the target) must match them exactly — all kernels
here are integer/bit-exact except the cardinality statistics (float32,
compared with allclose).

Sweep semantics are Jacobi: every sweep gathers from the *input* register
matrix and scatter-reduces into a fresh accumulator. This makes the result
independent of edge order, so ref, Pallas, and all distributed schedules
agree bit-for-bit at every sweep (not only at the fixpoint).

Diffusion-model hook: every sweep takes optional per-edge ``h`` (precomputed
sample-independent hash) and ``lo`` (interval low endpoint) operands plus a
static ``predicate`` callable (default: sampling.fused_predicate, the
universal interval form). When ``h``/``lo`` are omitted the legacy
weighted-cascade behaviour is reproduced bit-for-bit: h = edge_hash(src,
dst, seed), lo = 0, and the predicate collapses to ``(X ^ h) < thr``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sampling import edge_hash, fused_predicate
from repro.core.sketch import C_HARMONIC, VISITED
from repro.kernels.common import clamp_block, pad_amount


def _chunked(src, dst, h, lo, thr, edge_chunk: int):
    """Reshape edge operands to (n_chunks, edge_chunk), padding the tail
    chunk with predicate-dead edges (thr=0 never fires — see
    ``common.pad_amount``) so any chunk size is legal, not just divisors.
    Returns (xs, edge_chunk_used)."""
    num_edges = src.shape[0]
    edge_chunk = clamp_block(num_edges, edge_chunk)
    pad = pad_amount(num_edges, edge_chunk)
    ops = (src, dst, h, lo, thr)
    if pad:
        ops = tuple(jnp.pad(a, (0, pad)) for a in ops)
    n_chunks = (num_edges + pad) // edge_chunk
    return tuple(a.reshape(n_chunks, edge_chunk) for a in ops), edge_chunk


def _edge_args(src, dst, thr, h, lo, predicate, seed):
    """Canonicalize the model hook: default hash/offset/predicate give the
    legacy threshold compare."""
    if h is None:
        h = edge_hash(src, dst, seed=seed)
    if lo is None:
        lo = jnp.zeros(thr.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    return h, lo, predicate


def _edge_mask(h, lo, thr, x, predicate):
    """(E,) per-edge operands × (R,) X -> (E, R) bool live mask."""
    return predicate(h[:, None].astype(jnp.uint32), lo[:, None].astype(jnp.uint32),
                     thr[:, None].astype(jnp.uint32), x[None, :].astype(jnp.uint32))


def fused_sample_ref(src: jnp.ndarray, dst: jnp.ndarray, thr: jnp.ndarray,
                     x: jnp.ndarray, h=None, lo=None, *, seed: int = 0,
                     predicate=None) -> jnp.ndarray:
    """(E,) edges × (R,) X -> (E, R) uint8 membership mask (paper eq. (2))."""
    h, lo, predicate = _edge_args(src, dst, thr, h, lo, predicate, seed)
    return _edge_mask(h, lo, thr, x, predicate).astype(jnp.uint8)


def sketch_fill_ref(m: jnp.ndarray, *, reg_offset: int = 0, seed: int = 0) -> jnp.ndarray:
    """FILL-SKETCHES (paper Alg. 1) with the visited early-exit.

    m: int8[n_pad, J] current registers; VISITED entries are preserved,
    everything else is re-initialized to clz(h_j(u)).
    """
    from repro.core.sampling import register_hash

    n_pad, num_regs = m.shape
    u = jnp.arange(n_pad, dtype=jnp.uint32)[:, None]
    j = jnp.arange(num_regs, dtype=jnp.uint32)[None, :] + jnp.uint32(reg_offset)
    fresh = jax.lax.clz(register_hash(u, j, seed=seed)).astype(jnp.int8)
    return jnp.where(m == VISITED, m, fresh)


@partial(jax.jit, static_argnames=("edge_chunk", "seed", "predicate"))
def propagate_sweep_ref(m: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                        thr: jnp.ndarray, x: jnp.ndarray, h=None, lo=None, *,
                        edge_chunk: int = 2048, seed: int = 0,
                        predicate=None) -> jnp.ndarray:
    """One SIMULATE sweep (paper Alg. 2): pull-based sketch max-merge.

    For every edge (u, v) live in sim j, M[u, j] <- max(M[u, j], M[v, j]).
    Visited registers are sticky. Jacobi: gathers read the input ``m``.
    """
    h, lo, predicate = _edge_args(src, dst, thr, h, lo, predicate, seed)
    xs, _ = _chunked(src, dst, h, lo, thr, edge_chunk)

    def body(acc, chunk):
        s, d, hh, ll, t = chunk
        mask = _edge_mask(hh, ll, t, x, predicate)
        vals = m[d]  # (chunk, J) — pull from out-neighbors (Jacobi: reads input m)
        contrib = jnp.where(mask, vals, jnp.int8(VISITED))
        acc = acc.at[s].max(contrib)
        return acc, None

    acc, _ = jax.lax.scan(body, m, xs)
    return jnp.where(m == VISITED, m, acc)


@partial(jax.jit, static_argnames=("num_sweeps", "edge_chunk", "lane_fill",
                                   "seed", "predicate"))
def fused_sweep_ref(m: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                    thr: jnp.ndarray, x: jnp.ndarray, h=None, lo=None, *,
                    num_sweeps: int = 1, edge_chunk: int = 2048,
                    lane_fill: int = 0, seed: int = 0,
                    predicate=None) -> jnp.ndarray:
    """``num_sweeps`` SIMULATE sweeps fused into one traced program.

    Each sweep is exactly :func:`propagate_sweep_ref`; fusing them means one
    dispatch (and, on device, one HBM round-trip of the register matrix)
    instead of one per sweep. ``lane_fill`` processes the register axis in
    that many columns at a time (0 = full width): every column of the Jacobi
    max-merge is independent of every other, so slabbing is bit-identical —
    it only shrinks the per-chunk mask/gather working set from
    ``edge_chunk x num_regs`` to ``edge_chunk x lane_fill``, which is what
    keeps high-register-count sweeps cache-resident.
    """
    h, lo, predicate = _edge_args(src, dst, thr, h, lo, predicate, seed)
    xs, _ = _chunked(src, dst, h, lo, thr, edge_chunk)
    num_regs = int(m.shape[1])
    fill = int(lane_fill) if 0 < int(lane_fill) < num_regs else num_regs

    def one_sweep(m_in):
        def slab(j0, j1):
            x_s, m_s = x[j0:j1], m_in[:, j0:j1]

            def body(acc, chunk):
                s, d, hh, ll, t = chunk
                mask = _edge_mask(hh, ll, t, x_s, predicate)
                contrib = jnp.where(mask, m_s[d], jnp.int8(VISITED))
                return acc.at[s].max(contrib), None

            acc, _ = jax.lax.scan(body, m_s, xs)
            return jnp.where(m_s == VISITED, m_s, acc)

        if fill >= num_regs:
            return slab(0, num_regs)
        return jnp.concatenate(
            [slab(j0, min(j0 + fill, num_regs))
             for j0 in range(0, num_regs, fill)], axis=1)

    out = m
    for _ in range(int(num_sweeps)):
        out = one_sweep(out)
    return out


@partial(jax.jit, static_argnames=("edge_chunk", "seed", "predicate"))
def cascade_sweep_ref(m: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                      thr: jnp.ndarray, x: jnp.ndarray, h=None, lo=None, *,
                      edge_chunk: int = 2048, seed: int = 0,
                      predicate=None) -> jnp.ndarray:
    """One CASCADE sweep (paper Alg. 3): propagate visitedness forward.

    For every edge (u, v) live in sim j with M[u, j] == VISITED, mark
    M[v, j] <- VISITED. Jacobi semantics as above.
    """
    h, lo, predicate = _edge_args(src, dst, thr, h, lo, predicate, seed)
    xs, _ = _chunked(src, dst, h, lo, thr, edge_chunk)
    vis = m == VISITED

    def body(acc, chunk):
        s, d, hh, ll, t = chunk
        mask = _edge_mask(hh, ll, t, x, predicate)
        newly = jnp.logical_and(mask, vis[s]).astype(jnp.uint8)
        acc = acc.at[d].max(newly)
        return acc, None

    acc, _ = jax.lax.scan(body, vis.astype(jnp.uint8), xs)
    return jnp.where(acc.astype(bool), jnp.int8(VISITED), m)


def cardinality_stats_ref(m: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-vertex sufficient statistics for the HLL estimator.

    Returns (sum_{valid} 2^-M[u, j], count_valid) as float32[n_pad] each.
    """
    valid = m != VISITED
    stat = jnp.sum(jnp.where(valid, jnp.exp2(-m.astype(jnp.float32)), 0.0), axis=-1)
    count = jnp.sum(valid, axis=-1).astype(jnp.float32)
    return stat, count


def estimate_ref(m: jnp.ndarray) -> jnp.ndarray:
    """End-to-end HLL estimate (stats + finish) — matches sketch.estimate_cardinality."""
    num_regs = m.shape[-1]
    stat, count = cardinality_stats_ref(m)
    est = jnp.float32(C_HARMONIC) * count / jnp.maximum(stat, 1e-30)
    return jnp.where(count > 0, est * (count / num_regs), 0.0)
