"""Pallas kernel: hash-based fused edge sampling (paper §2.2, eq. (2)).

Produces the (E, R) membership mask ``predicate(h_e, lo_e, thr_e, X_r)`` in
tiles — default predicate ``(X_r ^ h(u,v)) < thr_e``, the interval form for
the diffusion model zoo. This is the purely data-parallel hot loop of
DiFuseR — one XOR + subtract + one unsigned compare per (edge, sample) —
and maps 1:1 onto the TPU VPU: the sample/register axis rides the 128-wide
lane dimension, edges ride sublanes. No MXU, no reductions, no control flow.

TPU tiling:
  grid = (E / EDGE_BLOCK, R / REG_TILE)
  VMEM per step: per-edge operands 3 x EDGE_BLOCK x 4 B (h/lo/thr — src/dst
  are consumed host-side by the hash precompute and never enter the kernel),
  x REG_TILE x 4 B, out EDGE_BLOCK x REG_TILE x 1 B  ->  ~71 KiB at
  (512, 128): trivially VMEM-resident; the grid is compute-bound on the VPU
  as intended.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import edge_hash, fused_predicate
from repro.kernels.common import EDGE_BLOCK, REG_TILE, clamp_block
from repro.kernels.sketch_propagate import (pad_edge_operands,
                                            pad_register_axis)


def _fused_sample_kernel(h_ref, lo_ref, thr_ref, x_ref, out_ref, *, predicate):
    h = h_ref[...].astype(jnp.uint32)
    lo = lo_ref[...].astype(jnp.uint32)
    thr = thr_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)
    mask = predicate(h[:, None], lo[:, None], thr[:, None], x[None, :])
    out_ref[...] = mask.astype(jnp.uint8)


@partial(jax.jit, static_argnames=("seed", "edge_block", "reg_tile", "interpret",
                                   "predicate"))
def fused_sample_pallas(src, dst, thr, x, h=None, lo=None, *, seed: int = 0,
                        edge_block: int = EDGE_BLOCK, reg_tile: int = REG_TILE,
                        interpret: bool = True, predicate=None):
    if h is None:
        h = edge_hash(src, dst, seed=seed)
    if lo is None:
        lo = jnp.zeros(thr.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    num_edges = src.shape[0]
    num_regs = x.shape[0]
    edge_block = clamp_block(num_edges, edge_block)
    reg_tile = clamp_block(num_regs, reg_tile)
    src, dst, h, lo, thr = pad_edge_operands(src, dst, h, lo, thr, edge_block)
    _, x = pad_register_axis(None, x, reg_tile)
    edges_pad, regs_pad = h.shape[0], x.shape[0]
    grid = (edges_pad // edge_block, regs_pad // reg_tile)
    out = pl.pallas_call(
        partial(_fused_sample_kernel, predicate=predicate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda e, r: (e,)),
            pl.BlockSpec((edge_block,), lambda e, r: (e,)),
            pl.BlockSpec((edge_block,), lambda e, r: (e,)),
            pl.BlockSpec((reg_tile,), lambda e, r: (r,)),
        ],
        out_specs=pl.BlockSpec((edge_block, reg_tile), lambda e, r: (e, r)),
        out_shape=jax.ShapeDtypeStruct((edges_pad, regs_pad), jnp.uint8),
        interpret=interpret,
    )(h, lo, thr, x)
    if edges_pad != num_edges or regs_pad != num_regs:
        out = out[:num_edges, :num_regs]
    return out
