"""Pallas kernel: one CASCADE sweep (paper Alg. 3).

Propagates visitedness forward: for every edge (u, v) sampled in sim j with
``M[u, j] == VISITED``, mark ``M[v, j] <- VISITED``.

The paper's unified frontier queue + warp-ballot dedup is a GPU-occupancy
device with no TPU analogue (DESIGN.md §2); here the frontier is implicit —
a dense sweep over the (dst-sorted) edge list whose per-lane work is a
compare + select. The fixpoint driver (core/cascade.py) supplies the early
exit the queue provided: it stops as soon as a sweep changes nothing.

Same schedule as sketch_propagate (register tile major, edge blocks minor,
register panes VMEM-resident); Jacobi semantics, bit-exact vs ref.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import EDGE_BLOCK, REG_TILE, kedge_hash, pick_block

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def _cascade_kernel(src_ref, dst_ref, thr_ref, x_ref, m_ref, out_ref, *,
                    edge_block: int, seed: int):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = m_ref[...]

    src = src_ref[...]
    dst = dst_ref[...]
    thr = thr_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)
    h = kedge_hash(src, dst, seed)

    def body(i, _):
        u = src[i]
        v = dst[i]
        mask = (h[i] ^ x) < thr[i]
        vis_u = pl.load(m_ref, (u, slice(None))) == VISITED  # Jacobi read
        newly = jnp.logical_and(mask, vis_u)
        cur = pl.load(out_ref, (v, slice(None)))
        pl.store(out_ref, (v, slice(None)), jnp.where(newly, jnp.full_like(cur, VISITED), cur))
        return 0

    jax.lax.fori_loop(0, edge_block, body, 0)


@partial(jax.jit, static_argnames=("seed", "edge_block", "reg_tile", "interpret"))
def cascade_sweep_pallas(m, src, dst, thr, x, *, seed: int = 0,
                         edge_block: int = EDGE_BLOCK, reg_tile: int = REG_TILE,
                         interpret: bool = True):
    n_pad, num_regs = m.shape
    num_edges = src.shape[0]
    reg_tile = pick_block(num_regs, reg_tile)
    edge_block = pick_block(num_edges, edge_block)
    assert num_edges % edge_block == 0 and num_regs % reg_tile == 0
    grid = (num_regs // reg_tile, num_edges // edge_block)
    return pl.pallas_call(
        partial(_cascade_kernel, edge_block=edge_block, seed=seed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((reg_tile,), lambda r, e: (r,)),
            pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        ],
        out_specs=pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        out_shape=jax.ShapeDtypeStruct((n_pad, num_regs), jnp.int8),
        interpret=interpret,
    )(src, dst, thr, x, m)
