"""Pallas kernel: one CASCADE sweep (paper Alg. 3).

Propagates visitedness forward: for every edge (u, v) live in sim j with
``M[u, j] == VISITED``, mark ``M[v, j] <- VISITED``.

The paper's unified frontier queue + warp-ballot dedup is a GPU-occupancy
device with no TPU analogue (DESIGN.md §2); here the frontier is implicit —
a dense sweep over the (dst-sorted) edge list whose per-lane work is a
compare + select. The fixpoint driver (core/cascade.py) supplies the early
exit the queue provided: it stops as soon as a sweep changes nothing.

Same schedule as sketch_propagate (register tile major, edge blocks minor,
register panes VMEM-resident); Jacobi semantics, bit-exact vs ref.py.
Same diffusion-model hook as sketch_propagate: per-edge (h, lo) operands
plus a static ``predicate`` (default: the universal interval form).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import edge_hash, fused_predicate
from repro.kernels.common import EDGE_BLOCK, REG_TILE, clamp_block
from repro.kernels.sketch_propagate import (pad_edge_operands,
                                            pad_register_axis)

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def _cascade_kernel(src_ref, dst_ref, h_ref, lo_ref, thr_ref, x_ref, m_ref,
                    out_ref, *, edge_block: int, predicate):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = m_ref[...]

    src = src_ref[...]
    dst = dst_ref[...]
    h = h_ref[...].astype(jnp.uint32)
    lo = lo_ref[...].astype(jnp.uint32)
    thr = thr_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)

    def body(i, _):
        u = src[i]
        v = dst[i]
        mask = predicate(h[i], lo[i], thr[i], x)
        vis_u = pl.load(m_ref, (u, slice(None))) == VISITED  # Jacobi read
        newly = jnp.logical_and(mask, vis_u)
        cur = pl.load(out_ref, (v, slice(None)))
        pl.store(out_ref, (v, slice(None)), jnp.where(newly, jnp.full_like(cur, VISITED), cur))
        return 0

    jax.lax.fori_loop(0, edge_block, body, 0)


@partial(jax.jit, static_argnames=("seed", "edge_block", "reg_tile", "interpret",
                                   "predicate"))
def cascade_sweep_pallas(m, src, dst, thr, x, h=None, lo=None, *, seed: int = 0,
                         edge_block: int = EDGE_BLOCK, reg_tile: int = REG_TILE,
                         interpret: bool = True, predicate=None):
    if h is None:
        h = edge_hash(src, dst, seed=seed)
    if lo is None:
        lo = jnp.zeros(thr.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    n_pad, num_regs = m.shape
    num_edges = src.shape[0]
    reg_tile = clamp_block(num_regs, reg_tile)
    edge_block = clamp_block(num_edges, edge_block)
    src, dst, h, lo, thr = pad_edge_operands(src, dst, h, lo, thr, edge_block)
    m_in, x = pad_register_axis(m, x, reg_tile)
    regs_pad = x.shape[0]
    grid = (regs_pad // reg_tile, src.shape[0] // edge_block)
    out = pl.pallas_call(
        partial(_cascade_kernel, edge_block=edge_block, predicate=predicate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((reg_tile,), lambda r, e: (r,)),
            pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        ],
        out_specs=pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        out_shape=jax.ShapeDtypeStruct((n_pad, regs_pad), jnp.int8),
        interpret=interpret,
    )(src, dst, h, lo, thr, x, m_in)
    return out[:, :num_regs] if regs_pad != num_regs else out
