"""Pallas kernel: one SIMULATE sweep (paper Alg. 2) — the core hot loop.

Pull-based sketch max-merge with sampling fused into the traversal:
for every edge (u, v) and register j whose fused predicate fires
(default ``(X_j ^ h(u,v)) < thr_uv``), ``M[u, j] <- max(M[u, j], M[v, j])``,
with VISITED (-1) sticky.

TPU adaptation of the CUDA kernel (see DESIGN.md §2):
  * registers ride the 128-lane dimension — one vector op covers 128
    simulations of one edge (the paper's warp = 32 threads becomes a lane
    tile = 128);
  * the warp-divergence problem becomes masked lanes; FASST raises lane
    occupancy exactly as it raises warp fill;
  * atomics are unnecessary because max-merge is idempotent (the paper's
    argument); duplicate-destination writes within an edge block are
    serialized by the in-kernel edge loop instead.

Grid = (J / REG_TILE, E / EDGE_BLOCK): the register tile is the outer
(major) axis so the (n_pad x REG_TILE) register panes for input and
accumulator stay VMEM-resident across all edge blocks (the classic
reduction-innermost schedule). VMEM at (n_pad=64Ki, 128): two 8 MiB panes —
the vertex dimension beyond that is tiled by the *distributed* vertex
partition (core/distributed.py), not by this kernel.

Jacobi semantics: gathers read the input pane, maxes accumulate into the
output pane — bit-identical to kernels/ref.py for any edge order.

Diffusion-model hook: the per-edge hash ``h`` and interval offset ``lo``
arrive as operands (hash once per build instead of once per sweep), and the
activation decision is a static ``predicate`` callable — default
sampling.fused_predicate, pure VPU ops, legal inside the kernel body.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import edge_hash, fused_predicate
from repro.kernels.common import (EDGE_BLOCK, REG_TILE, clamp_block,
                                 pad_amount)

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def pad_edge_operands(src, dst, h, lo, thr, edge_block: int):
    """Round the edge axis up to a multiple of ``edge_block`` with
    predicate-dead filler (thr=0 never fires, so padded edges contribute the
    max-merge identity) — any block size is legal, including on prime edge
    counts where the old largest-divisor search degraded to block=1."""
    pad = pad_amount(src.shape[0], edge_block)
    if pad:
        src, dst, h, lo, thr = (jnp.pad(a, (0, pad))
                                for a in (src, dst, h, lo, thr))
    return src, dst, h, lo, thr


def pad_register_axis(m, x, reg_tile: int):
    """Round the register axis up to a multiple of ``reg_tile``: padded x
    slots are 0 and the padded matrix columns VISITED (sticky under
    max-merge), so they never change and are sliced off by the caller."""
    pad = pad_amount(x.shape[0], reg_tile)
    if pad:
        x = jnp.pad(x, (0, pad))
        if m is not None:
            m = jnp.pad(m, ((0, 0), (0, pad)), constant_values=VISITED)
    return m, x


def _propagate_kernel(src_ref, dst_ref, h_ref, lo_ref, thr_ref, x_ref, m_ref,
                      out_ref, *, edge_block: int, predicate):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = m_ref[...]

    src = src_ref[...]
    dst = dst_ref[...]
    h = h_ref[...].astype(jnp.uint32)
    lo = lo_ref[...].astype(jnp.uint32)
    thr = thr_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)

    def body(i, _):
        u = src[i]
        v = dst[i]
        mask = predicate(h[i], lo[i], thr[i], x)  # (R_TILE,) fused sampling
        pulled = pl.load(m_ref, (v, slice(None)))  # Jacobi gather of v's tile
        contrib = jnp.where(mask, pulled, jnp.full_like(pulled, VISITED))
        cur = pl.load(out_ref, (u, slice(None)))
        # sticky visited: a VISITED register never resurrects
        new = jnp.where(cur == VISITED, cur, jnp.maximum(cur, contrib))
        pl.store(out_ref, (u, slice(None)), new)
        return 0

    jax.lax.fori_loop(0, edge_block, body, 0)


@partial(jax.jit, static_argnames=("seed", "edge_block", "reg_tile", "interpret",
                                   "predicate"))
def propagate_sweep_pallas(m, src, dst, thr, x, h=None, lo=None, *, seed: int = 0,
                           edge_block: int = EDGE_BLOCK, reg_tile: int = REG_TILE,
                           interpret: bool = True, predicate=None):
    if h is None:
        h = edge_hash(src, dst, seed=seed)
    if lo is None:
        lo = jnp.zeros(thr.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    n_pad, num_regs = m.shape
    num_edges = src.shape[0]
    reg_tile = clamp_block(num_regs, reg_tile)
    edge_block = clamp_block(num_edges, edge_block)
    src, dst, h, lo, thr = pad_edge_operands(src, dst, h, lo, thr, edge_block)
    m_in, x = pad_register_axis(m, x, reg_tile)
    regs_pad = x.shape[0]
    grid = (regs_pad // reg_tile, src.shape[0] // edge_block)
    out = pl.pallas_call(
        partial(_propagate_kernel, edge_block=edge_block, predicate=predicate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((edge_block,), lambda r, e: (e,)),
            pl.BlockSpec((reg_tile,), lambda r, e: (r,)),
            pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        ],
        out_specs=pl.BlockSpec((n_pad, reg_tile), lambda r, e: (0, r)),
        out_shape=jax.ShapeDtypeStruct((n_pad, regs_pad), jnp.int8),
        interpret=interpret,
    )(src, dst, h, lo, thr, x, m_in)
    return out[:, :num_regs] if regs_pad != num_regs else out
