"""Pallas kernel: bucketed SIMULATE sweep for the distributed 2-D runtime.

The distributed partition (core/distributed.py) pre-buckets edges by
(write-owner, ring step) and precomputes the per-edge predicate operands
(hash once instead of once per sweep — legal for every registered diffusion
model because h is sample-independent). At each ring step the device merges
its local accumulator rows with rows of the *remote* register block that
just arrived. This kernel is that merge:

    acc[w[i], j] <- max(acc[w[i], j], block[r[i], j])   if pred(h[i], lo[i], t[i], X_j)

Same Jacobi/TPU-lane layout as sketch_propagate (registers ride the 128
lanes; gathers/stores are dynamic row slices; no atomics because max-merge
is idempotent). ops-level dispatch: the jnp oracle is
``core.distributed._bucket_sweep_propagate``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import fused_predicate
from repro.kernels.common import (EDGE_BLOCK, REG_TILE, clamp_block,
                                  pad_amount)

VISITED = -1


def _bucket_kernel(h_ref, w_ref, r_ref, t_ref, lo_ref, x_ref, block_ref, acc_ref,
                   out_ref, *, edge_block: int, predicate):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = acc_ref[...]

    h = h_ref[...].astype(jnp.uint32)
    w = w_ref[...]
    r = r_ref[...]
    t = t_ref[...].astype(jnp.uint32)
    lo = lo_ref[...].astype(jnp.uint32)
    x = x_ref[...].astype(jnp.uint32)

    def body(i, _):
        mask = predicate(h[i], lo[i], t[i], x)
        pulled = pl.load(block_ref, (r[i], slice(None)))
        contrib = jnp.where(mask, pulled, jnp.full_like(pulled, VISITED))
        cur = pl.load(out_ref, (w[i], slice(None)))
        new = jnp.where(cur == VISITED, cur, jnp.maximum(cur, contrib))
        pl.store(out_ref, (w[i], slice(None)), new)
        return 0

    jax.lax.fori_loop(0, edge_block, body, 0)


@partial(jax.jit, static_argnames=("edge_block", "reg_tile", "interpret",
                                   "predicate"))
def bucket_propagate_pallas(acc, block, h, w, r, t, x, lo=None, *,
                            edge_block: int = EDGE_BLOCK, reg_tile: int = REG_TILE,
                            interpret: bool = True, predicate=None):
    """acc/block: int8[n_loc, J_loc]; h/w/r/t/lo: (B,) bucket arrays; x: (J_loc,)."""
    if lo is None:
        lo = jnp.zeros(t.shape, jnp.uint32)
    if predicate is None:
        predicate = fused_predicate
    n_loc, j_loc = acc.shape
    n_edges = h.shape[0]
    reg_tile = clamp_block(j_loc, reg_tile)
    edge_block = clamp_block(n_edges, edge_block)
    # pad the bucket axis with predicate-dead edges (t=0 never fires) and the
    # register axis with VISITED columns — bit-identical, any block shape
    epad = pad_amount(n_edges, edge_block)
    if epad:
        h, w, r, t, lo = (jnp.pad(a, (0, epad)) for a in (h, w, r, t, lo))
    rpad = pad_amount(j_loc, reg_tile)
    if rpad:
        x = jnp.pad(x, (0, rpad))
        acc = jnp.pad(acc, ((0, 0), (0, rpad)), constant_values=VISITED)
        block = jnp.pad(block, ((0, 0), (0, rpad)), constant_values=VISITED)
    jp = j_loc + rpad
    grid = (jp // reg_tile, (n_edges + epad) // edge_block)
    out = pl.pallas_call(
        partial(_bucket_kernel, edge_block=edge_block, predicate=predicate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda j, e: (e,)),
            pl.BlockSpec((edge_block,), lambda j, e: (e,)),
            pl.BlockSpec((edge_block,), lambda j, e: (e,)),
            pl.BlockSpec((edge_block,), lambda j, e: (e,)),
            pl.BlockSpec((edge_block,), lambda j, e: (e,)),
            pl.BlockSpec((reg_tile,), lambda j, e: (j,)),
            pl.BlockSpec((n_loc, reg_tile), lambda j, e: (0, j)),
            pl.BlockSpec((n_loc, reg_tile), lambda j, e: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_loc, reg_tile), lambda j, e: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_loc, jp), jnp.int8),
        interpret=interpret,
    )(h, w, r, t, lo, x, block, acc)
    return out[:, :j_loc] if rpad else out
