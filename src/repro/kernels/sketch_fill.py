"""Pallas kernel: FILL-SKETCHES (paper Alg. 1).

M[u, j] <- clz(h_j(u)) for non-visited registers; VISITED (-1) entries are
preserved (the Alg. 1 line-5 early exit, which on TPU is a lane select
rather than a thread `continue`).

TPU tiling: grid = (n_pad / VERTEX_BLOCK, J / REG_TILE); each step writes a
(256, 128) int8 tile (32 KiB). The vertex/register ids are derived from the
grid position with iota — the only input is the previous register tile (for
the visited mask), so the kernel is write-bandwidth-bound as in the paper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import REG_TILE, VERTEX_BLOCK, kclz32, kregister_hash, pick_block

VISITED = -1  # python literal: weak-typed inside kernels (no captured consts)


def _sketch_fill_kernel(m_ref, out_ref, *, vertex_block: int, reg_tile: int,
                        reg_offset: int, seed: int):
    vb = pl.program_id(0)
    rb = pl.program_id(1)
    u0 = vb * vertex_block
    j0 = rb * reg_tile + reg_offset
    u = (jax.lax.broadcasted_iota(jnp.int32, (vertex_block, reg_tile), 0) + u0).astype(jnp.uint32)
    j = (jax.lax.broadcasted_iota(jnp.int32, (vertex_block, reg_tile), 1) + j0).astype(jnp.uint32)
    fresh = kclz32(kregister_hash(u, j, seed)).astype(jnp.int8)
    prev = m_ref[...]
    out_ref[...] = jnp.where(prev == VISITED, prev, fresh)


@partial(jax.jit, static_argnames=("reg_offset", "seed", "vertex_block", "reg_tile", "interpret"))
def sketch_fill_pallas(m, *, reg_offset: int = 0, seed: int = 0,
                       vertex_block: int = VERTEX_BLOCK, reg_tile: int = REG_TILE,
                       interpret: bool = True):
    n_pad, num_regs = m.shape
    vertex_block = pick_block(n_pad, vertex_block)
    reg_tile = pick_block(num_regs, reg_tile)
    assert n_pad % vertex_block == 0 and num_regs % reg_tile == 0
    grid = (n_pad // vertex_block, num_regs // reg_tile)
    return pl.pallas_call(
        partial(_sketch_fill_kernel, vertex_block=vertex_block, reg_tile=reg_tile,
                reg_offset=reg_offset, seed=seed),
        grid=grid,
        in_specs=[pl.BlockSpec((vertex_block, reg_tile), lambda v, r: (v, r))],
        out_specs=pl.BlockSpec((vertex_block, reg_tile), lambda v, r: (v, r)),
        out_shape=jax.ShapeDtypeStruct((n_pad, num_regs), jnp.int8),
        interpret=interpret,
    )(m)
