"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) block.

Chunked train/prefill path: intra-chunk "attention-like" term + inter-chunk
state recurrence (lax.scan over chunks), O(S·Q) instead of O(S^2). Decode
path: O(1) state update — which is what makes the ssm/hybrid architectures
eligible for the long_500k cell.

Layout: x (B,S,d_inner) viewed as (B,S,H,P) heads; state (B,H,P,N);
single B/C group (G=1) as in the released Mamba-2 models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [x, z, B, C, dt]
        "w_in": init_dense(ks[0], d, 2 * di + 2 * n + h, cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim), jnp.float32)
                   * 0.1).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": init_dense(ks[2], di, d, cfg.pdtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d, kernel K unrolled (K is 4)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    s = u.shape[1]
    out = sum(pad[:, i:i + s, :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(dA: jnp.ndarray) -> jnp.ndarray:
    """dA: (..., Q) -> L-matrix log-weights (..., Q, Q): sum_{l=j+1..i} dA_l
    for j <= i, -inf above the diagonal."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *, chunk: int = 128,
                   return_cache: bool = False):
    """Full-sequence SSD. x: (B, S, d_model) -> (B, S, d_model).

    return_cache=True additionally returns the decode cache after the last
    token: {"state": (B,H,N,P) final SSM state, "conv": last K-1 conv inputs}.
    """
    bsz, s, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    q = min(chunk, s)
    while s % q != 0:
        q //= 2
    nc = s // q

    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    from repro.models.sharding import constrain
    proj = constrain(proj, "batch", "un", "un")
    xc, z, bmat, cmat, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    a = -jnp.exp(p["a_log"])                                  # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xc.reshape(bsz, s, h, pdim).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    # chunk views
    dA = (dt * a).reshape(bsz, nc, q, h)                      # (B,nc,Q,H)
    xck = xh.reshape(bsz, nc, q, h, pdim)
    bk = bmat.reshape(bsz, nc, q, n)
    ck = cmat.reshape(bsz, nc, q, n)
    dtk = dt.reshape(bsz, nc, q, h)

    # --- intra-chunk (the "duality" attention-like term) ---
    logl = _segsum(jnp.moveaxis(dA, -1, -2))                  # (B,nc,H,Q,Q)
    lmat = jnp.exp(logl)
    scores = jnp.einsum("bcin,bcjn->bcij", ck, bk)            # (B,nc,Q,Q)
    w = scores[:, :, None] * lmat * jnp.moveaxis(dtk, -1, -2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xck)

    # --- chunk final states + inter-chunk scan ---
    cs = jnp.cumsum(dA, axis=2)                               # (B,nc,Q,H)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)             # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", decay_to_end * dtk, bk, xck)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(h_prev, inp):
        st, dec = inp                                         # (B,H,N,P), (B,H)
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    h_final, h_in = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                           # (B,nc,H,N,P) state entering chunk

    in_decay = jnp.exp(cs)                                    # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", ck, in_decay, h_in)

    y = (y_intra + y_inter).reshape(bsz, s, h, pdim)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = (y.reshape(bsz, s, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_cache:
        k = cfg.conv_kernel
        tail = conv_in[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            conv_in, ((0, 0), (k - 1 - s, 0), (0, 0)))
        return out, {"state": h_final, "conv": tail}
    return out


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def mamba2_decode(p: dict, x: jnp.ndarray, cache: dict, cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """One-token step. x: (B, 1, d_model)."""
    bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim

    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])[:, 0]
    xc, z, bvec, cvec, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bvec, cvec], axis=-1)      # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,conv)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    xc, bvec, cvec = jnp.split(conv_out, [di, di + n], axis=-1)

    a = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    xh = xc.reshape(bsz, h, pdim).astype(jnp.float32)
    decay = jnp.exp(dt * a)                                   # (B,H)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bvec.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = (y.reshape(bsz, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["w_out"])[:, None]
    return out, {"state": state, "conv": window[:, 1:]}
