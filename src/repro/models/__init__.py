"""LM architecture zoo (assigned-pool deliverable)."""
from repro.models.config import ModelConfig, reduced
from repro.models.transformer import decode_step, forward, init_cache, init_params, prefill

__all__ = ["ModelConfig", "reduced", "forward", "prefill", "decode_step", "init_cache", "init_params"]
