"""Primitive layers (pure JAX, params as nested dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_embed(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def swiglu(x: jnp.ndarray, w_in: jnp.ndarray, w_gate: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    from repro.models.sharding import constrain

    h = jnp.einsum("...d,df->...f", x, w_in)
    g = jnp.einsum("...d,df->...f", x, w_gate)
    if h.ndim == 3:
        h = constrain(h, "batch", "un", "model")
        g = constrain(g, "batch", "un", "model")
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, w_out)


def rotary_embedding(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int32 -> cos/sin (..., S, head_dim/2) float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dtype = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy in float32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0 if mask is None else mask
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid.astype(jnp.float32)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
