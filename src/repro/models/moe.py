"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity
(GShard-style token-choice, DeepSeekMoE fine-grained layout).

Dispatch is k rounds of top-1 scatter/gather (argsort-free): per round the
position-in-expert comes from a cumsum over the one-hot expert assignment,
tokens beyond capacity drop (weight renormalization keeps the estimator
unbiased enough for training; capacity_factor controls the drop rate).
This keeps intermediates at O(T·E) bits instead of the O(T·E·C) one-hot
einsum, and lowers to gather/scatter + batched expert einsums that XLA
shards cleanly over the ``model`` axis (EP) with all-to-alls.

Load-balancing note (DESIGN.md §5): capacity padding makes every expert
shard lockstep-equal — the same max-shard-size logic FASST applies to
DiFuseR's sample space; `expert_load_stats` exposes the imbalance metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense
from repro.models.sharding import constrain


def init_moe(key, cfg: ModelConfig) -> dict:
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], cfg.d_model, e, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, cfg.d_model, ff), jnp.float32)
                 / jnp.sqrt(cfg.d_model)).astype(cfg.pdtype),
        "w_gate": (jax.random.normal(ks[2], (e, cfg.d_model, ff), jnp.float32)
                   / jnp.sqrt(cfg.d_model)).astype(cfg.pdtype),
        "w_out": (jax.random.normal(ks[3], (e, ff, cfg.d_model), jnp.float32)
                  / jnp.sqrt(ff)).astype(cfg.pdtype),
    }
    if cfg.moe_num_shared:
        sff = (cfg.moe_d_ff or cfg.d_ff) * cfg.moe_num_shared
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": init_dense(ks2[0], cfg.d_model, sff, cfg.pdtype),
            "w_gate": init_dense(ks2[1], cfg.d_model, sff, cfg.pdtype),
            "w_out": init_dense(ks2[2], sff, cfg.d_model, cfg.pdtype),
        }
    return p


def _rank_in_expert(eid: jnp.ndarray, e: int, t: int) -> jnp.ndarray:
    """Rank of each token within its expert, via sort instead of a
    token-length cumsum (§Perf deepseek iteration 3: the (t, E) one-hot
    cumsum lowers to a t-deep reduce-window — O(t^2) in both the HLO cost
    model and a naive TPU lowering; sort-based ranking is the
    MegaBlocks/MaxText dispatch idiom and is O(t log t))."""
    order = jnp.argsort(eid)                      # stable: ties keep order
    sorted_eid = eid[order]
    # start offset of each expert's run = exclusive cumsum of counts (E ops)
    counts = jnp.bincount(eid, length=e)
    starts = jnp.cumsum(counts) - counts          # (e,)
    rank_sorted = jnp.arange(t, dtype=jnp.int32) - starts[sorted_eid].astype(jnp.int32)
    return jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    e = cfg.moe_num_experts
    k = cfg.moe_top_k
    # Per-slot capacity: each of the k dispatch rounds routes exactly t
    # tokens (top-1 per round), so expected tokens/expert/round is t/e.
    # (Sizing this as t*k*cf/e — the full top-k budget per round — was the
    # §Perf deepseek iteration-1 bug: 6x redundant expert compute/memory.)
    cap = int(max(1, (t * cfg.moe_capacity_factor) // e))
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_ids = jax.lax.top_k(gates, k)                # (t, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    out = jnp.zeros((t, d), jnp.float32)
    for slot in range(k):
        eid = top_ids[:, slot]                                  # (t,)
        gate = top_vals[:, slot]
        my_pos = _rank_in_expert(eid, e, t)
        keep = my_pos < cap
        slot_idx = jnp.where(keep, eid * cap + my_pos, e * cap)  # drop bucket
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot_idx].set(xt)
        buf = buf[:-1].reshape(e, cap, d)
        # EP: experts ride "model"; TP ("ffn" mode): the hidden dim does.
        e_tag = "model" if cfg.moe_shard_mode == "expert" else "un"
        f_tag = "un" if cfg.moe_shard_mode == "expert" else "model"
        buf = constrain(buf, e_tag, "un", "un")
        h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        h = constrain(h, e_tag, "un", f_tag)
        g = constrain(g, e_tag, "un", f_tag)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["w_out"])
        y = constrain(y, e_tag, "un", "un")
        y = y.reshape(e * cap, d)
        gathered = y[jnp.minimum(slot_idx, e * cap - 1)]
        out = out + jnp.where(keep[:, None], gathered.astype(jnp.float32) * gate[:, None], 0.0)

    if "shared" in p:
        sp = p["shared"]
        h = constrain(jnp.einsum("td,df->tf", xt, sp["w_in"]), "un", "model")
        g = constrain(jnp.einsum("td,df->tf", xt, sp["w_gate"]), "un", "model")
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * h, sp["w_out"]).astype(jnp.float32)

    return out.reshape(b, s, d).astype(x.dtype)


def aux_load_balance_loss(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    t = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]).reshape(t, -1)
    gates = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.moe_num_experts, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(gates, axis=0)
    return cfg.moe_num_experts * jnp.sum(f * pmean)


def expert_load_stats(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Tokens routed per expert (top-1), for the load-balance benchmark."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    top1 = jnp.argmax(logits, axis=-1).reshape(-1)
    return jnp.bincount(top1, length=cfg.moe_num_experts)
