"""Model configuration shared by the whole zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "decoder"          # decoder | encdec | hybrid | ssm | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None   # default d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention
    tie_embeddings: bool = False
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0          # shared experts (DeepSeekMoE)
    moe_d_ff: int = 0                # per-expert hidden (fine-grained MoE)
    moe_shard_mode: str = "expert"   # "expert" (EP) | "ffn" (TP inside expert)
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0              # hybrid: shared attn block period (Zamba2)
    # --- enc-dec ---
    enc_layers: int = 0
    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio_frames | vision_patches
    num_patches: int = 256
    # --- numerics / training ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    remat: str = "full"              # full | none
    optimizer: str = "adamw"         # adamw | adafactor
    # --- shape-cell policy (assignment rules) ---
    sub_quadratic: bool = False      # may run long_500k
    has_decoder: bool = True         # encoder-only archs would skip decode
    max_train_seq: int = 4096
    vocab_pad_multiple: int = 128    # embeddings padded so vocab shards 16-way
    scan_layers: bool = True         # False: unroll (dry-run cost probes)
    attn_chunk: int = 0              # >0: online-softmax over key chunks
                                     # (flash-style; kills the SxS temp)
    padded_q_heads: int = 0          # pad q heads (zeros, per KV group) so
                                     # heads shard over model — kills the
                                     # S x S score psum (§Perf yi-34b)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def q_heads(self) -> int:
        """Physical q-head count (>= num_heads when padded for sharding)."""
        return self.padded_q_heads or self.num_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(self.num_heads, 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, l, v = self.d_model, self.num_layers, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_layer = 0
        if self.family in ("decoder", "encdec", "vlm"):
            per_layer += self._attn_params() + self._mlp_params()
            per_layer += 2 * d  # norms
        elif self.family == "ssm":
            per_layer += self._ssm_params() + d
        elif self.family == "hybrid":
            per_layer += self._ssm_params() + d  # mamba-only backbone (Zamba2)
        total += l * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one weight-shared transformer block: attn + MLP (this is where
            # Zamba2's d_ff lives — NOT in every backbone layer)
            total += self._attn_params() + 3 * d * self.d_ff + 2 * d
        if self.family == "encdec":
            total += self.enc_layers * (self._attn_params() + self._mlp_params() + 2 * d)
            total += l * (self._attn_params() + d)  # cross-attention per dec layer
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        dense = self.param_count() - l * self._mlp_params()
        ff = self.moe_d_ff or self.d_ff
        active_mlp = 3 * d * ff * (self.moe_top_k + self.moe_num_shared)
        router = d * self.moe_num_experts
        return dense + l * (active_mlp + router)

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        qkv = d * (self.num_heads + 2 * self.num_kv_heads) * hd
        out = self.num_heads * hd * d
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return qkv + out + bias

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            ff = self.moe_d_ff or self.d_ff
            router = d * self.moe_num_experts
            experts = self.moe_num_experts * 3 * d * ff
            shared = self.moe_num_shared * 3 * d * ff
            return router + experts + shared
        return 3 * d * self.d_ff  # SwiGLU: in/gate/out

    def _ssm_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)  # x, z, B, C, dt
        conv = self.conv_kernel * (di + 2 * n)
        out = di * d
        return in_proj + conv + out + 2 * h + di  # + A, dt_bias, D


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized sibling of ``cfg`` (same family/topology)."""
    small: dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1))),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        enc_layers=min(cfg.enc_layers, 2),
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        num_patches=8,
        padded_q_heads=0,
    )
    if cfg.is_moe:
        small.update(moe_num_experts=8, moe_top_k=min(cfg.moe_top_k, 2),
                     moe_num_shared=min(cfg.moe_num_shared, 1), moe_d_ff=64)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_every:
        small.update(attn_every=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
