"""Parameter/activation sharding rules for the production mesh.

Mesh axes: ``data`` (DP + FSDP), ``model`` (TP/EP), optional ``pod``
(data-parallel across pods; params replicated over pod, gradients
all-reduced). Rules are path-based over the param pytree.

Divisibility fallbacks (recorded in EXPERIMENTS.md §Dry-run): head counts
in the assigned pool aren't all multiples of 16 (yi-34b 56H, qwen1.5 20H,
GQA kv=4/8). The resolver tries, in order: shard heads over ``model`` →
shard head_dim over ``model`` (contracted-dim sharding; XLA inserts the
all-reduce) → replicate that dim. Vocab is padded to a multiple of 128
(config.padded_vocab) so embeddings always shard.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def _attn_proj_spec(cfg: ModelConfig, mesh: Mesh, *, heads: int, out: bool) -> P:
    """(d, H, hd) for qkv / (H, hd, d) for o. Heads shard over ``model`` when
    divisible (pad with config.padded_q_heads when they are not); otherwise
    the projection replicates over model (FSDP over data still applies).
    NEVER shard head_dim: a contracted-dim sharding of q/k forces an
    all-reduce of the S x S scores — measured 60 GB/layer on yi-34b
    (EXPERIMENTS.md §Perf)."""
    d_ok = _div(cfg.d_model, mesh, "data")
    d_ax = "data" if d_ok else None
    if _div(heads, mesh, "model"):
        return P("model", None, d_ax) if out else P(d_ax, "model", None)
    return P(None, None, d_ax) if out else P(d_ax, None, None)


def _mlp_specs(cfg: ModelConfig, mesh: Mesh, d_ff: int) -> dict:
    d_ax = "data" if _div(cfg.d_model, mesh, "data") else None
    f_ax = "model" if _div(d_ff, mesh, "model") else None
    return {"w_in": P(d_ax, f_ax), "w_gate": P(d_ax, f_ax), "w_out": P(f_ax, d_ax)}


def _moe_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    ff = cfg.moe_d_ff or cfg.d_ff
    d_ax = "data" if _div(cfg.d_model, mesh, "data") else None
    if cfg.moe_shard_mode == "expert" and _div(cfg.moe_num_experts, mesh, "model"):
        e_ax, f_ax = "model", None
    else:
        e_ax, f_ax = None, ("model" if _div(ff, mesh, "model") else None)
    specs = {
        "router": P(d_ax, None),
        "w_in": P(e_ax, d_ax, f_ax),
        "w_gate": P(e_ax, d_ax, f_ax),
        "w_out": P(e_ax, f_ax, d_ax),
    }
    if cfg.moe_num_shared:
        sff = ff * cfg.moe_num_shared
        specs["shared"] = _mlp_specs(cfg, mesh, sff)
    return specs


def _attn_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    specs = {
        "wq": _attn_proj_spec(cfg, mesh, heads=cfg.q_heads, out=False),
        "wk": _attn_proj_spec(cfg, mesh, heads=cfg.num_kv_heads, out=False),
        "wv": _attn_proj_spec(cfg, mesh, heads=cfg.num_kv_heads, out=False),
        "wo": _attn_proj_spec(cfg, mesh, heads=cfg.q_heads, out=True),
    }
    if cfg.qkv_bias:
        hq = "model" if _div(cfg.q_heads, mesh, "model") else None
        hkv = "model" if _div(cfg.num_kv_heads, mesh, "model") else None
        specs.update(bq=P(hq, None), bk=P(hkv, None), bv=P(hkv, None))
    return specs


def _mamba_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    d_ax = "data" if _div(cfg.d_model, mesh, "data") else None
    proj_out = 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
    e_ax = "model" if _div(proj_out, mesh, "model") else None
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    c_ax = "model" if _div(conv_dim, mesh, "model") else None
    h_ax = "model" if _div(cfg.ssm_heads, mesh, "model") else None
    di_ax = "model" if _div(cfg.d_inner, mesh, "model") else None
    return {
        "w_in": P(d_ax, e_ax),
        "conv_w": P(None, c_ax), "conv_b": P(c_ax),
        "a_log": P(h_ax), "dt_bias": P(h_ax), "d_skip": P(h_ax),
        "w_out": P(di_ax, d_ax),
    }


def _layer_specs(cfg: ModelConfig, mesh: Mesh, *, cross: bool = False) -> dict:
    if cfg.family in ("decoder", "vlm", "encdec"):
        p = {"ln1": P(None), "ln2": P(None), "attn": _attn_specs(cfg, mesh),
             "mlp": _moe_specs(cfg, mesh) if cfg.is_moe else _mlp_specs(cfg, mesh, cfg.d_ff)}
        if cross:
            p["ln_x"] = P(None)
            p["xattn"] = _attn_specs(cfg, mesh)
        return p
    if cfg.family == "ssm":
        return {"ln1": P(None), "mamba": _mamba_specs(cfg, mesh)}
    if cfg.family == "hybrid":
        return {"ln1": P(None), "mamba": _mamba_specs(cfg, mesh)}
    raise ValueError(cfg.family)


def _prepend(spec_tree, axis=None):
    """Add a leading (layer-stack) dim to every PartitionSpec in a tree."""
    return jax.tree.map(lambda s: P(axis, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    """PartitionSpec pytree matching transformer.init_params output."""
    v_ax = "model" if _div(cfg.padded_vocab, mesh, "model") else None
    d_ax = "data" if _div(cfg.d_model, mesh, "data") else None
    specs: dict = {
        "embed": P(v_ax, d_ax),
        "final_ln": P(None),
        "layers": _prepend(_layer_specs(cfg, mesh, cross=(cfg.family == "encdec"))),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(d_ax, v_ax)
    if cfg.family == "hybrid" and cfg.attn_every:
        specs["shared_attn"] = {"ln": P(None), "attn": _attn_specs(cfg, mesh),
                                "ln2": P(None), "mlp": _mlp_specs(cfg, mesh, cfg.d_ff)}
    if cfg.family == "encdec":
        specs["encoder"] = {"layers": _prepend(_layer_specs(cfg, mesh)),
                            "final_ln": P(None)}
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int) -> dict:
    """Input sharding for a train batch."""
    b = batch_axes(mesh)
    b_ax = b if all(a in mesh.axis_names for a in b) else None
    spec = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
    if cfg.family == "encdec":
        spec["enc_embeds"] = P(b_ax, None, None)
    if cfg.family == "vlm":
        spec["patch_embeds"] = P(b_ax, None, None)
    return spec


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int, seq_shard: bool = False) -> dict:
    """KV / SSM-state cache sharding for decode.

    seq_shard=True (long_500k, batch=1): shard the cache *sequence* dim over
    ``data`` — decode-time context parallelism; softmax over the sharded key
    axis psums (DESIGN.md §5)."""
    b = batch_axes(mesh)
    bsz_total = 1
    for a in b:
        bsz_total *= mesh.shape[a]
    b_ax = b if batch % bsz_total == 0 and not seq_shard else None
    s_ax = "data" if seq_shard else None
    kv_ax = "model" if _div(cfg.num_kv_heads, mesh, "model") else None
    # decode caches MAY shard head_dim: the decode score psum is one token's
    # (B, KV, 1, G, S) — tiny next to the cache itself. (Training forbids
    # hd-sharding because there the psum is the full S x S scores.)
    hd_ax = None if kv_ax else ("model" if _div(cfg.hd, mesh, "model") else None)
    kv_spec = P(None, b_ax, s_ax, kv_ax, hd_ax)
    if cfg.family in ("decoder", "vlm"):
        return {"k": kv_spec, "v": kv_spec}
    if cfg.family == "encdec":
        return {"k": kv_spec, "v": kv_spec, "xk": kv_spec, "xv": kv_spec}
    h_ax = "model" if _div(cfg.ssm_heads, mesh, "model") else None
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    c_ax = "model" if _div(conv_dim, mesh, "model") else None
    ssm = {"state": P(None, b_ax, h_ax, None, None), "conv": P(None, b_ax, None, c_ax)}
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        return dict(ssm, k=kv_spec, v=kv_spec)
    raise ValueError(cfg.family)


def to_shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation sharding constraints (trace-time, context-scoped)
# ---------------------------------------------------------------------------
# XLA's sharding propagation sometimes prefers exotic head/group shardings
# that replicate the batch dim of the S x S attention scores — measured as a
# 34 GB/device temp on tinyllama train_4k (EXPERIMENTS.md §Perf iteration 1).
# Model code stays mesh-agnostic: constraints apply only when a launcher
# traces inside `activation_mesh(mesh)`.

import contextlib
import threading

_ACT = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    prev = getattr(_ACT, "mesh", None)
    _ACT.mesh = mesh
    try:
        yield
    finally:
        _ACT.mesh = prev


def constrain(x, *dims):
    """Constrain activation sharding by logical dim tags.

    Tags per dim: "batch" (data[+pod] if divisible), a mesh axis name
    (used if divisible), None (replicated), "un" (unconstrained — let
    propagation decide). No-op outside an activation_mesh context.
    """
    mesh = getattr(_ACT, "mesh", None)
    if mesh is None:
        return x
    un = P.UNCONSTRAINED
    resolved = []
    for tag, size in zip(dims, x.shape):
        if tag == "batch":
            axes = tuple(a for a in batch_axes(mesh) if a in mesh.axis_names)
            tot = 1
            for a in axes:
                tot *= mesh.shape[a]
            resolved.append(axes if tot and size % tot == 0 else un)
        elif tag == "un":
            resolved.append(un)
        elif tag is None:
            resolved.append(None)
        elif tag in mesh.axis_names:
            resolved.append(tag if size % mesh.shape[tag] == 0 else un)
        else:  # unknown axis for this mesh — leave to propagation
            resolved.append(un)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))
