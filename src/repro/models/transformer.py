"""Model assembly for every family in the zoo.

Families:
  decoder — llama-style decoder-only LM (dense or MoE), scan-over-layers.
  ssm     — attention-free Mamba-2 stack.
  hybrid  — Mamba-2 backbone + ONE weight-shared attention block applied
            every ``attn_every`` layers (Zamba2).
  encdec  — Whisper-style: bidirectional encoder over stub frame embeddings,
            causal decoder with cross-attention.
  vlm     — decoder-only backbone consuming [patch-embeddings ; tokens]
            (InternVL2: the ViT frontend is a stub per the assignment).

Params are nested dicts; repeated layers are stacked on a leading axis and
consumed by ``lax.scan`` (compile-time O(1) in depth). ``cfg.remat``
checkpoints each block.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (attention, attention_decode,
                                    cross_attention_decode, encode_kv,
                                    init_attention)
from repro.models.config import ModelConfig
from repro.models.layers import init_dense, init_embed, rms_norm, swiglu
from repro.models.moe import init_moe, moe_ffn
from repro.models.sharding import constrain
from repro.models.ssm import (init_mamba2, mamba2_decode, mamba2_forward,
                              mamba2_init_cache)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_in": init_dense(ks[0], cfg.d_model, cfg.d_ff, cfg.pdtype),
        "w_gate": init_dense(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
        "w_out": init_dense(ks[2], cfg.d_ff, cfg.d_model, cfg.pdtype),
    }


def _init_decoder_layer(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdtype),
        "attn": init_attention(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
        "mlp": init_moe(ks[1], cfg) if cfg.is_moe else _init_mlp(ks[1], cfg),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        p["xattn"] = init_attention(ks[2], cfg, cross=True)
    return p


def _init_ssm_layer(key, cfg: ModelConfig, *, with_mlp: bool) -> dict:
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.ones((cfg.d_model,), cfg.pdtype), "mamba": init_mamba2(ks[0], cfg)}
    if with_mlp:
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        p["mlp"] = _init_mlp(ks[1], cfg)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    if cfg.family in ("decoder", "vlm"):
        layers = jax.vmap(lambda k: _init_decoder_layer(k, cfg))(layer_keys)
    elif cfg.family == "ssm":
        layers = jax.vmap(lambda k: _init_ssm_layer(k, cfg, with_mlp=False))(layer_keys)
    elif cfg.family == "hybrid":
        # Zamba2: the backbone is mamba-only; the d_ff MLP lives in the
        # weight-shared transformer block (config.param_count matches 1.2B
        # only with this layout)
        layers = jax.vmap(lambda k: _init_ssm_layer(k, cfg, with_mlp=False))(layer_keys)
    elif cfg.family == "encdec":
        layers = jax.vmap(lambda k: _init_decoder_layer(k, cfg, cross=True))(layer_keys)
    else:
        raise ValueError(cfg.family)

    params = {
        "embed": init_embed(keys[1], cfg.padded_vocab, cfg.d_model, cfg.pdtype),
        "layers": layers,
        "final_ln": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[2], cfg.d_model, cfg.padded_vocab, cfg.pdtype)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = {
            "ln": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": init_attention(keys[3], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mlp": _init_mlp(keys[5], cfg),
        }
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[4], cfg.enc_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_decoder_layer(k, cfg))(enc_keys),
            "final_ln": jnp.ones((cfg.d_model,), cfg.pdtype),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _decoder_block(lp: dict, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool,
                   positions=None, enc_out=None) -> jnp.ndarray:
    # pin the residual stream batch-sharded: without this XLA prefers to
    # all-gather activations over ``data`` (computing every projection on
    # the full global batch, 16x redundant) instead of FSDP-gathering the
    # weights (§Perf deepseek iteration 2)
    x = constrain(x, "batch", "un", "un")
    h = attention(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                  causal=causal, positions=positions)
    x = x + h
    if enc_out is not None:
        h = attention(lp["xattn"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg,
                      causal=False, kv_x=enc_out, rope=False)
        x = x + h
    y = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        return x + moe_ffn(lp["mlp"], y, cfg)
    return x + swiglu(y, lp["mlp"]["w_in"], lp["mlp"]["w_gate"], lp["mlp"]["w_out"])


def _ssm_block(lp: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = constrain(x, "batch", "un", "un")
    x = x + mamba2_forward(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg)
    if "mlp" in lp:
        y = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(y, lp["mlp"]["w_in"], lp["mlp"]["w_gate"], lp["mlp"]["w_out"])
    return x


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn



def _layer_slice(layers, i: int):
    return jax.tree.map(lambda a: a[i], layers)


def _scan_or_unroll(blk, x, layers, cfg: ModelConfig):
    """lax.scan over stacked layers, or a python unroll when
    cfg.scan_layers=False (used by the dry-run cost probes: XLA's
    HloCostAnalysis counts while-loop bodies once, so probes unroll)."""
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (blk(lp, c), None), x, layers)
        return x
    n = jax.tree.leaves(layers)[0].shape[0]
    for i in range(n):
        x = blk(_layer_slice(layers, i), x)
    return x


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, S_total, V).

    prefix_embeds: (B, P, d) modality embeddings prepended to the token
    embeddings (vlm / the assignment's stub frontends).
    enc_embeds: (B, S_enc, d) encoder-side stub frame embeddings (encdec).
    """
    x = params["embed"][tokens].astype(cfg.cdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)

    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None, "encdec needs encoder embeddings"
        enc_out = _encode(params, enc_embeds, cfg)

    if cfg.family in ("decoder", "vlm", "encdec"):
        blk = _maybe_remat(
            partial(_decoder_block, cfg=cfg, causal=True, enc_out=enc_out), cfg)
        x = _scan_or_unroll(blk, x, params["layers"], cfg)
    elif cfg.family == "ssm":
        blk = _maybe_remat(partial(_ssm_block, cfg=cfg), cfg)
        x = _scan_or_unroll(blk, x, params["layers"], cfg)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, x, cfg)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)


def _encode(params: dict, enc_embeds: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = enc_embeds.astype(cfg.cdtype)
    blk = _maybe_remat(partial(_decoder_block, cfg=cfg, causal=False), cfg)
    x = _scan_or_unroll(blk, x, params["encoder"]["layers"], cfg)
    return rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)


def _hybrid_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Mamba scan with the weight-shared attention block every attn_every
    layers (Zamba2). The shared block's weights are closure constants, so
    the scan still compiles O(1) in depth."""
    sa = params["shared_attn"]
    mamba_blk = _maybe_remat(partial(_ssm_block, cfg=cfg), cfg)

    def shared(x):
        x = x + attention(sa["attn"], rms_norm(x, sa["ln"], cfg.norm_eps), cfg, causal=True)
        y = rms_norm(x, sa["ln2"], cfg.norm_eps)
        return x + swiglu(y, sa["mlp"]["w_in"], sa["mlp"]["w_gate"], sa["mlp"]["w_out"])

    def body(carry, inp):
        i, lp = inp
        x = carry
        x = jax.lax.cond(i % cfg.attn_every == 0, shared, lambda v: v, x)
        return mamba_blk(lp, x), None

    idx = jnp.arange(cfg.num_layers)
    x, _ = _scan_with_cache(body, x, (idx, params["layers"]), cfg.scan_layers)
    return x



def _scan_with_cache(body, carry, inputs, scan: bool):
    """scan, or python-unroll + restack ys (dry-run cost probes)."""
    if scan:
        return jax.lax.scan(body, carry, inputs)
    n = jax.tree.leaves(inputs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], inputs))
        ys.append(y)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


# ---------------------------------------------------------------------------
# prefill (forward that also materializes the decode cache)
# ---------------------------------------------------------------------------

def prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, *,
            enc_embeds: Optional[jnp.ndarray] = None,
            prefix_embeds: Optional[jnp.ndarray] = None) -> tuple[jnp.ndarray, dict]:
    """Full forward over the prompt, returning (logits, decode cache).

    Cache sequence length == prompt length; serve/engine.py pads it out to
    the generation horizon before decoding.
    """
    from repro.models.attention import attention_with_cache, encode_kv

    x = params["embed"][tokens].astype(cfg.cdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)

    if cfg.family in ("decoder", "vlm", "encdec"):
        enc_out = None
        if cfg.family == "encdec":
            enc_out = _encode(params, enc_embeds, cfg)

        def body(carry, lp):
            x = carry
            h, k, v = attention_with_cache(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg)
            x = x + h
            ys = {"k": k, "v": v}
            if enc_out is not None:
                h = attention(lp["xattn"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg,
                              causal=False, kv_x=enc_out, rope=False)
                x = x + h
                xk, xv = encode_kv(lp["xattn"], enc_out)
                ys.update(xk=xk, xv=xv)
            y = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                x = x + moe_ffn(lp["mlp"], y, cfg)
            else:
                x = x + swiglu(y, lp["mlp"]["w_in"], lp["mlp"]["w_gate"], lp["mlp"]["w_out"])
            return x, ys

        x, cache = _scan_with_cache(body, x, params["layers"], cfg.scan_layers)

    elif cfg.family == "ssm":
        def body(carry, lp):
            x = carry
            h, c = mamba2_forward(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                                  return_cache=True)
            return x + h, c

        x, cache = _scan_with_cache(body, x, params["layers"], cfg.scan_layers)

    elif cfg.family == "hybrid":
        sa = params["shared_attn"]
        n_apps = (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
        s_len = x.shape[1]
        ks = jnp.zeros((n_apps, x.shape[0], s_len, cfg.num_kv_heads, cfg.hd), cfg.cdtype)
        vs = jnp.zeros_like(ks)

        def body(carry, inp):
            i, lp = inp
            x, ks, vs = carry
            app = jnp.minimum(i // cfg.attn_every, n_apps - 1)

            def with_attn(op):
                x, ks, vs = op
                h, k, v = attention_with_cache(sa["attn"], rms_norm(x, sa["ln"], cfg.norm_eps), cfg)
                ks = jax.lax.dynamic_update_index_in_dim(ks, k.astype(ks.dtype), app, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, v.astype(vs.dtype), app, 0)
                x = x + h
                y = rms_norm(x, sa["ln2"], cfg.norm_eps)
                x = x + swiglu(y, sa["mlp"]["w_in"], sa["mlp"]["w_gate"], sa["mlp"]["w_out"])
                return x, ks, vs

            x, ks, vs = jax.lax.cond(i % cfg.attn_every == 0, with_attn, lambda o: o, (x, ks, vs))
            h, c = mamba2_forward(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                                  return_cache=True)
            return (x + h, ks, vs), c

        idx = jnp.arange(cfg.num_layers)
        (x, ks, vs), ssm_cache = _scan_with_cache(body, (x, ks, vs), (idx, params["layers"]),
                                                  cfg.scan_layers)
        cache = dict(ssm_cache, k=ks, v=vs)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits, cache


# ---------------------------------------------------------------------------
# decode (one token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               enc_len: int = 0, dtype=None) -> dict:
    dtype = dtype or cfg.cdtype
    hd, kv = cfg.hd, cfg.num_kv_heads
    if cfg.family in ("decoder", "vlm"):
        return {"k": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype)}
    if cfg.family == "encdec":
        return {"k": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype),
                "xk": jnp.zeros((cfg.num_layers, batch, enc_len, kv, hd), dtype),
                "xv": jnp.zeros((cfg.num_layers, batch, enc_len, kv, hd), dtype)}
    if cfg.family == "ssm":
        c = mamba2_init_cache(cfg, batch, dtype)
        return {"state": jnp.zeros((cfg.num_layers,) + c["state"].shape, jnp.float32),
                "conv": jnp.zeros((cfg.num_layers,) + c["conv"].shape, dtype)}
    if cfg.family == "hybrid":
        c = mamba2_init_cache(cfg, batch, dtype)
        n_apps = (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
        return {"state": jnp.zeros((cfg.num_layers,) + c["state"].shape, jnp.float32),
                "conv": jnp.zeros((cfg.num_layers,) + c["conv"].shape, dtype),
                "k": jnp.zeros((n_apps, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((n_apps, batch, max_len, kv, hd), dtype)}
    raise ValueError(cfg.family)


def decode_step(params: dict, token: jnp.ndarray, cache: dict, position: jnp.ndarray,
                cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """token: (B,) int32; position: scalar int32. Returns (logits (B, V), cache)."""
    x = params["embed"][token][:, None].astype(cfg.cdtype)  # (B,1,d)

    if cfg.family in ("decoder", "vlm", "encdec"):
        def body(carry, inp):
            lp, ck, cv, *cross = inp
            x = carry
            h, newc = attention_decode(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                       {"k": ck, "v": cv}, position, cfg)
            x = x + h
            if cross:
                xk, xv = cross
                h = cross_attention_decode(lp["xattn"], rms_norm(x, lp["ln_x"], cfg.norm_eps),
                                           xk, xv, cfg)
                x = x + h
            y = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                x = x + moe_ffn(lp["mlp"], y, cfg)
            else:
                x = x + swiglu(y, lp["mlp"]["w_in"], lp["mlp"]["w_gate"], lp["mlp"]["w_out"])
            return x, (newc["k"], newc["v"])

        inputs = (params["layers"], cache["k"], cache["v"])
        if cfg.family == "encdec":
            inputs = inputs + (cache["xk"], cache["xv"])
        x, (nk, nv) = _scan_with_cache(body, x, inputs, cfg.scan_layers)
        new_cache = dict(cache, k=nk, v=nv)

    elif cfg.family == "ssm":
        def body(carry, inp):
            lp, st, cv = inp
            x = carry
            h, newc = mamba2_decode(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                    {"state": st, "conv": cv}, cfg)
            return x + h, (newc["state"], newc["conv"])

        x, (ns, ncv) = _scan_with_cache(body, x, (params["layers"], cache["state"], cache["conv"]),
                                        cfg.scan_layers)
        new_cache = {"state": ns, "conv": ncv}

    elif cfg.family == "hybrid":
        sa = params["shared_attn"]
        n_apps = cache["k"].shape[0]
        ks, vs = cache["k"], cache["v"]

        def body(carry, inp):
            i, lp, st, cv = inp
            x, ks, vs = carry
            app = jnp.minimum(i // cfg.attn_every, n_apps - 1)

            def with_attn(operand):
                x, ks, vs = operand
                h, newc = attention_decode(sa["attn"], rms_norm(x, sa["ln"], cfg.norm_eps),
                                           {"k": ks[app], "v": vs[app]}, position, cfg)
                ks = jax.lax.dynamic_update_index_in_dim(ks, newc["k"], app, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, newc["v"], app, 0)
                x = x + h
                y = rms_norm(x, sa["ln2"], cfg.norm_eps)
                x = x + swiglu(y, sa["mlp"]["w_in"], sa["mlp"]["w_gate"], sa["mlp"]["w_out"])
                return x, ks, vs

            x, ks, vs = jax.lax.cond(i % cfg.attn_every == 0, with_attn,
                                     lambda o: o, (x, ks, vs))
            h, newc = mamba2_decode(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                    {"state": st, "conv": cv}, cfg)
            return (x + h, ks, vs), (newc["state"], newc["conv"])

        idx = jnp.arange(cfg.num_layers)
        (x, ks, vs), (ns, ncv) = _scan_with_cache(
            body, (x, ks, vs), (idx, params["layers"], cache["state"], cache["conv"]),
            cfg.scan_layers)
        new_cache = {"state": ns, "conv": ncv, "k": ks, "v": vs}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits[:, 0], new_cache
