"""GQA attention with RoPE, optional sliding window, cross-attention, and
KV-cache decode. einsum formulation so pjit can shard heads over ``model``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rotary, init_dense, rotary_embedding
from repro.models.sharding import constrain

NEG_INF = -1e30


def _pad_heads_grouped(w, cfg: ModelConfig, *, head_axis: int):
    """Zero-pad q heads to cfg.q_heads, inserting pads at the END of each
    KV group so every real head keeps its original kv assignment — the
    padded heads produce zero scores -> uniform attention -> zeroed by the
    zero wo rows, so the math is exact (§Perf yi-34b iteration)."""
    h, kv, hp = cfg.num_heads, cfg.num_kv_heads, cfg.q_heads
    if hp == h:
        return w
    assert h % kv == 0 and hp % kv == 0, (
        "padded_q_heads requires kv | heads and kv | padded (MHA models "
        "would need paired q+kv padding)", h, kv, hp)
    per, per_pad = h // kv, hp // kv
    shape = list(w.shape)
    shape[head_axis:head_axis + 1] = [kv, per]
    w = w.reshape(shape)
    pad = [(0, 0)] * len(shape)
    pad[head_axis + 1] = (0, per_pad - per)
    w = jnp.pad(w, pad)
    shape[head_axis:head_axis + 2] = [hp]
    return w.reshape(shape)


def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 5)
    wq = init_dense(ks[0], cfg.d_model, cfg.num_heads * hd, cfg.pdtype).reshape(
        cfg.d_model, cfg.num_heads, hd)
    wo = init_dense(ks[3], cfg.num_heads * hd, cfg.d_model, cfg.pdtype).reshape(
        cfg.num_heads, hd, cfg.d_model)
    p = {
        "wq": _pad_heads_grouped(wq, cfg, head_axis=1),
        "wk": init_dense(ks[1], cfg.d_model, cfg.num_kv_heads * hd, cfg.pdtype).reshape(
            cfg.d_model, cfg.num_kv_heads, hd),
        "wv": init_dense(ks[2], cfg.d_model, cfg.num_kv_heads * hd, cfg.pdtype).reshape(
            cfg.d_model, cfg.num_kv_heads, hd),
        "wo": _pad_heads_grouped(wo, cfg, head_axis=0),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.q_heads, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), cfg.pdtype)
    return p


def _qkv(p: dict, x: jnp.ndarray, kv_x: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q, "batch", "un", "un", "un")
    k = constrain(k, "batch", "un", "un", "un")
    v = constrain(v, "batch", "un", "un", "un")
    return q, k, v


def _attend(q, k, v, mask, num_kv_heads: int):
    """q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd); GQA groups H/KV."""
    b, sq, h, hd = q.shape
    groups = h // num_kv_heads
    q = q.reshape(b, sq, num_kv_heads, groups, hd)
    scores = jnp.einsum("bsngk,btnk->bnsgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    # keep the batch dim of the S x S scores sharded — XLA propagation will
    # otherwise replicate it in favor of exotic head shardings (34 GB/dev
    # measured on train_4k; EXPERIMENTS.md §Perf)
    scores = constrain(scores, "batch", "un", "un", "un", "un")
    if mask is not None:
        scores = jnp.where(mask[:, None, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnsgt,btnk->bsngk", probs.astype(v.dtype), v)
    out = constrain(out, "batch", "un", "un", "un", "un")
    return out.reshape(b, sq, h, hd)


def _attend_chunked(q, k, v, num_kv_heads: int, *, chunk: int, causal: bool,
                    window: int = 0):
    """Online-softmax attention over key chunks (flash-attention schedule,
    beyond-paper optimization for the memory-bound train cells: peak temp
    drops from O(S^2) to O(S*chunk); EXPERIMENTS.md §Perf).

    Jacobian-complete: plain lax.scan of differentiable ops, so remat/grad
    work unchanged.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    groups = h // num_kv_heads
    assert sk % chunk == 0, (sk, chunk)
    qr = q.reshape(b, sq, num_kv_heads, groups, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(b, sk // chunk, chunk, num_kv_heads, hd)
    vc = v.reshape(b, sk // chunk, chunk, num_kv_heads, hd)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        m_run, l_run, acc = carry
        ci, k_blk, v_blk = inp
        s = jnp.einsum("bsngk,btnk->bnsgt", qr, k_blk.astype(jnp.float32)) * scale
        s = constrain(s, "batch", "un", "un", "un", "un")
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask = kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask = jnp.logical_and(mask, kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnsgt,btnk->bnsgk", p, v_blk.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, num_kv_heads, sq, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, num_kv_heads, sq, groups), jnp.float32)
    a0 = jnp.zeros((b, num_kv_heads, sq, groups, hd), jnp.float32)
    idx = jnp.arange(sk // chunk)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (idx, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    out = jnp.moveaxis(out, 1, 2)  # (b, sq, n, g, hd)
    return out.reshape(b, sq, h, hd).astype(v.dtype)


def causal_mask(sq: int, sk: int, *, window: int = 0, offset: int = 0) -> jnp.ndarray:
    """(1, Sq, Sk) bool; query i attends key j iff j <= i+offset (and within
    the sliding window when window > 0)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m = jnp.logical_and(m, kj > qi - window)
    return m[None]


def attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool = True,
              positions: Optional[jnp.ndarray] = None,
              kv_x: Optional[jnp.ndarray] = None,
              rope: bool = True) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). kv_x != None => cross-attn."""
    kv_in = x if kv_x is None else kv_x
    q, k, v = _qkv(p, x, kv_in)
    if rope and kv_x is None:
        if positions is None:
            positions = jnp.arange(x.shape[1])
        cos, sin = rotary_embedding(positions, cfg.hd, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    if cfg.attn_chunk and kv_x is None and x.shape[1] % cfg.attn_chunk == 0:
        out = _attend_chunked(q, k, v, cfg.num_kv_heads, chunk=cfg.attn_chunk,
                              causal=causal, window=cfg.sliding_window)
    else:
        mask = None
        if causal and kv_x is None:
            mask = causal_mask(x.shape[1], kv_in.shape[1], window=cfg.sliding_window)
        out = _attend(q, k, v, mask, cfg.num_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_with_cache(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                         positions: Optional[jnp.ndarray] = None):
    """Causal self-attention that also returns rotary-applied (k, v) for a
    prefill cache. Returns (out, k, v)."""
    q, k, v = _qkv(p, x, x)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    cos, sin = rotary_embedding(positions, cfg.hd, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    if cfg.attn_chunk and x.shape[1] % cfg.attn_chunk == 0:
        out = _attend_chunked(q, k, v, cfg.num_kv_heads, chunk=cfg.attn_chunk,
                              causal=True, window=cfg.sliding_window)
    else:
        mask = causal_mask(x.shape[1], x.shape[1], window=cfg.sliding_window)
        out = _attend(q, k, v, mask, cfg.num_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k, v


def attention_decode(p: dict, x: jnp.ndarray, cache: dict, position: jnp.ndarray,
                     cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """One-token decode against a KV cache.

    x: (B, 1, d). cache: {"k": (B, S_max, KV, hd), "v": ...}. position: scalar
    int32 — index of the new token. With sliding-window configs the cache is
    still laid out full-length; masking enforces the window (ring-buffer
    layout is a serving-engine optimization, see serve/engine.py).
    """
    q, k_new, v_new = _qkv(p, x, x)
    cos, sin = rotary_embedding(position[None], cfg.hd, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k_new = apply_rotary(k_new, cos, sin)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                           (0, position, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                           (0, position, 0, 0))
    s_max = k_cache.shape[1]
    kj = jnp.arange(s_max)[None, :]
    mask = kj <= position
    if cfg.sliding_window > 0:
        mask = jnp.logical_and(mask, kj > position - cfg.sliding_window)
    out = _attend(q, k_cache, v_cache, mask[:, None, :] * jnp.ones((x.shape[0], 1, 1), bool),
                  cfg.num_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache}


def cross_attention_decode(p: dict, x: jnp.ndarray, enc_k: jnp.ndarray,
                           enc_v: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Decode-time cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    out = _attend(q, enc_k, enc_v, None, cfg.num_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_kv(p: dict, enc_out: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v
