"""Closed-loop introspection: shard profiles, SLO watchdog, flight recorder,
metrics merge, and the HTML perf report.

The flight recorder and metrics registry are process-global; every test that
mutates them restores the quiet state in a finally block so the rest of the
suite keeps seeing the zero-overhead path.
"""
import json
import os

import numpy as np
import pytest

from repro.core.difuser import DiFuserConfig
from repro.graphs import rmat_graph
from repro.obs import flight, metrics, shardprof, trace
from repro.obs.slo import SLOConfig, SLOWatchdog


@pytest.fixture
def quiet_flight(tmp_path):
    """Point the global flight recorder at tmp_path with a clean ring and
    dump budget; restore the defaults afterwards."""
    fr = flight.get_flight_recorder()
    old_dir, old_max = fr.out_dir, fr.max_dumps
    fr.clear()
    fr.dump_count, fr.dumps = 0, []
    flight.configure(out_dir=str(tmp_path), max_dumps=8)
    try:
        yield fr
    finally:
        fr.clear()
        fr.dump_count, fr.dumps = 0, []
        flight.configure(out_dir=old_dir, max_dumps=old_max, enabled=True)


@pytest.fixture
def shard_profiling():
    shardprof.clear()
    shardprof.set_enabled(True)
    try:
        yield
    finally:
        shardprof.set_enabled(False)
        shardprof.clear()


# ---------------------------------------------------------------------------
# metrics: histogram merge + JSONL round trip
# ---------------------------------------------------------------------------


def test_histogram_merge_equals_combined_stream():
    a = metrics.Histogram()
    b = metrics.Histogram()
    c = metrics.Histogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(-6, 1.5, 400)
    for x in xs[:250]:
        a.observe(float(x))
    for x in xs[250:]:
        b.observe(float(x))
    for x in xs:
        c.observe(float(x))
    a.merge(b)
    assert a.count == c.count == 400
    for q in (50, 90, 99):
        assert a.percentile(q) == pytest.approx(c.percentile(q))


def test_histogram_bucket_boundaries_are_index_exact():
    h = metrics.Histogram()
    # boundary values land in their own bucket, not the one below (the
    # epsilon-alignment fix); i=0 is the <=V0 underflow bucket by design
    for i in range(1, 800):
        v = metrics._V0 * metrics._GROWTH ** i
        assert h._index(v) == i, f"boundary {i} misaligned"


def test_registry_jsonl_merge_roundtrip(tmp_path):
    r1 = metrics.MetricsRegistry()
    r2 = metrics.MetricsRegistry()
    r1.counter("reqs", path="a").inc(3)
    r2.counter("reqs", path="a").inc(4)
    r1.gauge("imb").set(1.5)
    r2.gauge("imb").set(2.5)
    for x in (0.001, 0.002, 0.004):
        r1.histogram("lat").observe(x)
    for x in (0.008, 0.016):
        r2.histogram("lat").observe(x)
    p1, p2 = tmp_path / "m1.jsonl", tmp_path / "m2.jsonl"
    r1.write_jsonl(str(p1))
    r2.write_jsonl(str(p2))

    merged = metrics.MetricsRegistry.from_jsonl(str(p1), str(p2))
    snap = {(rec["name"], tuple(sorted(rec.get("tags", {}).items()))): rec
            for rec in merged.snapshot()}
    assert snap[("reqs", (("path", "a"),))]["value"] == 7
    assert snap[("imb", ())]["value"] == 2.5          # gauges: last wins
    lat = snap[("lat", ())]
    assert lat["count"] == 5
    assert lat["max"] == pytest.approx(0.016)
    # and the merged percentile matches the combined stream
    direct = metrics.Histogram()
    for x in (0.001, 0.002, 0.004, 0.008, 0.016):
        direct.observe(x)
    assert lat["p99"] == pytest.approx(direct.percentile(99))


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_ring_is_bounded_and_captures_timed_spans(quiet_flight):
    fr = quiet_flight
    flight.configure(capacity=16)
    try:
        assert not trace.get_recorder().enabled
        for i in range(40):   # timed spans are real even with tracing off
            with trace.span("tick", phase="query", timed=True, i=i):
                pass
        assert len(fr) == 16
        names = [e["attrs"]["i"] for e in fr.events()]
        assert names == list(range(24, 40))   # oldest evicted first
    finally:
        flight.configure(capacity=flight.DEFAULT_CAPACITY)


def test_flight_dump_is_chrome_trace_with_reason(quiet_flight, tmp_path):
    with trace.span("work", phase="build", timed=True):
        pass
    path = flight.dump("unit-test reason!")
    assert path is not None and os.path.exists(path)
    assert "unit-test" in os.path.basename(path)
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert any(e["ph"] == "X" and e["name"] == "work" for e in evs)
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and "unit-test" in inst[0]["args"]["reason"]
    assert doc["metadata"]["reason"] == "unit-test reason!"


def test_flight_dump_rate_limit(quiet_flight):
    fr = quiet_flight
    fr.max_dumps = 2
    with trace.span("w", phase="query", timed=True):
        pass
    assert flight.dump("one") is not None
    assert flight.dump("two") is not None
    assert flight.dump("three") is None     # over budget: dropped, no raise
    assert fr.dump_count == 2


def test_engine_exception_dumps_flight_and_reraises(quiet_flight, monkeypatch):
    from repro.service import InfluenceEngine, TopKSeeds
    from repro.service import queries as Q

    g = rmat_graph(6, edge_factor=8, seed=0, setting="w1")
    eng = InfluenceEngine()
    key = eng.register(g, DiFuserConfig(num_registers=32, seed=0))

    def boom(*a, **k):
        raise RuntimeError("Boom")

    monkeypatch.setattr(Q, "spread_estimates", boom)
    eng.submit(key, Q.SpreadEstimate((1, 2)))
    before = metrics.registry().counter(
        "engine.exceptions", error="RuntimeError").value
    with pytest.raises(RuntimeError, match="Boom"):
        eng.run()
    after = metrics.registry().counter(
        "engine.exceptions", error="RuntimeError").value
    assert after == before + 1
    assert len(quiet_flight.dumps) == 1
    assert "engine-exception-RuntimeError" in quiet_flight.dumps[0]
    doc = json.load(open(quiet_flight.dumps[0]))
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


def test_slo_breach_dumps_flight_e2e(quiet_flight):
    """An impossible budget breaches on real engine traffic; the breach
    callback dumps the ring exactly once (rising edge)."""
    from repro.service import InfluenceEngine
    from repro.service import queries as Q

    g = rmat_graph(6, edge_factor=8, seed=0, setting="w1")
    eng = InfluenceEngine(slo=SLOConfig(
        budgets=(("SpreadEstimate", 1e-6),), window=16, min_samples=3))
    key = eng.register(g, DiFuserConfig(num_registers=32, seed=0))
    for i in range(5):   # one batch per call -> one watchdog sample each
        eng(key, Q.SpreadEstimate((i + 1,)))
    summ = eng.slo_summary()
    assert summ["_breach_count"] == 1          # rising edge fires once
    assert summ["SpreadEstimate"]["in_breach"]
    assert (summ["SpreadEstimate"]["window_p99_ms"]
            > summ["SpreadEstimate"]["budget_ms"])
    assert len(quiet_flight.dumps) == 1
    assert "slo-breach-SpreadEstimate" in quiet_flight.dumps[0]


# ---------------------------------------------------------------------------
# SLO watchdog (unit)
# ---------------------------------------------------------------------------


def test_slo_config_coerce_forms():
    assert SLOConfig.coerce(None) is None
    assert SLOConfig.coerce(()) is None
    assert SLOConfig.coerce({}) is None
    cfg = SLOConfig.coerce({"TopKSeeds": 50.0})
    assert cfg.budget_ms("TopKSeeds") == 50.0
    assert cfg.budget_ms("Other") is None
    cfg2 = SLOConfig.coerce((("A", 1.0), ("B", 2.0)))
    assert cfg2.budget_ms("B") == 2.0
    assert SLOConfig.coerce(cfg2) is cfg2


def test_slo_watchdog_rising_edge_and_recovery():
    hits = []
    wd = SLOWatchdog(SLOConfig(budgets=(("q", 10.0),), window=8,
                               min_samples=2),
                     on_breach=lambda qc, p99, bud, w: hits.append((qc, p99)))
    assert not wd.observe("q", 0.001)      # 1ms, under budget
    for _ in range(8):
        wd.observe("q", 0.050)             # 50ms >> 10ms budget
    # rising edge fired exactly once across the excursion
    assert len(hits) == 1 and hits[0][0] == "q"
    assert wd.in_breach("q")
    for _ in range(16):                    # window drains back under budget
        wd.observe("q", 0.001)
    assert not wd.in_breach("q")
    for _ in range(8):                     # second excursion -> second edge
        wd.observe("q", 0.050)
    assert len(hits) == 2
    # unbudgeted classes are observed but never breach
    assert not wd.observe("unbudgeted", 999.0)


def test_slo_min_samples_gates_warmup():
    wd = SLOWatchdog(SLOConfig(budgets=(("q", 1.0),), min_samples=5))
    for _ in range(4):
        assert not wd.observe("q", 1.0)    # 1000ms over budget, but warming
    assert wd.observe("q", 1.0)            # 5th sample arms the watchdog


def test_runspec_carries_slo_to_engine():
    from repro.runtime import RunSpec
    from repro.service import InfluenceEngine

    spec = RunSpec.from_config(DiFuserConfig(num_registers=32),
                               backend="single")
    spec = spec.with_(slo=(("TopKSeeds", 250.0),))
    eng = InfluenceEngine(spec=spec)
    assert eng.slo is not None
    assert eng.slo.config.budget_ms("TopKSeeds") == 250.0


# ---------------------------------------------------------------------------
# shard profiles: predicted vs measured on a skewed RMAT
# ---------------------------------------------------------------------------


def _serial_profile(g, strategy):
    from repro.partition.serial import _find_seeds_ring_serial

    res, _ = _find_seeds_ring_serial(
        g, 2, DiFuserConfig(num_registers=64, seed=0),
        mu_v=4, mu_s=1, strategy=strategy)
    prof = shardprof.last_profile()
    assert prof is not None
    return res, prof


def test_measured_profile_degree_beats_block_on_skewed_rmat(shard_profiling):
    g = rmat_graph(8, edge_factor=8, a=0.65, b=0.15, c=0.15, seed=3,
                   setting="w1")
    res_blk, blk = _serial_profile(g, "block")
    res_deg, deg = _serial_profile(g, "degree")
    # strategies agree on the answer...
    assert np.array_equal(res_blk.seeds, res_deg.seeds)
    # ...but the measured byte skew separates them: the degree planner
    # spreads hub traffic, block concentrates it
    assert blk.bytes_imbalance() > 1.2
    assert deg.bytes_imbalance() < blk.bytes_imbalance() * 0.8
    # the serial ring times each bucket merge individually
    assert blk.per_step_timed and deg.per_step_timed
    assert blk.phase == "fixpoint" and blk.backend == "serial"
    assert blk.step_seconds.shape == (4, 4)
    assert float(blk.step_seconds.sum()) > 0.0
    assert int(blk.step_bytes.sum()) > 0
    # skew table: header + one row per vertex shard
    table = blk.skew_table()
    assert "bytes_imb" in table
    assert sum(line.lstrip().startswith(tuple("0123"))
               for line in table.splitlines()) == 4


def test_predicted_vs_measured_gauges_published(shard_profiling):
    g = rmat_graph(8, edge_factor=8, a=0.65, b=0.15, c=0.15, seed=3,
                   setting="w1")
    _serial_profile(g, "block")
    snap = {(rec["name"], tuple(sorted(rec.get("tags", {}).items())))
            for rec in metrics.registry().snapshot()}
    labels = (("backend", "serial"), ("strategy", "block"))
    for name in ("partition.measured_edge_imb",
                 "partition.measured_time_imb",
                 "partition.achieved_gbps",
                 "partition.predicted_vs_measured_edge_imb",
                 "partition.predicted_vs_measured_bucket_imb"):
        assert (name, labels) in snap, f"missing gauge {name}"
    # measured bytes are proportional to the planner's per-edge counts, so
    # the edge-imbalance ratio is a consistency check: it must be ~1
    ratio = metrics.registry().gauge(
        "partition.predicted_vs_measured_edge_imb",
        backend="serial", strategy="block").value
    assert ratio == pytest.approx(1.0, rel=0.05)


def test_mesh_profile_bytes_only(shard_profiling):
    """The SPMD mesh path can't time per-step host-side; it publishes a
    bytes-only profile derived from the partition's real edge counts."""
    prof = shardprof.ShardProfiler(2, 2, backend="mesh", phase="build",
                                   strategy="block")
    counts = np.arange(8, dtype=np.int64).reshape(2, 2, 2) + 1
    prof.add_partition_bytes(counts, j_loc=16, sweeps=3)
    p = prof.finish(wall_s=0.5)
    assert not p.per_step_timed
    per_edge = shardprof.bucket_bytes(1, 16)
    assert int(p.step_bytes.sum()) == int(counts.sum()) * per_edge * 3
    # time imbalance falls back to bytes imbalance when steps aren't timed
    assert p.time_imbalance() == pytest.approx(p.bytes_imbalance())
    assert p.achieved_gbps() > 0.0


def test_profile_ring_is_bounded(shard_profiling):
    for i in range(80):
        prof = shardprof.ShardProfiler(2, 1, backend="serial", phase="build")
        prof.record(0, 0, 0.001, 100)
        shardprof.publish(prof.finish(wall_s=0.01))
    assert len(shardprof.profiles()) == 64


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def test_write_report_smoke(tmp_path, shard_profiling):
    from repro.obs import report

    g = rmat_graph(7, edge_factor=8, seed=1, setting="w1")
    _serial_profile(g, "block")
    wd = SLOWatchdog(SLOConfig(budgets=(("TopKSeeds", 10.0),), min_samples=1))
    wd.observe("TopKSeeds", 0.002)
    runtime = {"backends": {"serial": {"available": True,
                                       "seeds_per_s_warm": 12.5,
                                       "cold_s": 1.0, "warm_s": 0.8,
                                       "store_build_s": 0.2}}}
    service = {"qps": 120.0, "wall_s": 1.6,
               "host": {"p50_ms": 4.0, "p99_ms": 9.0, "qps": 120.0},
               "device": None,
               "async": {"sustained_qps": 1300.0, "deadline_ms": 50.0,
                         "completed": 1500, "deadline_misses": 30,
                         "deadline_miss_rate": 0.02, "e2e_p99_ms": 42.0,
                         "flushes": 210, "cross_entry_batches": 4,
                         "admission_stalls": 0,
                         "resident_bytes": 1 << 20,
                         "budget_bytes": 2 << 20,
                         "queue_depth_timeline": [(0.0, 0), (0.1, 7),
                                                  (0.2, 3), (0.3, 0)]}}
    events = [{"name": "build", "phase": "build", "depth": 0,
               "ts_s": 0.0, "dur_s": 1.25, "attrs": {}}]
    out = tmp_path / "report.html"
    report.write_report(str(out), title="unit", runtime=runtime,
                        service=service, events=events,
                        metrics_rows=metrics.registry().snapshot(),
                        profiles=shardprof.profiles(),
                        slo=wd.summary(), generated="2026-08-09")
    html = out.read_text()
    assert "<svg" in html and "prefers-color-scheme" in html
    assert "Shard skew" in html and "SLO" in html
    assert "TopKSeeds" in html
    assert "Admission" in html and "queue depth over time" in html
    assert "deadline misses" in html and "sustained qps" in html
    assert len(html) > 4000


def test_write_report_empty_inputs_never_error(tmp_path):
    from repro.obs import report

    out = tmp_path / "empty.html"
    report.write_report(str(out))
    html = out.read_text()
    assert "<html" in html and len(html) > 500


def test_write_report_from_artifacts(tmp_path, monkeypatch):
    from repro.obs import report

    monkeypatch.chdir(tmp_path)
    (tmp_path / "BENCH_runtime.json").write_text(json.dumps(
        {"backends": {"single": {"available": True,
                                 "seeds_per_s_warm": 5.0}}}))
    out = report.write_report_from_artifacts("r.html", generated="now")
    assert os.path.exists(out)
    assert "single" in open(out).read()
