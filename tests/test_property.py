"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional test dependency (not baked into the runtime
image); the module skips cleanly when it is absent so plain ``pytest -x -q``
still collects the rest of the suite.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.sampling import clz32, edge_hash, mix32, weight_to_threshold
from repro.core.sketch import VISITED, merge

regs = st.lists(st.integers(min_value=-1, max_value=32), min_size=4, max_size=4)


def _m(vals):
    return jnp.asarray(np.array(vals, dtype=np.int8)[None, :])


@settings(max_examples=60, deadline=None)
@given(regs, regs)
def test_merge_commutative_modulo_visited(a, b):
    """merge(a,b) == merge(b,a) wherever neither side is VISITED; VISITED
    positions are sticky to the *left* operand (the paper's in-place
    update)."""
    ab = np.asarray(merge(_m(a), _m(b)))[0]
    ba = np.asarray(merge(_m(b), _m(a)))[0]
    for i, (x, y) in enumerate(zip(a, b)):
        if x != VISITED and y != VISITED:
            assert ab[i] == ba[i] == max(x, y)


@settings(max_examples=60, deadline=None)
@given(regs, regs, regs)
def test_merge_contribution_associative(a, b, c):
    """The law the kernels rely on: the destination guard commutes with
    accumulating contributions by plain max —
        merge(merge(a, b), c) == merge(a, max(b, c)).
    (Plain associativity of ``merge`` itself does NOT hold: VISITED is
    sticky only on the destination side, by design.)"""
    import jax.numpy as jnp

    lhs = merge(merge(_m(a), _m(b)), _m(c))
    rhs = merge(_m(a), jnp.maximum(_m(b), _m(c)))
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@settings(max_examples=60, deadline=None)
@given(regs)
def test_merge_idempotent(a):
    m = _m(a)
    np.testing.assert_array_equal(np.asarray(merge(m, m)), np.asarray(m))


@settings(max_examples=60, deadline=None)
@given(regs, regs)
def test_merge_monotone_and_visited_sticky(a, b):
    out = np.asarray(merge(_m(a), _m(b)))[0]
    for i, x in enumerate(a):
        if x == VISITED:
            assert out[i] == VISITED  # visited never resurrects
        else:
            assert out[i] >= x  # monotone non-decreasing


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_clz32_definition(v):
    x = np.array([v], dtype=np.uint32)
    expect = 32 if v == 0 else 32 - int(v).bit_length()
    assert clz32(x)[0] == expect


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_edge_hash_deterministic(u, v):
    a = edge_hash(np.array([u]), np.array([v]))
    b = edge_hash(np.array([u]), np.array([v]))
    assert a[0] == b[0]


@settings(max_examples=40, deadline=None)
@given(st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
def test_threshold_in_range(w):
    thr = weight_to_threshold(np.array([w], np.float32))
    assert 0 <= int(thr[0]) <= 0xFFFFFFFF


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1),
       st.integers(min_value=0, max_value=2**32 - 1),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_fused_predicate_lo_zero_is_legacy_compare(h, thr, x):
    """The model zoo's universal interval predicate with lo = 0 is
    bit-identical to the paper's threshold compare (X ^ h) < thr — the wc
    backward-compatibility contract at the predicate level."""
    from repro.core.sampling import fused_predicate

    hv = np.array([h], dtype=np.uint32)
    tv = np.array([thr], dtype=np.uint32)
    xv = np.array([x], dtype=np.uint32)
    lo = np.zeros(1, dtype=np.uint32)
    legacy = (hv ^ xv) < tv
    np.testing.assert_array_equal(fused_predicate(hv, lo, tv, xv), legacy)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=15),
                          st.integers(min_value=0, max_value=15),
                          st.floats(min_value=0.0, max_value=1.0,
                                    allow_nan=False, width=32)),
                min_size=1, max_size=40))
def test_wc_registry_bit_identical_to_legacy_path(edges):
    """``wc`` through the diffusion registry lowers to exactly the legacy
    per-edge operands: h = edge_hash(src, dst, seed), lo = 0,
    thr = weight_to_threshold(weight) — so every wc sample decision (and
    hence every wc seed set) is byte-identical to the pre-zoo path."""
    from repro.core.sampling import fused_predicate, make_x_vector, sample_mask
    from repro.diffusion import resolve
    from repro.graphs.structs import Graph

    src, dst, w = (np.array(c) for c in zip(*edges))
    g = Graph.from_edges(16, src, dst, w.astype(np.float32), edge_block=8)
    ep = resolve("wc").edge_params(g, seed=3)
    legacy_h = edge_hash(g.src, g.dst, seed=3)
    legacy_thr = weight_to_threshold(g.weight)
    np.testing.assert_array_equal(ep.h, legacy_h)
    np.testing.assert_array_equal(ep.thr, legacy_thr)
    assert not ep.lo.any()
    x = make_x_vector(16, seed=1)
    np.testing.assert_array_equal(
        fused_predicate(ep.h[:, None], ep.lo[:, None], ep.thr[:, None], x[None, :]),
        sample_mask(legacy_h, legacy_thr, x))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                min_size=8, max_size=64, unique=True))
def test_partition_preserves_sample_multiset(xs):
    """FASST is a permutation of the sample space: the multiset of sampled
    graphs is invariant (paper §4.1)."""
    from repro.core.fasst import partition_samples

    x = np.array(xs[: len(xs) // 4 * 4], dtype=np.uint32)
    if x.size == 0:
        return
    shards, perm = partition_samples(x, 4, method="fasst")
    assert sorted(shards.reshape(-1).tolist()) == sorted(x.tolist())
    np.testing.assert_array_equal(x[perm], shards.reshape(-1))


# ---------------------------------------------------------------------------
# Repair equivalence (ISSUE 5): serial shard repair == full rebuild for every
# (diffusion model, partition strategy); plus mesh repair == both, under the
# AxisType guard — the mesh half executes in the test-jax-latest CI job
# (8 fake devices), where this property is the bitwise acceptance gate.
# ---------------------------------------------------------------------------

_REPAIR_MODELS = ["wc", "ic:0.2", "dic:0.5"]   # lt rebuilds by design
_REPAIR_STRATEGIES = ["block", "degree", "edge", "random"]
_REPAIR_MU_V = 4


def _mesh_repair_ready():
    from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

    if not JAX_HAS_AXIS_TYPE:
        return False
    import jax

    return len(jax.devices()) >= _REPAIR_MU_V


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=len(_REPAIR_MODELS) - 1),
       st.integers(min_value=0, max_value=len(_REPAIR_STRATEGIES) - 1),
       st.lists(st.tuples(st.integers(min_value=0, max_value=95),
                          st.integers(min_value=0, max_value=95)),
                min_size=1, max_size=8),
       st.integers(min_value=0, max_value=5))
def test_repair_plan_shards_equals_rebuild_all_backends(mi, si, adds, seed):
    """Property: for a random insertion delta, frontier-restricted shard
    repair (serial — and mesh, when it can run here) produces a matrix
    bitwise equal to a pristine full rebuild, across every context-free
    diffusion model and every partition strategy. ``lt`` is excluded: its
    interval renormalization makes insertion repair unsound, so apply_delta
    rebuilds instead (covered by tests/test_diffusion.py)."""
    from repro.core.difuser import DiFuserConfig
    from repro.graphs import rmat_graph
    from repro.graphs.structs import GraphDelta
    from repro.partition import plan_partition
    from repro.service import SketchStore, apply_delta

    model = _REPAIR_MODELS[mi]
    strategy = _REPAIR_STRATEGIES[si]
    g = rmat_graph(6, edge_factor=5, seed=seed, setting="w1")
    cfg = DiFuserConfig(num_registers=64, seed=seed, model=model)
    src = np.array([a % g.n for a, _ in adds], dtype=np.int64)
    dst = np.array([b % g.n for _, b in adds], dtype=np.int64)
    keep = src != dst
    if not keep.any():
        return
    delta = GraphDelta.make(add=(src[keep], dst[keep]), default_weight=0.6)

    def repaired_matrix(backend):
        store = SketchStore()
        e = store.get_or_build(g, cfg)
        store.attach_plan(e.key, plan_partition(
            e.graph, _REPAIR_MU_V, mu_s=1, strategy=strategy, x=e.x,
            seed=seed, model=model))
        if backend == "mesh":
            from repro.launch.mesh import make_serving_mesh

            e.place_on_mesh(make_serving_mesh(_REPAIR_MU_V))
        rep = apply_delta(store, e.key, delta, backend=backend)
        assert rep.repair_backend == backend or rep.added == 0
        return np.asarray(store.entry(e.key).matrix)

    serial_m = repaired_matrix("serial")

    store = SketchStore()
    e = store.get_or_build(g, cfg)
    apply_delta(store, e.key, delta)        # historical per-bank repair
    np.testing.assert_array_equal(serial_m, np.asarray(store.entry(e.key).matrix))
    store.rebuild(e.key)                    # pristine rebuild, same graph
    np.testing.assert_array_equal(serial_m, np.asarray(store.entry(e.key).matrix))

    if _mesh_repair_ready():
        np.testing.assert_array_equal(repaired_matrix("mesh"), serial_m)


# ---------------------------------------------------------------------------
# Kernel-config bit-identity (ISSUE 8 + 10): the knobs the autotuner moves
# — scan chunks, cascade chunks, ring local_sweeps, bucket pad_mode, and
# the fused-sweep pair (fuse_sweeps, lane_fill) — are
# performance-only. Seed sets, gains, and the canonical sketch matrix are
# byte-identical across every sampled KernelConfig x diffusion model x
# backend. The mesh twin executes under the AxisType guard (the
# test-jax-latest CI job); its ring consumes the same (local_sweeps,
# pad_mode) knobs through DistributedConfig.
# ---------------------------------------------------------------------------

_TUNE_MODELS = ["wc", "ic:0.2", "lt", "dic:0.5"]
#: RunSpec overrides the tuner could emit (spec_overrides output space);
#: {} is today's defaults — the baseline every other point must match
_TUNE_OVERRIDES = [
    {},
    {"edge_chunk": 7, "cascade_chunk": 7},
    {"edge_chunk": 128, "cascade_chunk": 512},
    {"edge_chunk": 1 << 20},                   # >= m: one unscanned sweep
    {"local_sweeps": 1},
    {"local_sweeps": 2, "pad_mode": "global"},
    # fused_sweep family (ISSUE 10): the local_sweeps prologue through the
    # fused multi-sweep kernel, at full width and at lane fills that slab
    # the 32-register axis evenly (8) and raggedly (24, a non-divisor)
    {"local_sweeps": 2, "fuse_sweeps": True},
    {"local_sweeps": 2, "fuse_sweeps": True, "lane_fill": 8},
    {"local_sweeps": 1, "fuse_sweeps": True, "lane_fill": 24,
     "pad_mode": "global"},
]

_tune_baselines: dict = {}


def _tune_run(model, backend, overrides):
    from repro.graphs import rmat_graph
    from repro.runtime import InfluenceSession, RunSpec

    g = _tune_baselines.setdefault(
        "graph", rmat_graph(6, edge_factor=4, seed=11, setting="w1"))
    spec = RunSpec(num_registers=32, seed=11, model=model, backend=backend,
                   mu_v=2 if backend != "single" else 1,
                   mu_s=2 if backend != "single" else 1,
                   **overrides)
    sess = InfluenceSession(g, spec)
    res = sess.find_seeds(3)
    m, _, _ = sess.build_sketch_matrix()
    return np.asarray(res.seeds), np.asarray(res.est_gains), np.asarray(m)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=len(_TUNE_MODELS) - 1),
       st.integers(min_value=1, max_value=len(_TUNE_OVERRIDES) - 1),
       st.sampled_from(["single", "serial"]))
def test_kernel_config_bit_identity(mi, ci, backend):
    """Property: any tuner-reachable RunSpec override produces seeds, gains,
    and a canonical matrix byte-identical to the hard-coded defaults, for
    every diffusion model on every always-available backend."""
    model = _TUNE_MODELS[mi]
    base_key = (model, backend)
    if base_key not in _tune_baselines:
        _tune_baselines[base_key] = _tune_run(model, backend, {})
    seeds0, gains0, m0 = _tune_baselines[base_key]
    seeds, gains, m = _tune_run(model, backend, _TUNE_OVERRIDES[ci])
    np.testing.assert_array_equal(seeds, seeds0)
    np.testing.assert_array_equal(gains, gains0)
    np.testing.assert_array_equal(m, m0)

    if backend == "serial" and _mesh_repair_ready():
        m_seeds, m_gains, m_m = _tune_run(model, "mesh", _TUNE_OVERRIDES[ci])
        np.testing.assert_array_equal(m_seeds, seeds0)
        np.testing.assert_array_equal(m_gains, gains0)
        np.testing.assert_array_equal(m_m, m0)
