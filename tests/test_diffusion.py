"""Diffusion model zoo: registry semantics, wc backward compatibility,
LT live-edge exclusivity, per-model quality vs the Monte-Carlo oracle,
distributed bucketization, delta soundness, and mixed-model serving."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import influence_score, sample_live_mask
from repro.core.difuser import DiFuserConfig, find_seeds
from repro.core.sampling import (edge_hash, fused_predicate, make_x_vector,
                                 weight_to_threshold)
from repro.diffusion import available_models, resolve
from repro.graphs import erdos_renyi_graph, rmat_graph


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_has_all_four_models():
    assert set(available_models()) >= {"ic", "wc", "lt", "dic"}


def test_resolve_parses_params_and_caches():
    assert resolve("ic").p == 0.1
    assert resolve("ic:0.25").p == 0.25
    assert resolve("dic").decay == 1.0
    assert resolve("dic:0.5").decay == 0.5
    assert resolve("wc") is resolve("wc")  # stateless instances are cached


def test_resolve_rejects_unknown_and_bad_specs():
    with pytest.raises(KeyError):
        resolve("lice")
    with pytest.raises(ValueError):
        resolve("ic:nope")
    with pytest.raises(ValueError):
        resolve("ic:1.5")
    with pytest.raises(TypeError):
        resolve("")
    # parameterless models reject suffixes instead of silently ignoring them
    # (a tolerated "wc:0.5" would fork a duplicate store key)
    with pytest.raises(ValueError):
        resolve("wc:0.5")
    with pytest.raises(ValueError):
        resolve("lt:banana")


# ---------------------------------------------------------------------------
# wc backward compatibility (acceptance: byte-identical to pre-PR find_seeds)
# ---------------------------------------------------------------------------

# captured from the pre-zoo tree at this graph/config (see CHANGES.md):
# rmat_graph(8, edge_factor=8, seed=3, setting="w1"),
# DiFuserConfig(num_registers=256, seed=0), k=8
GOLDEN_SEEDS = [2, 32, 24, 65, 128, 219, 135, 129]
GOLDEN_SCORES = [67.72265625, 69.0234375, 70.34375, 71.66015625,
                 72.9375, 73.9375, 74.9375, 75.9375]


def test_wc_find_seeds_byte_identical_to_pre_zoo_golden():
    g = rmat_graph(8, edge_factor=8, seed=3, setting="w1")
    res = find_seeds(g, 8, DiFuserConfig(num_registers=256, seed=0))
    assert res.seeds.tolist() == GOLDEN_SEEDS
    assert res.scores.tolist() == GOLDEN_SCORES


def test_wc_edge_params_match_legacy_formulas(small_graph):
    ep = resolve("wc").edge_params(small_graph, seed=5)
    np.testing.assert_array_equal(ep.h, edge_hash(small_graph.src,
                                                  small_graph.dst, seed=5))
    np.testing.assert_array_equal(ep.thr, weight_to_threshold(small_graph.weight))
    assert not ep.lo.any()
    # the interval predicate with lo = 0 IS the legacy compare
    x = make_x_vector(64, seed=9)
    legacy = (ep.h[:, None] ^ x[None, :]) < ep.thr[:, None]
    np.testing.assert_array_equal(
        fused_predicate(ep.h[:, None], ep.lo[:, None], ep.thr[:, None],
                        x[None, :]), legacy)


def test_default_config_model_is_wc():
    assert DiFuserConfig().model == "wc"


# ---------------------------------------------------------------------------
# Model preprocessing semantics
# ---------------------------------------------------------------------------


def test_ic_uniform_probability_ignores_weights(small_graph):
    ep = resolve("ic:0.25").edge_params(small_graph, seed=0)
    thr = np.asarray(ep.thr)
    expect = weight_to_threshold(np.full(2, 0.25, np.float32))[0]
    assert (thr[: small_graph.m_real] == expect).all()
    assert (thr[small_graph.m_real:] == 0).all()  # padding stays inert


def test_dic_decay_zero_equals_wc_thresholds(small_graph):
    dic0 = resolve("dic:0.0").edge_params(small_graph, seed=0)
    wc = resolve("wc").edge_params(small_graph, seed=0)
    np.testing.assert_array_equal(dic0.thr, wc.thr)
    np.testing.assert_array_equal(dic0.h, wc.h)
    # positive decay strictly shrinks every real edge's threshold
    dic2 = resolve("dic:2.0").edge_params(small_graph, seed=0)
    real = slice(0, small_graph.m_real)
    assert (dic2.thr[real] <= wc.thr[real]).all()
    assert (dic2.thr[real] < wc.thr[real]).any()


def test_lt_at_most_one_in_edge_per_sample(small_graph):
    mdl = resolve("lt")
    ep = mdl.edge_params(small_graph, seed=4)
    x = make_x_vector(512, seed=3)
    mask = mdl.predicate(ep.h[:, None], ep.lo[:, None], ep.thr[:, None],
                         x[None, :])
    live = np.zeros((small_graph.n_pad, 512), dtype=np.int32)
    np.add.at(live, small_graph.dst[: small_graph.m_real],
              mask[: small_graph.m_real].astype(np.int32))
    assert live.max() <= 1
    # padding edges never fire
    assert not mask[small_graph.m_real:].any()
    # fused marginals match the model's interval widths (hash uniformity)
    lo_f, hi_f = mdl._interval_fractions(small_graph)
    b = (hi_f - lo_f)[: small_graph.m_real]
    got = mask[: small_graph.m_real].mean()
    assert abs(got - b.mean()) < 0.01, (got, b.mean())


def test_lt_mc_mask_exclusive_and_matched(small_graph):
    rng = np.random.default_rng(0)
    live = np.zeros(small_graph.n_pad, dtype=np.int32)
    for _ in range(20):
        m = sample_live_mask(small_graph, "lt", rng)
        per = np.zeros(small_graph.n_pad, dtype=np.int32)
        np.add.at(per, small_graph.dst[: small_graph.m_real], m.astype(np.int32))
        assert per.max() <= 1
        live += per
    assert live[: small_graph.n].sum() > 0


# ---------------------------------------------------------------------------
# Quality vs the per-model Monte-Carlo oracle
# (acceptance: top-k spread within 5% of the mc_oracle estimate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["ic:0.1", "lt", "dic:1.0"])
def test_model_topk_spread_within_5pct_of_oracle(spec):
    g = erdos_renyi_graph(400, avg_degree=20, seed=7, setting="w1")
    res = find_seeds(g, 4, DiFuserConfig(num_registers=2048, seed=1, model=spec))
    oracle = influence_score(g, res.seeds, num_sims=500, rng_seed=11, model=spec)
    rel = abs(float(res.scores[-1]) - oracle) / max(oracle, 1.0)
    assert rel < 0.05, (spec, float(res.scores[-1]), oracle, rel)


def test_lt_pallas_matches_ref_end_to_end():
    g = erdos_renyi_graph(200, avg_degree=10, seed=3, setting="w1")
    ref = find_seeds(g, 3, DiFuserConfig(num_registers=128, seed=2, model="lt"))
    pal = find_seeds(g, 3, DiFuserConfig(num_registers=128, seed=2, model="lt",
                                         impl="pallas"))
    np.testing.assert_array_equal(ref.seeds, pal.seeds)
    np.testing.assert_allclose(ref.scores, pal.scores, rtol=1e-6)


# ---------------------------------------------------------------------------
# Distributed bucketization (serial ring emulation — no mesh needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["wc", "lt"])
def test_bucketized_sweep_matches_single_device(spec):
    """One full 2-D-partition propagate sweep, emulated serially over the
    (mu_v, mu_s) shard grid with the runtime's jnp bucket merge, must be
    bit-identical to the single-device sweep — for threshold AND interval
    models (the lo arrays ride the buckets)."""
    from repro.core.difuser import edge_operands
    from repro.core.distributed import (_bucket_sweep_propagate,
                                        build_partition_2d)
    from repro.kernels import ops

    mu_v, mu_s = 2, 2
    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1").sorted_by_dst()
    cfg = DiFuserConfig(num_registers=128, seed=3, model=spec)
    x = np.sort(make_x_vector(128, seed=3))
    part = build_partition_2d(g, x, mu_v, mu_s, seed=3, model=spec)
    mdl = resolve(spec)

    n_pad, j, j_loc, n_loc = part.n_pad, 128, part.j_loc, part.n_loc
    m0 = ops.sketch_fill(jnp.zeros((n_pad, j), jnp.int8), seed=3)
    m0 = jnp.where((jnp.arange(n_pad) >= g.n)[:, None], jnp.int8(-1), m0)

    # single-device reference sweep (model operands, full edge list)
    src, dst, h, lo, thr = edge_operands(g, cfg)
    ref = ops.propagate_sweep(m0, src, dst, thr, jnp.asarray(x), seed=3,
                              h=h, lo=lo, predicate=mdl.predicate)

    # serial emulation of the ring schedule over all (v, s) shards
    out = np.array(m0)
    for v in range(mu_v):
        rows = slice(v * n_loc, (v + 1) * n_loc)
        for s in range(mu_s):
            cols = slice(s * j_loc, (s + 1) * j_loc)
            acc = jnp.asarray(np.array(m0)[rows, cols])
            m_vs = acc
            for kk in range(mu_v):
                owner = (v + kk) % mu_v
                block = jnp.asarray(
                    np.array(m0)[owner * n_loc:(owner + 1) * n_loc, cols])
                acc = _bucket_sweep_propagate(
                    acc, block, jnp.asarray(part.p_h[kk][v, s]),
                    jnp.asarray(part.p_w[kk][v, s]),
                    jnp.asarray(part.p_r[kk][v, s]),
                    jnp.asarray(part.p_t[kk][v, s]),
                    jnp.asarray(part.x_shards[s]),
                    jnp.asarray(part.p_l[kk][v, s]), mdl.predicate)
            out[rows, cols] = np.where(np.array(m_vs) == -1, np.array(m_vs),
                                       np.array(acc))
    np.testing.assert_array_equal(out[: g.n_pad], np.array(ref)[: g.n_pad])


# ---------------------------------------------------------------------------
# Service layer: mixed-model serving, persistence, delta soundness
# ---------------------------------------------------------------------------


def test_mixed_model_engine_serves_distinct_keys(small_graph):
    from repro.service import InfluenceEngine, SpreadEstimate, TopKSeeds

    engine = InfluenceEngine()
    specs = ("wc", "ic:0.1", "lt", "dic:1.0")
    keys = {}
    for spec in specs:
        cfg = DiFuserConfig(num_registers=128, seed=0, model=spec)
        keys[spec] = engine.register(small_graph, cfg)
    assert len(set(keys.values())) == len(specs)
    assert keys["wc"].model == "wc" and keys["lt"].model == "lt"

    for spec in specs:
        engine.submit(keys[spec], TopKSeeds(4))
        engine.submit(keys[spec], SpreadEstimate([1, 2, 3]))
    results = engine.run()
    assert len(results) == 2 * len(specs)
    # warm top-k through each model's store entry == that model's cold run
    for i, spec in enumerate(specs):
        cold = find_seeds(small_graph, 4,
                          DiFuserConfig(num_registers=128, seed=0, model=spec))
        np.testing.assert_array_equal(results[2 * i].value.seeds, cold.seeds)
    # the models genuinely disagree somewhere (distinct indexes, not aliases)
    seed_sets = {tuple(results[2 * i].value.seeds.tolist())
                 for i in range(len(specs))}
    assert len(seed_sets) > 1


def test_engine_rejects_unregistered_key_at_submit(small_graph):
    """A typo'd/unregistered key must fail at submit — not as a KeyError
    mid-run that drops the whole already-dequeued batch."""
    import dataclasses

    from repro.service import InfluenceEngine, TopKSeeds

    engine = InfluenceEngine()
    key = engine.register(small_graph, DiFuserConfig(num_registers=64, seed=0))
    engine.submit(key, TopKSeeds(2))
    bogus = dataclasses.replace(key, model="ic:0.1")  # never registered
    with pytest.raises(KeyError):
        engine.submit(bogus, TopKSeeds(2))
    results = engine.run()  # the valid request survives
    assert len(results) == 1 and results[0].value.seeds.shape == (2,)


def test_store_npz_roundtrip_carries_model(tmp_path, small_graph):
    from repro.service import SketchStore

    cfg = DiFuserConfig(num_registers=64, seed=1, model="dic:0.5")
    store = SketchStore()
    entry = store.get_or_build(small_graph, cfg)
    p = str(tmp_path / "idx")
    store.save(p, entry.key)
    fresh = SketchStore()
    loaded = fresh.load(p)
    assert loaded.cfg.model == "dic:0.5"
    assert loaded.key == entry.key
    np.testing.assert_array_equal(np.asarray(loaded.matrix),
                                  np.asarray(entry.matrix))


def test_store_legacy_npz_rekeyed_as_wc(tmp_path, small_graph):
    """Snapshots written before the model zoo carry no ``model`` field and
    must load re-keyed under the backward-compatible wc default."""
    from repro.service import SketchStore

    cfg = DiFuserConfig(num_registers=64, seed=1)
    store = SketchStore()
    entry = store.get_or_build(small_graph, cfg)
    p = str(tmp_path / "idx.npz")
    store.save(p, entry.key)
    z = dict(np.load(p))
    del z["model"]  # simulate a pre-zoo snapshot
    np.savez_compressed(p, **z)
    loaded = SketchStore().load(p)
    assert loaded.cfg.model == "wc"
    assert loaded.key == entry.key


def test_delta_insertions_rebuild_for_lt(small_graph):
    """lt insertions re-normalize sibling intervals — the monotone repair is
    unsound, so apply_delta must take the rebuild path (and stay on the
    repair path for wc)."""
    from repro.graphs.structs import GraphDelta
    from repro.service import SketchStore, apply_delta

    rng = np.random.default_rng(2)
    delta = GraphDelta.make(add=(rng.integers(0, small_graph.n, 16),
                                 rng.integers(0, small_graph.n, 16)))
    for spec, expect_rebuild in (("lt", True), ("wc", False)):
        store = SketchStore()
        cfg = DiFuserConfig(num_registers=64, seed=0, model=spec)
        entry = store.get_or_build(small_graph, cfg)
        report = apply_delta(store, entry.key, delta)
        assert report.rebuilt is expect_rebuild, spec
        # post-delta index == pristine rebuild of the post-delta graph
        post = store.entry(entry.key)
        ref_store = SketchStore()
        ref = ref_store.get_or_build(post.graph, cfg)
        np.testing.assert_array_equal(np.asarray(post.matrix),
                                      np.asarray(ref.matrix))


def test_delta_removals_rebuild_for_lt(small_graph):
    """lt removals widen sibling intervals, so the stale matrix is not even
    a sound over-approximation — any removal must rebuild immediately
    (wc keeps the cheap staleness path below the threshold)."""
    from repro.graphs.structs import GraphDelta
    from repro.service import SketchStore, apply_delta

    rem = (small_graph.src[:4].astype(np.int64),
           small_graph.dst[:4].astype(np.int64))
    delta = GraphDelta.make(remove=rem)
    for spec, expect_rebuild in (("lt", True), ("wc", False)):
        store = SketchStore()
        cfg = DiFuserConfig(num_registers=64, seed=0, model=spec)
        entry = store.get_or_build(small_graph, cfg)
        report = apply_delta(store, entry.key, delta)
        assert report.removed > 0
        assert report.rebuilt is expect_rebuild, spec
        post = store.entry(entry.key)
        if expect_rebuild:
            assert not post.stale
            ref = SketchStore().get_or_build(post.graph, cfg)
            np.testing.assert_array_equal(np.asarray(post.matrix),
                                          np.asarray(ref.matrix))
        else:
            assert post.stale  # wc: sound over-estimate until lazy rebuild


def test_workload_presets_cover_every_model():
    from repro.configs.difuser_workloads import PRESETS

    zoo_models = {PRESETS[n].model.partition(":")[0]
                  for n in PRESETS if n.startswith("zoo-")}
    assert zoo_models == {"ic", "wc", "lt", "dic"}
    # non-zoo presets keep the backward-compatible default
    assert PRESETS["livejournal-like"].model == "wc"
