"""Propagation/cascade vs an independent per-simulation BFS oracle.

For every simulation j we materialize the sampled edge set with the SAME
hash/X values, BFS the reachability sets in python, and check:
  * fixpoint registers == max clz over the true reachable set (exact), and
  * cascade visited == true BFS closure of the seed set (exact).
"""
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import cascade_from_seed
from repro.core.sampling import (clz32, edge_hash, make_x_vector,
                                 register_hash, weight_to_threshold)
from repro.core.simulate import propagate_to_fixpoint
from repro.kernels import ops


def _sampled_adj(g, x):
    """bool[m, R] host-side masks + adjacency lists per sim."""
    h = edge_hash(g.src[: g.m_real], g.dst[: g.m_real])
    thr = weight_to_threshold(g.weight[: g.m_real])
    return (h[:, None] ^ x[None, :]) < thr[:, None]


def _bfs_reach(g, mask_col):
    """list[set]: reach set for every vertex under one sampled edge set."""
    n = g.n
    adj = [[] for _ in range(n)]
    for e in np.nonzero(mask_col)[0]:
        adj[g.src[e]].append(int(g.dst[e]))
    reach = []
    for v in range(n):
        seen = {v}
        stack = [v]
        while stack:
            u = stack.pop()
            for w in adj[u]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        reach.append(seen)
    return reach


def test_fixpoint_equals_bfs_oracle(small_graph):
    g = small_graph
    regs = 32
    x = make_x_vector(regs, seed=21)
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, regs), jnp.int8))
    m, iters = propagate_to_fixpoint(
        m0, jnp.asarray(g.src), jnp.asarray(g.dst),
        jnp.asarray(weight_to_threshold(g.weight)), jnp.asarray(x), max_iters=64)
    m = np.asarray(m)
    assert int(iters) < 64, "did not converge"

    masks = _sampled_adj(g, x)
    j_ids = np.arange(regs, dtype=np.uint32)
    for j in (0, 7, 31):
        reach = _bfs_reach(g, masks[:, j])
        for v in (0, 3, g.n // 2, g.n - 1):
            members = np.fromiter(reach[v], dtype=np.uint32)
            expect = int(clz32(register_hash(members, np.uint32(j))).max())
            assert m[v, j] == expect, (v, j, m[v, j], expect)


def test_cascade_equals_bfs_closure(small_graph):
    g = small_graph
    regs = 32
    seed_vertex = 3
    x = make_x_vector(regs, seed=22)
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, regs), jnp.int8))
    m, _ = cascade_from_seed(
        m0, jnp.int32(seed_vertex), jnp.asarray(g.src), jnp.asarray(g.dst),
        jnp.asarray(weight_to_threshold(g.weight)), jnp.asarray(x), max_iters=64)
    m = np.asarray(m)

    masks = _sampled_adj(g, x)
    for j in (0, 5, 19, 31):
        reach = _bfs_reach(g, masks[:, j])[seed_vertex]
        visited = set(np.nonzero(m[: g.n, j] == -1)[0].tolist())
        assert visited == reach, (j, visited ^ reach)


def test_cascade_monotone_scores(small_graph):
    """Adding seeds never decreases the visited count."""
    g = small_graph
    regs = 64
    x = jnp.asarray(make_x_vector(regs, seed=23))
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    thr = jnp.asarray(weight_to_threshold(g.weight))
    m = ops.sketch_fill(jnp.zeros((g.n_pad, regs), jnp.int8))
    prev = 0
    for s in (1, 10, 50, 100):
        m, _ = cascade_from_seed(m, jnp.int32(s), src, dst, thr, x)
        cur = int((np.asarray(m[: g.n]) == -1).sum())
        assert cur >= prev
        prev = cur
