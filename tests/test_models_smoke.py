"""Per-arch smoke tests (assignment deliverable f): every assigned
architecture instantiates a REDUCED config of the same family and runs one
forward + one train step + one decode step on CPU, asserting output shapes
and finiteness. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.train import DataConfig, TrainConfig, make_optimizer, make_train_step, synthetic_batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dcfg = DataConfig(batch=2, seq=16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, dcfg, 0).items()}

    # forward
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = batch["enc_embeds"]
    if cfg.family == "vlm":
        kw["prefix_embeds"] = batch["patch_embeds"]
    logits = forward(params, batch["tokens"], cfg, **kw)
    s_total = 16 + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    # one train step
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, TrainConfig()))
    params2, state2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved

    # one decode step
    cache = init_cache(cfg, 2, 24, enc_len=16)
    tok_logits, cache2 = decode_step(params, batch["tokens"][:, 0], cache,
                                     jnp.int32(0), cfg)
    assert tok_logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(tok_logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_parameter_count(arch):
    """Analytic param counts of the FULL configs land in the advertised
    ballpark (catches config typos without allocating anything)."""
    from repro.configs import get_config

    expected_b = {
        "deepseek-moe-16b": (14, 20), "grok-1-314b": (280, 340),
        "yi-34b": (30, 38), "h2o-danube-3-4b": (3, 5),
        "tinyllama-1.1b": (0.9, 1.4), "qwen1.5-4b": (3, 5),
        "zamba2-1.2b": (0.9, 1.6), "whisper-medium": (0.85, 1.15),  # SwiGLU MLPs (+~30% vs GELU original)
        "mamba2-780m": (0.6, 1.0), "internvl2-26b": (19, 27),
    }
    lo, hi = expected_b[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params out of [{lo}, {hi}]B"
