"""Partition planner: golden bit-compat, balance, and results-invariance.

The contract under test (ISSUE 3 acceptance):

  * ``block`` + ``pad_mode="global"`` reproduces the pre-planner partition
    bit-identically (golden copy of the legacy builder below);
  * ``degree`` / ``edge`` cut the measured max/mean edge imbalance >= 2x on
    a skewed RMAT graph (the partition_balance benchmark regime);
  * seed sets and spread estimates are IDENTICAL across all planners and
    under arbitrary random vertex relabeling, for every registered
    diffusion model (serial-ring executor — no mesh needed);
  * the service store remembers plans (persistence included) and deltas
    permute through them.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.difuser import DiFuserConfig, find_seeds
from repro.core.sampling import make_x_vector
from repro.graphs import rmat_graph
from repro.partition import (PartitionPlan, available_strategies,
                             build_partition_2d, find_seeds_ring_serial,
                             plan_partition)


def _skewed_graph(scale=9):
    return rmat_graph(scale, edge_factor=8, a=0.65, b=0.15, c=0.15, seed=71,
                      setting="w1", permute_ids=False).sorted_by_dst()


# ---------------------------------------------------------------------------
# Golden: the pre-planner host build, copied verbatim (contiguous block
# assignment, one global b_max). block+global must reproduce it bit-for-bit.
# ---------------------------------------------------------------------------


def _legacy_bucketize(ids, w_own, k, eh, wrow, rrow, thr, elo, mu_v, b_max):
    h_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)
    w_out = np.zeros((mu_v, mu_v, b_max), dtype=np.int32)
    r_out = np.zeros((mu_v, mu_v, b_max), dtype=np.int32)
    t_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)
    l_out = np.zeros((mu_v, mu_v, b_max), dtype=np.uint32)
    order = np.lexsort((ids, k, w_own))
    w_s, k_s = w_own[order], k[order]
    eh_s, wr_s, rr_s, th_s, lo_s = (eh[order], wrow[order], rrow[order],
                                    thr[order], elo[order])
    keys = w_s.astype(np.int64) * mu_v + k_s
    boundaries = np.searchsorted(keys, np.arange(mu_v * mu_v + 1))
    for b in range(mu_v * mu_v):
        lo, hi = boundaries[b], boundaries[b + 1]
        if hi == lo:
            continue
        v, kk = divmod(b, mu_v)
        cnt = hi - lo
        h_out[v, kk, :cnt] = eh_s[lo:hi]
        w_out[v, kk, :cnt] = wr_s[lo:hi]
        r_out[v, kk, :cnt] = rr_s[lo:hi]
        t_out[v, kk, :cnt] = th_s[lo:hi]
        l_out[v, kk, :cnt] = lo_s[lo:hi]
    return h_out, w_out, r_out, t_out, l_out


def _legacy_build(g, x, mu_v, mu_s, *, seed=0, edge_block=256, model="wc"):
    from repro.core.difuser import resolve_model
    from repro.core.fasst import _sampled_by_any, partition_samples

    x_shards, _ = partition_samples(x, mu_s, method="fasst")
    n_pad = g.n_pad + ((-g.n_pad) % mu_v)
    n_loc = n_pad // mu_v
    mdl = resolve_model(model)
    ep = mdl.edge_params(g, seed=seed)
    eh_all, lo_all, thr_all = ep.h, ep.lo, ep.thr
    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    own_src = (src // n_loc).astype(np.int32)
    own_dst = (dst // n_loc).astype(np.int32)
    p_parts, c_parts = [], []
    bp_sizes, bc_sizes = [], []
    masks = [np.nonzero(_sampled_by_any(eh_all, thr_all, x_shards[s], lo=lo_all,
                                        predicate=mdl.predicate))[0]
             for s in range(mu_s)]
    for s in range(mu_s):
        ids = masks[s]
        kp = (own_dst[ids] - own_src[ids]) % mu_v
        kc = (own_src[ids] - own_dst[ids]) % mu_v
        bp = np.bincount(own_src[ids].astype(np.int64) * mu_v + kp, minlength=mu_v * mu_v)
        bc = np.bincount(own_dst[ids].astype(np.int64) * mu_v + kc, minlength=mu_v * mu_v)
        bp_sizes.append(bp.max() if bp.size else 0)
        bc_sizes.append(bc.max() if bc.size else 0)
    b_max = int(max(max(bp_sizes), max(bc_sizes), 1))
    b_max += (-b_max) % edge_block
    for s in range(mu_s):
        ids = masks[s]
        e_h, e_t, e_l = eh_all[ids], thr_all[ids], lo_all[ids]
        wsrc, wdst = own_src[ids], own_dst[ids]
        kp = (wdst - wsrc) % mu_v
        kc = (wsrc - wdst) % mu_v
        src_loc = (src[ids] % n_loc).astype(np.int32)
        dst_loc = (dst[ids] % n_loc).astype(np.int32)
        p_parts.append(_legacy_bucketize(ids, wsrc, kp, e_h, src_loc, dst_loc,
                                         e_t, e_l, mu_v, b_max))
        c_parts.append(_legacy_bucketize(ids, wdst, kc, e_h, dst_loc, src_loc,
                                         e_t, e_l, mu_v, b_max))

    def stack(parts, i):
        return np.stack([p[i] for p in parts], axis=1)  # (mu_v, mu_s, mu_v, B)

    return {name: stack(parts, i)
            for parts, fields in ((p_parts, ("p_h", "p_w", "p_r", "p_t", "p_l")),
                                  (c_parts, ("c_h", "c_w", "c_r", "c_t", "c_l")))
            for i, name in enumerate(fields)}


@pytest.mark.parametrize("model", ["wc", "lt"])
def test_block_global_bit_identical_to_legacy(model):
    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1").sorted_by_dst()
    x = make_x_vector(128, seed=3)
    golden = _legacy_build(g, x, 2, 2, seed=3, model=model)
    part = build_partition_2d(g, x, 2, 2, seed=3, model=model,
                              pad_mode="global")
    assert part.plan.strategy == "block"
    np.testing.assert_array_equal(part.plan.perm, np.arange(part.n_pad))
    for name in golden:
        # new layout: per-step tuple of (mu_v, mu_s, B); stack to legacy 4-D
        got = np.stack(getattr(part, name), axis=2)
        np.testing.assert_array_equal(got, golden[name], err_msg=name)


# ---------------------------------------------------------------------------
# Planner validity + balance
# ---------------------------------------------------------------------------


def test_all_strategies_produce_valid_permutations():
    g = _skewed_graph(8)
    x = make_x_vector(128, seed=5)
    for strat in available_strategies():
        plan = plan_partition(g, 4, mu_s=2, strategy=strat, x=x, seed=5)
        assert np.array_equal(np.sort(plan.perm), np.arange(plan.n_pad)), strat
        assert np.array_equal(plan.perm[plan.inv_perm],
                              np.arange(plan.n_pad)), strat
        # every shard owns exactly n_loc rows
        owners = plan.perm[: g.n] // plan.n_loc
        assert np.bincount(owners, minlength=4).max() <= plan.n_loc, strat
        assert plan.owned_ids().shape == (4, plan.n_loc), strat


def test_degree_and_edge_cut_block_imbalance_2x():
    """The ISSUE acceptance bar, at the partition_balance benchmark's fast
    config: skewed RMAT, mu_v=8 — degree/edge must at least halve block's
    measured max/mean edge imbalance."""
    g = _skewed_graph(9)
    x = make_x_vector(128, seed=7)
    imb = {}
    for strat in ("block", "degree", "edge"):
        plan = plan_partition(g, 8, mu_s=1, strategy=strat, x=x, seed=7)
        part = build_partition_2d(g, x, 8, 1, seed=7, plan=plan)
        imb[strat] = part.stats().edge_imbalance
    assert imb["block"] >= 2.0 * imb["degree"], imb
    assert imb["block"] >= 2.0 * imb["edge"], imb


def test_per_step_padding_wastes_no_more_than_global():
    g = _skewed_graph(8)
    x = make_x_vector(128, seed=7)
    step = build_partition_2d(g, x, 4, 1, seed=7, pad_mode="step")
    glob = build_partition_2d(g, x, 4, 1, seed=7, pad_mode="global")
    assert step.stats().pad_waste_frac <= glob.stats().pad_waste_frac
    # identical real contents: per-step arrays truncate to the same buckets
    for kk in range(4):
        for v in range(4):
            cnt = int(step.p_counts[v, 0, kk])
            np.testing.assert_array_equal(step.p_h[kk][v, 0][:cnt],
                                          glob.p_h[kk][v, 0][:cnt])
            assert not step.p_t[kk][v, 0][cnt:].any()  # padding is inert


# ---------------------------------------------------------------------------
# Results invariance (the load-bearing property): same seeds, same
# estimates, across every planner and any relabeling, for every model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["wc", "ic:0.1", "lt", "dic:1.0"])
def test_serial_ring_invariant_across_planners_all_models(model):
    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1")
    cfg = DiFuserConfig(num_registers=128, seed=3, model=model)
    single = find_seeds(g, 3, cfg)

    g_sorted = g.sorted_by_dst()
    x = np.sort(make_x_vector(128, seed=3)).astype(np.uint32)
    n_pad = g_sorted.n_pad + ((-g_sorted.n_pad) % 2)
    rng = np.random.default_rng(42)
    plans = {strat: plan_partition(g_sorted, 2, mu_s=2, strategy=strat, x=x,
                                   seed=3, model=model)
             for strat in ("block", "degree", "edge")}
    # arbitrary random relabeling — not even a registered strategy
    plans["relabel"] = PartitionPlan.from_permutation(
        g.n, 2, 2, rng.permutation(n_pad).astype(np.int32))

    ref = None
    for name, plan in plans.items():
        res, _ = find_seeds_ring_serial(g, 3, cfg, mu_v=2, mu_s=2, plan=plan)
        if ref is None:
            ref = res
            # the ring schedule must agree with the single-device run
            np.testing.assert_array_equal(res.seeds, single.seeds)
            np.testing.assert_allclose(res.scores, single.scores, rtol=1e-5)
        else:
            np.testing.assert_array_equal(res.seeds, ref.seeds, err_msg=name)
            np.testing.assert_array_equal(res.scores, ref.scores, err_msg=name)
            np.testing.assert_array_equal(res.est_gains, ref.est_gains,
                                          err_msg=name)
            np.testing.assert_array_equal(res.rebuilds, ref.rebuilds,
                                          err_msg=name)


# ---------------------------------------------------------------------------
# Service-layer threading: plans on store entries, deltas permute through
# ---------------------------------------------------------------------------


@pytest.fixture()
def store_entry():
    from repro.service import SketchStore

    g = rmat_graph(7, edge_factor=6, seed=4, setting="w1")
    cfg = DiFuserConfig(num_registers=128, seed=1)
    store = SketchStore()
    entry = store.get_or_build(g, cfg)
    return store, entry


def test_store_attach_plan_and_planned_matrix(store_entry):
    store, entry = store_entry
    plan = plan_partition(entry.graph, 4, mu_s=1, strategy="degree",
                          x=entry.x, seed=1)
    store.attach_plan(entry.key, plan)
    pm = np.asarray(entry.planned_matrix())
    m = np.asarray(entry.matrix)
    assert pm.shape[0] == plan.n_pad
    # row i of the planned layout is the original row inv_perm[i]
    pad = np.full((plan.n_pad - m.shape[0], m.shape[1]), -1, dtype=m.dtype)
    np.testing.assert_array_equal(pm, np.concatenate([m, pad])[plan.inv_perm])


def test_store_plan_survives_save_load(store_entry, tmp_path):
    from repro.service import SketchStore

    store, entry = store_entry
    plan = plan_partition(entry.graph, 4, mu_s=1, strategy="edge",
                          x=entry.x, seed=1)
    store.attach_plan(entry.key, plan)
    path = str(tmp_path / "idx")
    store.save(path, entry.key)
    other = SketchStore()
    loaded = other.load(path)
    assert loaded.plan is not None
    assert loaded.plan.strategy == "edge"
    np.testing.assert_array_equal(loaded.plan.perm, plan.perm)
    np.testing.assert_array_equal(np.asarray(loaded.planned_matrix()),
                                  np.asarray(entry.planned_matrix()))


def test_delta_reports_plan_shards_touched(store_entry):
    from repro.graphs.structs import GraphDelta
    from repro.service import apply_delta

    store, entry = store_entry
    plan = plan_partition(entry.graph, 4, mu_s=1, strategy="degree",
                          x=entry.x, seed=1)
    store.attach_plan(entry.key, plan)
    u, v = 3, 97
    delta = GraphDelta.make(add=([u], [v], [0.9]))
    report = apply_delta(store, entry.key, delta)
    expect = tuple(np.unique(plan.owner_of(np.array([u, v]))).tolist())
    assert report.plan_shards_touched == expect
    # plan survives the delta; planned_matrix tracks the repaired matrix
    assert entry.plan is plan
    pm = np.asarray(entry.planned_matrix())
    assert pm.shape[0] == plan.n_pad


def test_delta_without_plan_reports_empty(store_entry):
    from repro.graphs.structs import GraphDelta
    from repro.service import apply_delta

    store, entry = store_entry
    report = apply_delta(store, entry.key, GraphDelta.make(add=([1], [2], [0.5])))
    assert report.plan_shards_touched == ()


# ---------------------------------------------------------------------------
# ROADMAP cleanups that ride along
# ---------------------------------------------------------------------------


def test_build_banks_hoists_edge_operands(monkeypatch):
    """num_banks > 1 must run the O(m) model preprocessing exactly once."""
    import repro.service.store as store_mod

    calls = {"n": 0}
    real = store_mod.edge_operands

    def counting(g, cfg):
        calls["n"] += 1
        return real(g, cfg)

    monkeypatch.setattr(store_mod, "edge_operands", counting)
    g = rmat_graph(7, edge_factor=6, seed=4, setting="w1")
    store = store_mod.SketchStore(num_banks=4)
    entry = store.get_or_build(g, DiFuserConfig(num_registers=128, seed=1))
    assert calls["n"] == 1
    # and the build primed the serving cache: device_edges is free
    entry.device_edges()
    assert calls["n"] == 1


def test_prime_edges_cache_tracks_version(store_entry):
    _, entry = store_entry
    edges = entry.device_edges()
    assert entry.device_edges() is edges  # cached
    entry.version += 1
    assert entry.device_edges() is not edges  # recomputed on version bump
