"""Observability layer: spans, recorder, Chrome export, metrics, drivers.

The recorder and registry are process-global, so every test that turns them
on restores the disabled/empty state in a finally block — the rest of the
suite must keep seeing the zero-overhead null path.
"""
import json

import numpy as np
import pytest

from repro.obs import metrics, trace


@pytest.fixture
def recorder():
    rec = trace.get_recorder()
    rec.start()
    try:
        yield rec
    finally:
        rec.stop()
        rec.clear()


# ---------------------------------------------------------------------------
# trace: spans + recorder
# ---------------------------------------------------------------------------


def test_disabled_span_is_shared_null_singleton():
    """With the recorder off, span() allocates nothing: every call returns
    the same null object, and nothing is recorded."""
    rec = trace.get_recorder()
    assert not rec.enabled
    s1 = trace.span("a", phase="build")
    s2 = trace.span("b", x=1)
    assert s1 is s2
    with s1 as sp:
        assert sp.sync(123) == 123
        sp.annotate(ignored=True)
    assert sp.duration_s == 0.0
    assert rec.events() == []


def test_timed_span_measures_while_disabled():
    """timed=True callers (engine latency accounting, benchmarks) get real
    wall time regardless of tracing — but still record nothing."""
    rec = trace.get_recorder()
    assert not rec.enabled
    with trace.span("work", phase="query", timed=True) as sp:
        sum(range(1000))
    assert sp.duration_s > 0.0
    assert rec.events() == []


def test_span_nesting_depth_and_phase_inheritance(recorder):
    with trace.span("outer", phase="build"):
        with trace.span("inner"):          # no phase -> inherits "build"
            with trace.span("leaf", phase="query"):
                pass
    evs = {e["name"]: e for e in recorder.events()}
    assert evs["outer"]["depth"] == 0 and evs["outer"]["phase"] == "build"
    assert evs["inner"]["depth"] == 1 and evs["inner"]["phase"] == "build"
    assert evs["leaf"]["depth"] == 2 and evs["leaf"]["phase"] == "query"
    # children complete before parents; timestamps nest inside the parent
    names = [e["name"] for e in recorder.events()]
    assert names == ["leaf", "inner", "outer"]
    assert evs["outer"]["ts_s"] <= evs["inner"]["ts_s"]
    assert (evs["inner"]["ts_s"] + evs["inner"]["dur_s"]
            <= evs["outer"]["ts_s"] + evs["outer"]["dur_s"] + 1e-9)


def test_span_sync_blocks_jax_outputs(recorder):
    jnp = pytest.importorskip("jax.numpy")
    with trace.span("device_work", phase="build") as sp:
        out = sp.sync(jnp.arange(512) * 2)
    assert int(np.asarray(out)[-1]) == 1022
    (ev,) = recorder.events()
    assert ev["name"] == "device_work" and ev["dur_s"] > 0


def test_top_level_seconds_counts_only_depth_zero(recorder):
    with trace.span("a", phase="build"):
        with trace.span("a.child"):
            pass
    with trace.span("b", phase="query"):
        pass
    evs = recorder.events()
    expect = sum(e["dur_s"] for e in evs if e["depth"] == 0)
    assert recorder.top_level_seconds() == pytest.approx(expect)
    assert recorder.phases_seen() == {"build", "query"}


def test_chrome_trace_schema_and_lanes(recorder):
    """The export is valid Chrome trace-event JSON (what Perfetto loads):
    a traceEvents list of M metadata + X complete events, one tid lane per
    phase, ts/dur in microseconds."""
    with trace.span("plan_it", phase="plan", n=64):
        pass
    with trace.span("query_it", phase="query"):
        pass
    doc = json.loads(json.dumps(recorder.chrome_trace()))   # JSON-clean
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert all(e["ph"] in ("M", "X") for e in events)
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"plan", "query"}
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"plan_it", "query_it"}
    by_name = {e["name"]: e for e in xs}
    assert by_name["plan_it"]["tid"] == trace.PHASES.index("plan")
    assert by_name["query_it"]["tid"] == trace.PHASES.index("query")
    assert by_name["plan_it"]["args"]["n"] == 64
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["cat"] in trace.PHASES


def test_save_chrome_trace_roundtrip(tmp_path, recorder):
    with trace.span("one", phase="build"):
        pass
    path = tmp_path / "trace.json"
    n = recorder.save_chrome_trace(str(path))
    assert n == 1
    doc = json.loads(path.read_text())
    assert any(e["ph"] == "X" and e["name"] == "one"
               for e in doc["traceEvents"])


def test_traced_decorator(recorder):
    @trace.traced("deco.region", phase="repair")
    def work(a, b):
        return a + b

    assert work(2, 3) == 5
    (ev,) = recorder.events()
    assert ev["name"] == "deco.region" and ev["phase"] == "repair"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_counter_gauge_get_or_create():
    reg = metrics.MetricsRegistry()
    c = reg.counter("hits", path="warm")
    c.inc()
    c.inc(4)
    assert reg.counter("hits", path="warm") is c and c.value == 5
    assert reg.counter("hits", path="cold") is not c
    g = reg.gauge("resident")
    g.set(3)
    assert reg.gauge("resident").value == 3.0
    with pytest.raises(TypeError):
        reg.gauge("hits", path="warm")    # kind mismatch on same name+tags


def test_histogram_percentiles_match_numpy():
    """Streaming (geometric-bucket) percentiles land within the bucket
    resolution (~2% relative) of numpy's exact order statistics."""
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-3.0, sigma=1.2, size=20_000)
    h = metrics.Histogram(unit="s")
    for v in samples:
        h.observe(v)
    for q in (50, 95, 99):
        exact = float(np.percentile(samples, q))
        assert h.percentile(q) == pytest.approx(exact, rel=0.05), q
    assert h.count == len(samples)
    assert h.min == pytest.approx(samples.min())
    assert h.max == pytest.approx(samples.max())
    assert h.mean == pytest.approx(samples.mean(), rel=1e-6)
    assert h.percentile(0) == h.min and h.percentile(100) == h.max


def test_histogram_edge_cases():
    h = metrics.Histogram()
    assert h.percentile(50) == 0.0          # empty
    h.observe(0.0)
    h.observe(-1.0)                          # underflow bucket
    h.observe(2.5)
    s = h.summary()
    assert s["count"] == 3 and s["min"] == -1.0 and s["max"] == 2.5
    assert 0.0 <= s["p50"] <= 2.5


def test_snapshot_jsonl_roundtrip(tmp_path):
    reg = metrics.MetricsRegistry()
    reg.counter("events", kind="delta").inc(3)
    reg.gauge("frac").set(0.25)
    reg.histogram("lat", unit="s").observe(0.01)
    path = tmp_path / "metrics.jsonl"
    assert reg.write_jsonl(str(path)) == 3
    rows = metrics.load_jsonl(str(path))
    by_name = {(r["name"], tuple(sorted(r["tags"].items()))): r for r in rows}
    assert by_name[("events", (("kind", "delta"),))]["value"] == 3
    assert by_name[("frac", ())]["value"] == 0.25
    lat = by_name[("lat", ())]
    assert lat["kind"] == "histogram" and lat["unit"] == "s"
    assert lat["count"] == 1 and lat["p99"] > 0


# ---------------------------------------------------------------------------
# end-to-end: the serve driver under --trace/--metrics
# ---------------------------------------------------------------------------


def test_serve_im_trace_covers_build_and_query(tmp_path):
    """Smoke the serving driver with tracing on: the written artifact is
    Perfetto-loadable Chrome JSON whose lanes cover the build and query
    phases of the run."""
    from repro.launch.serve_im import run

    trace_path = tmp_path / "serve_trace.json"
    metrics_path = tmp_path / "serve_metrics.jsonl"
    try:
        out = run(["--graph", "rmat:7", "--registers", "64", "--queries",
                   "20", "--topk", "4", "--trace", str(trace_path),
                   "--metrics", str(metrics_path)])
    finally:
        rec = trace.get_recorder()
        rec.stop()
        rec.clear()
    assert out["num_queries"] == 20
    doc = json.loads(trace_path.read_text())
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"build", "query"} <= lanes, lanes
    assert any(e["ph"] == "X" for e in doc["traceEvents"])
    rows = metrics.load_jsonl(str(metrics_path))
    names = {r["name"] for r in rows}
    assert "store.bank_build_s" in names
    assert "engine.requests" in names
