"""FM/HLL sketch state + estimator tests (paper §2.3, §3.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch
from repro.core.sketch import (VISITED, estimate_cardinality,
                               estimate_from_sums, exact_distinct_reference,
                               fill_registers, merge, partial_sums)


def test_fill_deterministic_and_bounded():
    m1 = fill_registers(64, 128, seed=7)
    m2 = fill_registers(64, 128, seed=7)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert int(m1.min()) >= 0 and int(m1.max()) <= 32


def test_fill_offset_matches_columns():
    """Shard tau's registers equal the corresponding global columns."""
    full = fill_registers(32, 64, reg_offset=0, seed=3)
    shard = fill_registers(32, 16, reg_offset=16, seed=3)
    np.testing.assert_array_equal(np.asarray(full[:, 16:32]), np.asarray(shard))


def test_merge_is_union_max():
    a = jnp.array([[1, 5, VISITED]], dtype=jnp.int8)
    b = jnp.array([[3, 2, 7]], dtype=jnp.int8)
    out = np.asarray(merge(a, b))
    np.testing.assert_array_equal(out, [[3, 5, VISITED]])  # visited sticky


@pytest.mark.parametrize("true_n", [50, 500, 5000])
def test_estimator_accuracy(true_n):
    """HLL estimate within ~3 standard errors for known distinct counts."""
    est = exact_distinct_reference(np.arange(true_n), num_regs=256, seed=11)
    rel_err = abs(est - true_n) / true_n
    assert rel_err < 0.25, (true_n, est)


def test_estimate_visited_scales_marginal():
    """Marking half the sims visited halves the expected marginal gain."""
    m = fill_registers(4, 256, seed=1)
    est_full = np.asarray(estimate_cardinality(m))
    half = m.at[:, :128].set(VISITED)
    est_half = np.asarray(estimate_cardinality(half))
    ratio = est_half[0] / est_full[0]
    assert 0.3 < ratio < 0.7, ratio


def test_partial_sums_reduce_equals_direct():
    """psum-style reduction of shard statistics == direct estimate."""
    m = fill_registers(16, 128, seed=9)
    m = m.at[3, :50].set(VISITED)
    direct = np.asarray(estimate_cardinality(m))
    shards = [m[:, i * 32:(i + 1) * 32] for i in range(4)]
    sums = sum(partial_sums(s) for s in shards)
    via_sums = np.asarray(estimate_from_sums(sums, 128))
    np.testing.assert_allclose(direct, via_sums, rtol=1e-5)


def test_count_visited_only_real_rows():
    m = jnp.full((8, 4), VISITED, jnp.int8)
    assert int(sketch.count_visited(m, 5)) == 20
