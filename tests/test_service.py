"""Influence query service: store, queries, engine, delta repair."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import influence_score
from repro.core.difuser import DiFuserConfig, find_seeds
from repro.graphs import rmat_graph
from repro.graphs.structs import GraphDelta
from repro.service import (CoverageProbe, InfluenceEngine, MarginalGain,
                           Request, SketchStore, SpreadEstimate, TopKSeeds,
                           apply_delta, summarize_latencies)


@pytest.fixture(scope="module")
def served():
    """One shared (graph, config, store, engine) — the build is the point."""
    g = rmat_graph(9, edge_factor=8, seed=21, setting="w1")
    cfg = DiFuserConfig(num_registers=256, seed=2)
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g, cfg)
    return g, cfg, store, engine, key


def test_warm_topk_matches_cold_exactly(served):
    """Acceptance: warm-store TopKSeeds is byte-identical to cold find_seeds
    on the same (graph, config, x)."""
    g, cfg, store, engine, key = served
    entry = store.entry(key)
    cold = find_seeds(g, 8, cfg, x=entry.x)
    warm = engine(key, TopKSeeds(8)).value
    np.testing.assert_array_equal(warm.seeds, cold.seeds)
    np.testing.assert_array_equal(warm.est_gains, cold.est_gains)
    np.testing.assert_array_equal(warm.scores, cold.scores)
    np.testing.assert_array_equal(warm.rebuilds, cold.rebuilds)


def test_spread_estimate_matches_oracle(served):
    """SpreadEstimate agrees with the independent MC oracle within sketch
    tolerance (same bar as the e2e internal-score test)."""
    g, cfg, store, engine, key = served
    seeds = engine(key, TopKSeeds(5)).value.seeds
    est = engine(key, SpreadEstimate(seeds)).value
    oracle = influence_score(g, seeds, num_sims=300, rng_seed=17)
    assert abs(est - oracle) / max(oracle, 1.0) < 0.20, (est, oracle)


def test_marginal_gain_consistency(served):
    """gain(c | S) == spread(S + c) - spread(S), and committed vertices have
    zero gain."""
    g, cfg, store, engine, key = served
    s0, s1 = 3, 17
    sp_s0 = engine(key, SpreadEstimate([s0])).value
    sp_both = engine(key, SpreadEstimate([s0, s1])).value
    gain = engine(key, MarginalGain(s1, [s0])).value
    np.testing.assert_allclose(gain, sp_both - sp_s0, rtol=1e-5, atol=1e-3)
    self_gain = engine(key, MarginalGain(s0, [s0])).value
    np.testing.assert_allclose(self_gain, 0.0, atol=1e-3)


def test_coverage_probe_matches_singleton_spread(served):
    g, cfg, store, engine, key = served
    verts = [0, 5, 9]
    probe = engine(key, CoverageProbe(verts)).value
    singles = [engine(key, SpreadEstimate([v])).value for v in verts]
    np.testing.assert_allclose(probe["est"], singles, rtol=1e-5)
    assert probe["max_register"].shape == (3,)


def test_engine_batching_matches_single(served):
    """A mixed padded batch returns the same answers as one-by-one queries,
    in request order, with latency accounting filled in."""
    g, cfg, store, engine, key = served
    rng = np.random.default_rng(4)
    qs = []
    for _ in range(17):
        size = int(rng.integers(1, 7))
        qs.append(SpreadEstimate(rng.integers(0, g.n, size)))
    qs.append(MarginalGain(11, [2, 3]))
    qs.append(CoverageProbe([1, 2]))
    results = engine.run([Request(key=key, query=q) for q in qs])
    assert len(results) == len(qs)
    for q, r in zip(qs, results):
        assert r.query is q
        assert r.latency_s >= r.amortized_s >= 0.0
    # spot-check padded-batch values against singleton execution
    for i in (0, 7, 16):
        solo = engine(key, qs[i]).value
        np.testing.assert_allclose(results[i].value, solo, rtol=1e-6)
    stats = summarize_latencies(results)
    assert stats["num_queries"] == len(qs) and stats["p99_ms"] >= stats["p50_ms"]


def test_summarize_latencies_empty_results():
    """No results (or an all-memo-hit batch with zero measured time) must
    report qps 0.0, not inf — inf poisons the JSON bench artifacts and the
    trend gate's ratios."""
    stats = summarize_latencies([])
    assert stats["num_queries"] == 0
    assert stats["qps"] == 0.0
    assert stats["p50_ms"] == 0.0 and stats["p99_ms"] == 0.0
    assert stats["amortized_ms"] == 0.0 and stats["by_backend"] == {}


def test_topk_dedupe_and_memo(served):
    g, cfg, store, engine, key = served
    reqs = [Request(key=key, query=TopKSeeds(4)) for _ in range(3)]
    results = engine.run(reqs)
    # first batch: one execution shared in-batch (dedupe, not memo hits)
    assert sum(1 for r in results if r.deduped) == 2
    assert sum(1 for r in results if r.cache_hit) == 0
    for r in results[1:]:
        np.testing.assert_array_equal(r.value.seeds, results[0].value.seeds)
    # second batch: the memo serves it without execution
    again = engine.run([Request(key=key, query=TopKSeeds(4))])
    assert again[0].cache_hit
    np.testing.assert_array_equal(again[0].value.seeds, results[0].value.seeds)


def test_multi_bank_build_bit_identical(served):
    g, cfg, store, engine, key = served
    banked = SketchStore(num_banks=4).get_or_build(g, cfg)
    assert bool(jnp.all(banked.matrix == store.entry(key).matrix))


def test_delta_insertion_matches_rebuild(served):
    """Acceptance: apply_delta insertion result equals a from-scratch build
    on the updated graph, bit for bit."""
    g, cfg, _, _, _ = served
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g, cfg)
    rng = np.random.default_rng(8)
    delta = GraphDelta.make(add=(rng.integers(0, g.n, 40),
                                 rng.integers(0, g.n, 40)))
    report = apply_delta(store, key, delta)
    assert report.added == 40 and not report.rebuilt and not report.stale
    entry = store.entry(key)
    fresh = SketchStore().get_or_build(entry.graph, cfg, entry.x)
    assert bool(jnp.all(entry.matrix == fresh.matrix))


def test_delta_removal_staleness_and_lazy_rebuild(served):
    """Removals below threshold mark the entry stale; the next TopKSeeds
    rebuilds pristine and matches a cold run on the updated graph."""
    g, cfg, _, _, _ = served
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g, cfg)
    entry = store.entry(key)
    rem = (np.asarray(entry.graph.src[:4]), np.asarray(entry.graph.dst[:4]))
    report = apply_delta(store, key, GraphDelta.make(remove=rem))
    assert report.stale and not report.rebuilt
    warm = engine(key, TopKSeeds(5)).value
    entry = store.entry(key)
    assert not entry.stale and entry.rebuilds == 1
    cold = find_seeds(entry.graph, 5, cfg, x=entry.x)
    np.testing.assert_array_equal(warm.seeds, cold.seeds)


def test_delta_removal_threshold_triggers_full_rebuild(served):
    g, cfg, _, _, _ = served
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g, cfg)
    entry = store.entry(key)
    m = entry.graph.m_real
    rem = (np.asarray(entry.graph.src[: m // 2]),
           np.asarray(entry.graph.dst[: m // 2]))
    report = apply_delta(store, key, GraphDelta.make(remove=rem),
                         staleness_threshold=0.1)
    assert report.rebuilt and not report.stale
    fresh = SketchStore().get_or_build(store.entry(key).graph, cfg,
                                       store.entry(key).x)
    assert bool(jnp.all(store.entry(key).matrix == fresh.matrix))


def test_store_save_load_roundtrip(served, tmp_path):
    g, cfg, store, engine, key = served
    path = os.path.join(tmp_path, "index.npz")
    store.save(path, key)
    restored = SketchStore()
    entry2 = restored.load(path)
    assert entry2.key == key
    assert bool(jnp.all(entry2.matrix == store.entry(key).matrix))
    # the restored store serves identical top-k without rebuilding
    warm2 = InfluenceEngine(restored)(key, TopKSeeds(6)).value
    warm1 = engine(key, TopKSeeds(6)).value
    np.testing.assert_array_equal(warm2.seeds, warm1.seeds)


def test_store_hit_no_rebuild(served):
    g, cfg, store, engine, key = served
    before = len(store)
    e1 = store.get_or_build(g, cfg)
    assert len(store) == before and e1 is store.entry(key)


def test_topk_memo_invalidated_by_delta():
    """Regression: a delta that changes the top-k must invalidate the memo —
    post-delta queries can never serve a pre-delta seed set."""
    g = rmat_graph(8, edge_factor=4, seed=5, setting="w1")
    cfg = DiFuserConfig(num_registers=128, seed=3)
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g, cfg)
    before = engine(key, TopKSeeds(3))
    assert engine(key, TopKSeeds(3)).cache_hit   # memo is live
    # a star delta from a non-seed hub: high-weight edges to most of the
    # graph make the hub the dominant seed, so the answer must change
    hub = next(v for v in range(g.n) if v not in set(map(int, before.value.seeds)))
    dst = np.asarray([v for v in range(g.n) if v != hub], dtype=np.int64)
    delta = GraphDelta.make(add=(np.full(dst.shape, hub, dtype=np.int64), dst,
                                 np.full(dst.shape, 0.9, dtype=np.float32)))
    apply_delta(store, key, delta)
    after = engine(key, TopKSeeds(3))
    assert not after.cache_hit, "post-delta query served the stale memo"
    assert hub in set(map(int, after.value.seeds))
    # and the served answer equals a cold run on the post-delta graph
    entry = store.entry(key)
    cold = find_seeds(entry.graph, 3, cfg, x=entry.x)
    np.testing.assert_array_equal(after.value.seeds, cold.seeds)
    # repeated post-delta queries memo-hit against the *new* version
    assert engine(key, TopKSeeds(3)).cache_hit
