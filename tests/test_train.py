"""Training substrate: loss goes down, accumulation is exact, clipping,
both optimizers, checkpoint restart determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.train import DataConfig, TrainConfig, make_optimizer, make_train_step, synthetic_batch

CFG = ModelConfig(name="t", family="decoder", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32", remat="none")


def _run(steps, opt_name="adamw", accum=1, seed=0, lr=3e-3):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    opt = make_optimizer(opt_name, lr=lr, warmup=5)
    state = opt.init(params)
    step = jax.jit(make_train_step(CFG, opt, TrainConfig(accum_steps=accum)))
    dcfg = DataConfig(batch=8, seq=32, seed=seed)
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(CFG, dcfg, i).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    return losses, params, state


def test_loss_decreases_adamw():
    losses, _, _ = _run(40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::8]


def test_loss_decreases_adafactor():
    losses, _, _ = _run(40, opt_name="adafactor", lr=2e-2)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[::8]


def test_grad_accumulation_matches_full_batch():
    """accum=2 over the same batch == accum=1 (same grads up to fp error)."""
    l1, p1, _ = _run(3, accum=1, seed=3)
    l2, p2, _ = _run(3, accum=2, seed=3)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_checkpoint_restart_bitwise():
    import tempfile

    from repro.train.checkpoint import restore, save

    losses_ref, _, _ = _run(8, seed=5)

    # run 4 steps, checkpoint, restart, run 4 more
    params = init_params(CFG, jax.random.PRNGKey(5))
    opt = make_optimizer("adamw", lr=3e-3, warmup=5)
    state = opt.init(params)
    step = jax.jit(make_train_step(CFG, opt, TrainConfig()))
    dcfg = DataConfig(batch=8, seq=32, seed=5)
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(CFG, dcfg, i).items()}
        params, state, _ = step(params, state, batch)
    d = tempfile.mkdtemp()
    save(d, 4, {"params": params, "opt_state": state})
    got_step, tree = restore(d)
    assert got_step == 4
    params2, state2 = tree["params"], tree["opt_state"]
    losses2 = []
    for i in range(4, 8):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(CFG, dcfg, i).items()}
        params2, state2, m = step(params2, state2, batch)
        losses2.append(float(m["loss"]))
    np.testing.assert_allclose(losses_ref[4:], losses2, rtol=1e-5)


def test_grad_clipping_caps_norm():
    params = init_params(CFG, jax.random.PRNGKey(6))
    opt = make_optimizer("adamw", lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(CFG, opt, TrainConfig(max_grad_norm=1e-6)))
    dcfg = DataConfig(batch=4, seq=16, seed=6)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(CFG, dcfg, 0).items()}
    p2, _, m = step(params, state, batch)
    # with a microscopic clip threshold, params barely move
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta < 1e-3


def test_data_pipeline_deterministic_and_host_sharded():
    dcfg = DataConfig(batch=8, seq=16, seed=9)
    b1 = synthetic_batch(CFG, dcfg, 7)
    b2 = synthetic_batch(CFG, dcfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    h0 = synthetic_batch(CFG, dcfg, 7, host_id=0, num_hosts=2)
    h1 = synthetic_batch(CFG, dcfg, 7, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
