"""Distributed DiFuseR == single-device, bitwise (paper §4 in shard_map).

Runs in a subprocess with 8 fake XLA devices (the flag must be set before
jax initializes, and the rest of the suite needs the real single device).
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core.distributed import JAX_HAS_AXIS_TYPE

pytestmark = pytest.mark.skipif(
    not JAX_HAS_AXIS_TYPE,
    reason="jax.sharding.AxisType missing (old jax) — mesh/shard_map API drift")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.graphs import rmat_graph
from repro.core.difuser import DiFuserConfig, find_seeds
from repro.core.distributed import DistributedConfig, find_seeds_distributed
from repro.launch.mesh import make_mesh

g = rmat_graph(9, edge_factor=8, seed=2, setting="w1")
J = 256
single = find_seeds(g, 8, DiFuserConfig(num_registers=J, seed=0))
out = {"single": single.seeds.tolist(), "score": float(single.scores[-1])}

for name, shape, axes, sched in [
    ("ring_2x4", (2, 4), ("data", "model"), "ring"),
    ("ag_2x4", (2, 4), ("data", "model"), "allgather"),
    ("ring_4x2", (4, 2), ("data", "model"), "ring"),
    ("simonly_1x8", (1, 8), ("data", "model"), "ring"),
    ("pod_2x2x2", (2, 2, 2), ("pod", "data", "model"), "ring"),
]:
    mesh = make_mesh(shape, axes)
    cfg = DistributedConfig(num_registers=J, seed=0, schedule=sched,
                            sim_axes=tuple(a for a in axes if a != "data"))
    res, part = find_seeds_distributed(g, 8, mesh, cfg)
    out[name] = {
        "seeds": res.seeds.tolist(),
        "score": float(res.scores[-1]),
        "max_shard": int(part.edge_counts.max()),
    }
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), capture_output=True, text=True,
        timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_all_meshes_match_single_device(dist_results):
    r = dist_results
    for name in ("ring_2x4", "ag_2x4", "ring_4x2", "simonly_1x8", "pod_2x2x2"):
        assert r[name]["seeds"] == r["single"], name
        assert abs(r[name]["score"] - r["score"]) < 1e-4, name


def test_fasst_balances_shards(dist_results):
    assert dist_results["ring_2x4"]["max_shard"] > 0
