"""Model-zoo numerical correctness beyond smoke: SSD vs naive recurrence,
decode-chain == forward (teacher-forcing equivalence), prefill continuity,
sliding-window masking, GQA reduction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.ssm import init_mamba2, mamba2_decode, mamba2_forward, mamba2_init_cache
from repro.models.transformer import decode_step, forward, init_cache, init_params, prefill

BASE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, param_dtype="float32", compute_dtype="float32",
            remat="none")


def _cfg(family="decoder", **kw):
    return ModelConfig(name="t", family=family, **{**BASE, **kw})


def test_ssd_chunked_equals_sequential_decode():
    """Chunked SSD forward == token-by-token recurrent decode (the duality)."""
    cfg = _cfg("ssm", num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16, ssm_head_dim=32)
    p = init_mamba2(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.3
    full = mamba2_forward(p, x, cfg, chunk=8)
    cache = mamba2_init_cache(cfg, 2)
    outs = []
    for t in range(32):
        y, cache = mamba2_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), rtol=2e-3, atol=2e-4)


def test_ssd_prefill_cache_continues_decode():
    cfg = _cfg("ssm", num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16, ssm_head_dim=32)
    p = init_mamba2(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 24, cfg.d_model)) * 0.3
    # full forward over 24 tokens
    full = mamba2_forward(p, x, cfg, chunk=8)
    # prefill 16, then decode 8
    _, cache = mamba2_forward(p, x[:, :16], cfg, chunk=8, return_cache=True)
    outs = []
    for t in range(16, 24):
        y, cache = mamba2_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(full[:, 16:]),
                               np.asarray(jnp.concatenate(outs, 1)), rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("family,extra", [
    ("decoder", {}),
    ("decoder", {"qkv_bias": True}),
    ("decoder", {"sliding_window": 8}),
    ("ssm", {"num_heads": 0, "num_kv_heads": 0, "d_ff": 0, "ssm_state": 16, "ssm_head_dim": 32}),
    ("hybrid", {"ssm_state": 16, "ssm_head_dim": 32, "attn_every": 2, "num_layers": 4}),
])
def test_decode_chain_matches_forward(family, extra):
    """Greedy teacher-forced decode logits == full forward logits."""
    cfg = _cfg(family, **extra)
    params = init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 12), 0, cfg.vocab_size)
    ref_logits = forward(params, toks, cfg)
    cache = init_cache(cfg, 2, 12)
    got = []
    for t in range(12):
        lg, cache = decode_step(params, toks[:, t], cache, jnp.int32(t), cfg)
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(got),
                               rtol=5e-3, atol=5e-3)


def test_prefill_then_decode_matches_forward():
    cfg = _cfg("decoder")
    params = init_params(cfg, jax.random.PRNGKey(7))
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 16), 0, cfg.vocab_size)
    ref = forward(params, toks, cfg)
    logits_pre, cache = prefill(params, toks[:, :10], cfg)
    np.testing.assert_allclose(np.asarray(ref[:, :10]), np.asarray(logits_pre),
                               rtol=5e-3, atol=5e-3)
    # pad cache and continue decoding
    from repro.serve.engine import _pad_cache

    cache = _pad_cache(cache, 16)
    for t in range(10, 16):
        lg, cache = decode_step(params, toks[:, t], cache, jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(ref[:, t]), np.asarray(lg),
                                   rtol=5e-3, atol=5e-3)


def test_sliding_window_limits_context():
    """With window w, token t's output is invariant to tokens < t - w."""
    cfg = _cfg("decoder", sliding_window=4, num_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(9))
    toks1 = jax.random.randint(jax.random.PRNGKey(10), (1, 16), 0, cfg.vocab_size)
    toks2 = toks1.at[0, 0:4].set((toks1[0, 0:4] + 7) % cfg.vocab_size)
    l1 = forward(params, toks1, cfg)
    l2 = forward(params, toks2, cfg)
    # last position attends only to positions 12..15 -> unchanged
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-5, atol=1e-5)
    # an early position does change
    assert not np.allclose(np.asarray(l1[0, 2]), np.asarray(l2[0, 2]))


def test_gqa_equals_mha_when_kv_equals_heads():
    """kv=H GQA must reduce to standard MHA (groups of 1)."""
    from repro.models.attention import _attend

    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (2, 8, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(12), (2, 8, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(13), (2, 8, 4, 16))
    out = _attend(q, k, v, None, num_kv_heads=4)
    # manual MHA
    scores = jnp.einsum("bshk,bthk->bhst", q, k) / 4.0
    probs = jax.nn.softmax(scores, -1)
    ref = jnp.einsum("bhst,bthk->bshk", probs, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_gracefully():
    """Tiny capacity factor still produces finite outputs (token dropping)."""
    cfg = _cfg("decoder", moe_num_experts=4, moe_top_k=2, moe_d_ff=32,
               moe_capacity_factor=0.25)
    params = init_params(cfg, jax.random.PRNGKey(14))
    toks = jax.random.randint(jax.random.PRNGKey(15), (2, 16), 0, cfg.vocab_size)
    logits = forward(params, toks, cfg)
    assert bool(jnp.isfinite(logits).all())
