"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the jnp
oracles. Integer kernels must match bit-exactly; float statistics allclose.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampling import make_x_vector, weight_to_threshold
from repro.graphs import rmat_graph
from repro.kernels import ops


def _arrays(scale, ef, regs, setting="u01", seed=0):
    g = rmat_graph(scale, edge_factor=ef, seed=seed, setting=setting).sorted_by_dst()
    x = jnp.asarray(make_x_vector(regs, seed=seed + 1))
    return (g, jnp.asarray(g.src), jnp.asarray(g.dst),
            jnp.asarray(weight_to_threshold(g.weight)), x)


SWEEP = [
    (6, 4, 128, "w1"),
    (7, 8, 128, "u01"),
    (8, 8, 256, "n005"),
    (8, 16, 512, "w01"),
]


@pytest.mark.parametrize("scale,ef,regs,setting", SWEEP)
def test_fused_sample_sweep(scale, ef, regs, setting):
    g, src, dst, thr, x = _arrays(scale, ef, regs, setting)
    ref = ops.fused_sample(src, dst, thr, x, impl="ref")
    pal = ops.fused_sample(src, dst, thr, x, impl="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.parametrize("scale,ef,regs,setting", SWEEP)
def test_propagate_sweep_kernel(scale, ef, regs, setting):
    g, src, dst, thr, x = _arrays(scale, ef, regs, setting)
    m = ops.sketch_fill(jnp.zeros((g.n_pad, regs), jnp.int8), impl="ref")
    m = m.at[0].set(-1)  # visited row must stay sticky in both impls
    ref = ops.propagate_sweep(m, src, dst, thr, x, impl="ref")
    pal = ops.propagate_sweep(m, src, dst, thr, x, impl="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    assert (np.asarray(ref[0]) == -1).all()


@pytest.mark.parametrize("scale,ef,regs,setting", SWEEP[:3])
def test_cascade_sweep_kernel(scale, ef, regs, setting):
    g, src, dst, thr, x = _arrays(scale, ef, regs, setting)
    m = ops.sketch_fill(jnp.zeros((g.n_pad, regs), jnp.int8), impl="ref")
    m = m.at[1].set(-1)
    ref = ops.cascade_sweep(m, src, dst, thr, x, impl="ref")
    pal = ops.cascade_sweep(m, src, dst, thr, x, impl="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.parametrize("regs", [64, 128, 256, 1024])
def test_sketch_fill_kernel(regs):
    m0 = jnp.zeros((264, regs), jnp.int8).at[5].set(-1)
    ref = ops.sketch_fill(m0, reg_offset=32, seed=4, impl="ref")
    pal = ops.sketch_fill(m0, reg_offset=32, seed=4, impl="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.parametrize("n_pad,regs", [(64, 64), (264, 128), (512, 1024)])
def test_cardinality_kernel(n_pad, regs):
    m = ops.sketch_fill(jnp.zeros((n_pad, regs), jnp.int8), impl="ref")
    m = m.at[0, : regs // 2].set(-1)
    ref = ops.cardinality_stats(m, impl="ref")
    pal = ops.cardinality_stats(m, impl="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), rtol=1e-6)


def test_propagate_padding_edges_inert():
    """Sentinel (thr=0) edges never contribute."""
    g, src, dst, thr, x = _arrays(6, 4, 128)
    m = ops.sketch_fill(jnp.zeros((g.n_pad, 128), jnp.int8), impl="ref")
    out = ops.propagate_sweep(m, src, dst, thr, x, impl="ref")
    # padding rows started visited? no — they are filled; check sentinel row
    # received no merges from padding edges: run with ONLY padding edges
    pad_src = src[g.m_real:]
    pad_dst = dst[g.m_real:]
    pad_thr = thr[g.m_real:]
    if pad_src.shape[0]:
        out2 = ops.propagate_sweep(m, pad_src, pad_dst, pad_thr, x, impl="ref")
        np.testing.assert_array_equal(np.asarray(out2), np.asarray(m))
