"""Baseline implementations are themselves correct (they referee DiFuseR)."""
import numpy as np

from repro.baselines import exact_greedy, influence_score, ris_find_seeds
from repro.graphs import erdos_renyi_graph
from repro.graphs.structs import Graph


def _line_graph(p=1.0):
    # 0 -> 1 -> 2 -> 3 with probability p
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 3])
    return Graph.from_edges(4, src, dst, np.full(3, p, np.float32), edge_block=8)


def test_oracle_deterministic_graph():
    g = _line_graph(1.0)
    assert influence_score(g, np.array([0]), num_sims=10) == 4.0
    assert influence_score(g, np.array([2]), num_sims=10) == 2.0


def test_oracle_probabilistic_expectation():
    g = _line_graph(0.5)
    # E[spread from 0] = 1 + 1/2 + 1/4 + 1/8 = 1.875
    s = influence_score(g, np.array([0]), num_sims=4000, rng_seed=1)
    assert abs(s - 1.875) < 0.1, s


def test_exact_greedy_picks_source():
    g = _line_graph(1.0)
    seeds, score = exact_greedy(g, 1, num_sims=20)
    assert seeds[0] == 0
    assert score == 4.0


def test_ris_close_to_greedy():
    g = erdos_renyi_graph(200, avg_degree=5, seed=3, setting="w1")
    ris_seeds, _ = ris_find_seeds(g, 4, num_rr_sets=4000, rng_seed=2)
    greedy_seeds, greedy_score = exact_greedy(g, 4, num_sims=100, rng_seed=4)
    o_ris = influence_score(g, ris_seeds, num_sims=300, rng_seed=5)
    o_greedy = influence_score(g, greedy_seeds, num_sims=300, rng_seed=5)
    assert o_ris >= 0.9 * o_greedy


def test_ris_theta_bound_reasonable():
    from repro.baselines.ris import imm_num_rr_sets

    t = imm_num_rr_sets(10_000, 50, epsilon=0.5)
    assert 256 <= t < 10_000_000
