"""Bucketed propagate Pallas kernel == the distributed runtime's jnp sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import _bucket_sweep_propagate
from repro.core.sampling import make_x_vector
from repro.core.sketch import VISITED
from repro.kernels.bucket_propagate import bucket_propagate_pallas
from repro.kernels import ops


@pytest.mark.parametrize("n_loc,j_loc,n_edges", [(64, 128, 512), (96, 256, 1024)])
def test_bucket_propagate_matches_ref(n_loc, j_loc, n_edges):
    rng = np.random.default_rng(5)
    acc = ops.sketch_fill(jnp.zeros((n_loc, j_loc), jnp.int8))
    acc = acc.at[3].set(VISITED)
    block = ops.sketch_fill(jnp.zeros((n_loc, j_loc), jnp.int8), seed=9)
    h = jnp.asarray(rng.integers(0, 1 << 32, n_edges, dtype=np.uint64).astype(np.uint32))
    w = jnp.asarray(rng.integers(0, n_loc, n_edges).astype(np.int32))
    r = jnp.asarray(rng.integers(0, n_loc, n_edges).astype(np.int32))
    t = jnp.asarray((np.full(n_edges, 0.3) * 2**32).astype(np.uint64).astype(np.uint32))
    x = jnp.asarray(make_x_vector(j_loc, seed=2))

    ref = _bucket_sweep_propagate(acc, block, h, w, r, t, x)
    ref = jnp.where(acc == VISITED, acc, ref)  # runtime applies the guard at sweep end
    pal = bucket_propagate_pallas(acc, block, h, w, r, t, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    # visited stickiness
    assert (np.asarray(pal[3]) == VISITED).all()


def test_bucket_propagate_zero_threshold_inert():
    acc = ops.sketch_fill(jnp.zeros((32, 128), jnp.int8))
    block = ops.sketch_fill(jnp.zeros((32, 128), jnp.int8), seed=1)
    n_edges = 256
    h = jnp.zeros((n_edges,), jnp.uint32)
    w = jnp.zeros((n_edges,), jnp.int32)
    r = jnp.zeros((n_edges,), jnp.int32)
    t = jnp.zeros((n_edges,), jnp.uint32)
    x = jnp.asarray(make_x_vector(128, seed=3))
    out = bucket_propagate_pallas(acc, block, h, w, r, t, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))
