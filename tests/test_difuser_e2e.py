"""End-to-end DiFuseR quality and behavior (paper Tables 3/4 claims)."""
import numpy as np
import pytest

from repro.baselines import exact_greedy, influence_score, ris_find_seeds
from repro.core.difuser import DiFuserConfig, find_seeds
from repro.graphs import erdos_renyi_graph, rmat_graph


def test_quality_vs_exact_greedy_supercritical():
    """DiFuseR's seed set reaches >=90% of exact-greedy influence in the
    paper's regime (supercritical cascades, spreads in the hundreds+)."""
    g = erdos_renyi_graph(300, avg_degree=14, seed=11, setting="w1")
    k = 5
    res = find_seeds(g, k, DiFuserConfig(num_registers=256, seed=1))
    _, greedy_score = exact_greedy(g, k, num_sims=120, rng_seed=5)
    ours = influence_score(g, res.seeds, num_sims=300, rng_seed=6)
    assert ours >= 0.90 * greedy_score, (ours, greedy_score)


def test_quality_vs_exact_greedy_subcritical():
    """Subcritical micro-spreads (each seed reaches ~3 vertices) are the FM
    sketch's known weak spot (clz granularity at cardinality < 8); DiFuseR
    still lands within 85% of exact greedy. The paper's graphs (spreads of
    1e3..1e7) don't hit this regime — documented, not hidden."""
    g = erdos_renyi_graph(300, avg_degree=6, seed=11, setting="w1")
    res = find_seeds(g, 5, DiFuserConfig(num_registers=256, seed=1))
    _, greedy_score = exact_greedy(g, 5, num_sims=120, rng_seed=5)
    ours = influence_score(g, res.seeds, num_sims=300, rng_seed=6)
    assert ours >= 0.85 * greedy_score, (ours, greedy_score)


def test_quality_vs_ris():
    g = rmat_graph(9, edge_factor=8, seed=12, setting="w1")
    k = 8
    res = find_seeds(g, k, DiFuserConfig(num_registers=256, seed=0))
    ris_seeds, _ = ris_find_seeds(g, k, num_rr_sets=3000, rng_seed=3)
    ours = influence_score(g, res.seeds, num_sims=200, rng_seed=7)
    ris = influence_score(g, ris_seeds, num_sims=200, rng_seed=7)
    assert ours >= 0.92 * ris, (ours, ris)


def test_internal_score_matches_oracle():
    """DiFuseR's own influence estimate (visited count / J) is close to the
    independent Monte-Carlo oracle (paper §5.1 oracle validation)."""
    g = rmat_graph(9, edge_factor=8, seed=13, setting="w1")
    res = find_seeds(g, 5, DiFuserConfig(num_registers=512, seed=2))
    oracle = influence_score(g, res.seeds, num_sims=300, rng_seed=8)
    rel = abs(res.scores[-1] - oracle) / max(oracle, 1.0)
    assert rel < 0.15, (res.scores[-1], oracle)


def test_scores_monotone_in_k():
    g = rmat_graph(8, edge_factor=8, seed=14, setting="u01")
    res = find_seeds(g, 10, DiFuserConfig(num_registers=128, seed=3))
    assert (np.diff(res.scores) >= -1e-6).all()
    assert len(set(res.seeds.tolist())) == 10, "seeds must be distinct"


def test_lazy_rebuild_threshold():
    """e=inf never rebuilds; e=0 rebuilds whenever the score moves."""
    g = rmat_graph(8, edge_factor=8, seed=15, setting="w1")
    never = find_seeds(g, 6, DiFuserConfig(num_registers=128, seed=4,
                                           rebuild_threshold=float("inf")))
    always = find_seeds(g, 6, DiFuserConfig(num_registers=128, seed=4,
                                            rebuild_threshold=0.0))
    assert never.rebuilds.sum() == 0
    assert always.rebuilds.sum() >= 5
    # rebuilding can only help quality (within estimator noise)
    assert always.scores[-1] >= 0.85 * never.scores[-1]


def test_more_registers_better_estimates():
    g = rmat_graph(8, edge_factor=8, seed=16, setting="w1")
    small = find_seeds(g, 5, DiFuserConfig(num_registers=32, seed=5))
    big = find_seeds(g, 5, DiFuserConfig(num_registers=512, seed=5))
    o_small = influence_score(g, small.seeds, num_sims=200, rng_seed=9)
    o_big = influence_score(g, big.seeds, num_sims=200, rng_seed=9)
    assert o_big >= 0.95 * o_small


def test_pallas_impl_end_to_end():
    """The full driver also runs with the Pallas-interpret kernels."""
    g = rmat_graph(7, edge_factor=6, seed=17, setting="w1")
    ref = find_seeds(g, 3, DiFuserConfig(num_registers=128, seed=6, impl="ref"))
    pal = find_seeds(g, 3, DiFuserConfig(num_registers=128, seed=6, impl="pallas"))
    np.testing.assert_array_equal(ref.seeds, pal.seeds)
    np.testing.assert_allclose(ref.scores, pal.scores, rtol=1e-6)
