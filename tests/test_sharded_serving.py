"""Device-resident sharded serving (ISSUE 5).

Two tiers:

* **always-on** — the shard-local reduction *math* is exercised without a
  mesh: ``queries.shard_partial_rows`` is called per shard on slices of the
  plan-order matrix and combined with ``np.maximum`` (the pmax twin); the
  result must be bit-identical to the host-order reductions. Plus routing
  units: ``QueryResult.backend`` accounting, ``apply_delta(backend="auto")``
  on host entries, placement preconditions, npz residency field.

* **AxisType-guarded** — real ``NamedSharding`` placement on a host-device
  mesh: all four query classes bit-identical device vs host, mesh
  ``repair_plan_shards`` == serial repair == full rebuild, session
  residency routing, snapshot round-trip onto a mesh. These run in the
  ``test-jax-latest`` CI job (8 fake devices).
"""
import numpy as np
import pytest

from repro.core import sketch
from repro.core.difuser import DiFuserConfig
from repro.graphs import rmat_graph
from repro.graphs.structs import GraphDelta
from repro.partition import plan_partition
from repro.service import (CoverageProbe, InfluenceEngine, MarginalGain,
                           SketchStore, SpreadEstimate, TopKSeeds, apply_delta,
                           summarize_latencies)
from repro.service import queries as Q
from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

MU_V = 4


def _mesh_ready(mu_v=MU_V):
    if not JAX_HAS_AXIS_TYPE:
        return False, "jax.sharding.AxisType missing (old jax) — API drift"
    import jax

    if len(jax.devices()) < mu_v:
        return False, (f"needs {mu_v} devices (export XLA_FLAGS="
                       f"--xla_force_host_platform_device_count=8)")
    return True, ""


def _require_mesh():
    ok, why = _mesh_ready()
    if not ok:
        pytest.skip(why)


def _store_with_plan(strategy="degree", registers=128, seed=3, model="wc"):
    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1")
    cfg = DiFuserConfig(num_registers=registers, seed=seed, model=model)
    store = SketchStore()
    e = store.get_or_build(g, cfg)
    plan = plan_partition(e.graph, MU_V, mu_s=1, strategy=strategy, x=e.x,
                          seed=seed, model=model)
    store.attach_plan(e.key, plan)
    return store, e


def _rng_sets(n, count, rng, max_len=6):
    return [tuple(int(v) for v in rng.integers(0, n, rng.integers(1, max_len)))
            for _ in range(count)]


# ---------------------------------------------------------------------------
# Always-on: the shard-local partial reduction is bit-identical to host order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("estimator", ["hll", "fm_mean"])
@pytest.mark.parametrize("strategy", ["block", "degree", "random"])
def test_shard_partial_reduction_matches_host_bitwise(strategy, estimator):
    """Emulate the shard_map spread/probe bodies shard by shard (the exact
    ``shard_partial_rows`` function the device path runs, combined with the
    numpy twin of the pmax) and require bitwise equality with the host
    lowering — the core claim that lets device serving skip the gather."""
    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1")
    cfg = DiFuserConfig(num_registers=128, seed=3, estimator=estimator)
    store = SketchStore()
    e = store.get_or_build(g, cfg)
    plan = plan_partition(e.graph, MU_V, mu_s=1, strategy=strategy, x=e.x,
                          seed=3)
    store.attach_plan(e.key, plan)

    rng = np.random.default_rng(11)
    sets = _rng_sets(e.graph.n, 16, rng)
    host_est = Q.spread_estimates(e, sets)

    # device-twin: per-shard partial merge over the plan-order rows + pmax.
    # The int8 register merge must match the host merge BITWISE — that is
    # the decomposition the device path rests on (pmax of the owned-row
    # partials == the host union). The float estimator tail is compared to
    # near-ulp here because this twin runs it eagerly while the host kernel
    # is one fused jit; the jit-vs-jit exactness is asserted by the guarded
    # test_device_queries_bit_identical_to_host below.
    planned = np.asarray(e.planned_matrix())
    cands = Q.pad_candidate_sets(sets, e.graph.n_pad - 1,
                                 max(len(s) for s in sets))
    rows = plan.perm[cands.astype(np.int64)].astype(np.int32)
    n_loc = plan.n_loc
    partials = []
    for v in range(MU_V):
        m_loc = planned[v * n_loc:(v + 1) * n_loc]
        part = np.asarray(Q.shard_partial_rows(m_loc, rows, v * n_loc, n_loc))
        partials.append(part.max(axis=1))                  # (B, J) partial
    merged = np.maximum.reduce(partials)                   # the pmax combine
    host_merged = np.asarray(e.matrix)[cands].max(axis=1)
    np.testing.assert_array_equal(merged, host_merged)
    sums = sketch.partial_sums(merged, estimator=estimator)
    twin_est = np.asarray(sketch.estimate_from_sums(
        sums, e.x.shape[0], estimator=estimator))
    np.testing.assert_allclose(twin_est, host_est, rtol=1e-6)

    # probe twin: single-row gather, same combine — registers again bitwise
    verts = np.arange(0, e.graph.n, 7, dtype=np.int32)
    host_probe, host_maxreg = Q.coverage_probes(e, verts)
    vrows = plan.perm[verts.astype(np.int64)].astype(np.int32)
    prow = np.maximum.reduce([
        np.asarray(Q.shard_partial_rows(planned[v * n_loc:(v + 1) * n_loc],
                                        vrows, v * n_loc, n_loc))
        for v in range(MU_V)])
    np.testing.assert_array_equal(prow, np.asarray(e.matrix)[verts])
    sums = sketch.partial_sums(prow, estimator=estimator)
    np.testing.assert_allclose(
        np.asarray(sketch.estimate_from_sums(sums, e.x.shape[0],
                                             estimator=estimator)),
        host_probe, rtol=1e-6)
    np.testing.assert_array_equal(prow.max(axis=-1).astype(np.int32),
                                  host_maxreg)


def test_planned_rows_partition_every_vertex_once():
    """Every original vertex id maps to exactly one shard-local row — the
    ownership property the VISITED-elsewhere gather relies on."""
    _, e = _store_with_plan()
    rows = Q._plan_rows(e, np.arange(e.plan.n_pad))
    assert sorted(rows.tolist()) == list(range(e.plan.n_pad))
    owners = rows // e.plan.n_loc
    assert np.bincount(owners, minlength=MU_V).sum() == e.plan.n_pad


# ---------------------------------------------------------------------------
# Always-on: engine accounting + delta routing + placement preconditions
# ---------------------------------------------------------------------------


def test_queryresult_records_backend_and_memo():
    store, e = _store_with_plan()
    engine = InfluenceEngine(store)
    key = e.key
    r1 = engine(key, SpreadEstimate((1, 2, 3)))
    assert r1.backend == "single:host"
    t1 = engine(key, TopKSeeds(3))
    t2 = engine(key, TopKSeeds(3))     # memo hit
    assert t1.backend == "single:host" and not t1.cache_hit
    assert t2.backend == "memo" and t2.cache_hit
    stats = summarize_latencies([r1, t1, t2])
    assert stats["by_backend"] == {"single:host": 2, "memo": 1}


def test_apply_delta_auto_routes_serial_on_host_entries():
    """backend='auto' on a host-resident planned entry picks the serial
    shard repair and stays bit-identical to a pristine rebuild."""
    store, e = _store_with_plan()
    rng = np.random.default_rng(5)
    add = rng.integers(0, e.graph.n, (6, 2))
    delta = GraphDelta.make(add=(add[:, 0], add[:, 1],
                                 np.full(6, 0.8, np.float32)))
    rep = apply_delta(store, e.key, delta, backend="auto")
    assert rep.repair_backend == "serial"
    assert rep.plan_shards_touched
    assert set(rep.plan_shards_touched) <= set(rep.shards_swept) or \
        rep.repair_sweeps == 0
    repaired = np.asarray(store.entry(e.key).matrix)
    store.rebuild(e.key)
    np.testing.assert_array_equal(repaired,
                                  np.asarray(store.entry(e.key).matrix))


def test_host_entries_never_repair_on_mesh():
    """Residency is authoritative over the caller's backend: a host-order
    planned entry repairs through serial even when the session's backend is
    mesh (shipping the matrix to a throwaway mesh helps nobody), and with
    no backend at all the historical per-bank repair keeps running."""
    from repro.runtime import get_backend
    from repro.service.delta import _shard_repair_backend

    _, e = _store_with_plan()
    assert _shard_repair_backend(get_backend("mesh"), e).name == "serial"
    assert _shard_repair_backend("mesh", e).name == "serial"
    assert _shard_repair_backend("auto", e).name == "serial"
    assert _shard_repair_backend(None, e) is None
    assert _shard_repair_backend("single", e) is None


def test_place_on_mesh_preconditions():
    g = rmat_graph(6, edge_factor=5, seed=1, setting="w1")
    store = SketchStore()
    e = store.get_or_build(g, DiFuserConfig(num_registers=64, seed=1))
    with pytest.raises(ValueError, match="plan"):
        e.place_on_mesh(mesh=None)
    assert e.residency == "host" and e.serving_backend == "single:host"
    # to_host on a host entry is a no-op
    assert e.to_host() is e


def test_npz_snapshot_carries_residency_field(tmp_path):
    store, e = _store_with_plan()
    path = str(tmp_path / "snap")
    store.save(path, e.key)
    z = np.load(path + ".npz")
    assert str(z["residency"]) == "host"
    restored = SketchStore().load(path)
    assert restored.residency == "host"
    np.testing.assert_array_equal(np.asarray(restored.matrix),
                                  np.asarray(e.matrix))


def test_runspec_residency_resolution():
    from repro.runtime import RunSpec, get_backend, resolve_residency

    assert RunSpec().residency == "auto"
    single = get_backend("single")
    serial = get_backend("serial")
    mesh = get_backend("mesh")
    assert resolve_residency(RunSpec(), single) == "host"
    assert resolve_residency(RunSpec(), serial) == "host"
    assert resolve_residency(RunSpec(), mesh) == "device"
    assert resolve_residency(RunSpec(residency="host"), mesh) == "host"
    assert resolve_residency(RunSpec(residency="device"), single) == "device"


# ---------------------------------------------------------------------------
# AxisType-guarded: real placement on a host-device mesh
# ---------------------------------------------------------------------------


def _placed_store(strategy="degree", model="wc", registers=128):
    from repro.launch.mesh import make_serving_mesh

    store, e = _store_with_plan(strategy=strategy, model=model,
                                registers=registers)
    host = SketchStore()
    host_e = host.get_or_build(e.graph, e.cfg)     # untouched host twin
    e.place_on_mesh(make_serving_mesh(MU_V))
    return store, e, host, host_e


def test_placement_shards_rows_across_devices():
    _require_mesh()
    store, e, _, _ = _placed_store()
    assert e.residency == "device" and e.serving_backend == "mesh:device"
    pm = e.planned_matrix()
    assert pm.shape[0] == e.plan.n_pad
    devices = {s.device for s in pm.addressable_shards}
    assert len(devices) == MU_V
    for bank in e.banks:
        assert len({s.device for s in bank.addressable_shards}) == MU_V


@pytest.mark.parametrize("model", ["wc", "ic:0.2", "lt", "dic:0.5"])
def test_device_queries_bit_identical_to_host(model):
    _require_mesh()
    store, e, host, host_e = _placed_store(model=model)
    rng = np.random.default_rng(23)
    sets = _rng_sets(e.graph.n, 12, rng)
    np.testing.assert_array_equal(Q.spread_estimates(e, sets),
                                  Q.spread_estimates(host_e, sets))
    cands = [int(v) for v in rng.integers(0, e.graph.n, 8)]
    committed = _rng_sets(e.graph.n, 8, rng, max_len=4)
    np.testing.assert_array_equal(Q.marginal_gains(e, cands, committed),
                                  Q.marginal_gains(host_e, cands, committed))
    verts = [int(v) for v in rng.integers(0, e.graph.n, 16)]
    d_est, d_reg = Q.coverage_probes(e, verts)
    h_est, h_reg = Q.coverage_probes(host_e, verts)
    np.testing.assert_array_equal(d_est, h_est)
    np.testing.assert_array_equal(d_reg, h_reg)
    d_top = Q.top_k_seeds(store, e, 4)
    h_top = Q.top_k_seeds(host, host_e, 4)
    np.testing.assert_array_equal(d_top.seeds, h_top.seeds)
    np.testing.assert_array_equal(d_top.scores, h_top.scores)
    np.testing.assert_array_equal(d_top.est_gains, h_top.est_gains)


def test_engine_reports_device_backend():
    _require_mesh()
    store, e, _, _ = _placed_store()
    engine = InfluenceEngine(store)
    r = engine(e.key, CoverageProbe((0, 1, 2)))
    assert r.backend == "mesh:device"
    m = engine(e.key, MarginalGain(3, (1, 2)))
    assert m.backend == "mesh:device"


@pytest.mark.parametrize("strategy", ["block", "degree", "edge", "random"])
def test_mesh_repair_equals_serial_and_rebuild(strategy):
    _require_mesh()
    store, e, host, host_e = _placed_store(strategy=strategy)
    rng = np.random.default_rng(7)
    add = rng.integers(0, e.graph.n, (8, 2))
    delta = GraphDelta.make(add=(add[:, 0], add[:, 1],
                                 np.full(8, 0.7, np.float32)))

    rep_mesh = apply_delta(store, e.key, delta, backend="auto")
    assert rep_mesh.repair_backend == "mesh"
    assert store.entry(e.key).residency == "device"   # stayed placed

    host_plan = plan_partition(host_e.graph, MU_V, mu_s=1, strategy=strategy,
                               x=host_e.x, seed=3)
    host.attach_plan(host_e.key, host_plan)
    rep_serial = apply_delta(host, host_e.key, delta, backend="serial")
    assert rep_serial.repair_backend == "serial"

    mesh_m = np.asarray(store.entry(e.key).matrix)
    serial_m = np.asarray(host.entry(host_e.key).matrix)
    np.testing.assert_array_equal(mesh_m, serial_m)
    host.rebuild(host_e.key)
    np.testing.assert_array_equal(mesh_m,
                                  np.asarray(host.entry(host_e.key).matrix))
    assert rep_mesh.shards_swept == rep_serial.shards_swept
    assert rep_mesh.repair_sweeps == rep_serial.repair_sweeps


def test_session_auto_residency_and_repair_routing():
    _require_mesh()
    from repro.runtime import InfluenceSession, RunSpec

    g = rmat_graph(7, edge_factor=6, seed=9, setting="w1")
    spec = RunSpec(num_registers=128, seed=3, backend="mesh",
                   mu_v=2, mu_s=2, partition="degree")
    sess = InfluenceSession(g, spec)
    e = sess.entry()
    assert e.residency == "device"          # auto followed the mesh backend
    assert e.plan is not None and e.plan.mu_v == 2
    warm = sess.find_seeds_warm(4)
    cold = sess.find_seeds(4)
    np.testing.assert_array_equal(warm.seeds, cold.seeds)
    rng = np.random.default_rng(3)
    add = rng.integers(0, g.n, (4, 2))
    rep = sess.apply_delta(GraphDelta.make(
        add=(add[:, 0], add[:, 1], np.full(4, 0.9, np.float32))))
    assert rep.repair_backend == "mesh"


def test_snapshot_roundtrip_onto_mesh(tmp_path):
    _require_mesh()
    from repro.launch.mesh import make_serving_mesh

    store, e, _, _ = _placed_store()
    path = str(tmp_path / "devsnap")
    store.save(path, e.key)
    z = np.load(path + ".npz")
    assert str(z["residency"]) == "device"

    restored = SketchStore().load(path, mesh=make_serving_mesh(MU_V))
    assert restored.residency == "device"
    rng = np.random.default_rng(2)
    sets = _rng_sets(e.graph.n, 6, rng)
    np.testing.assert_array_equal(Q.spread_estimates(restored, sets),
                                  Q.spread_estimates(e, sets))
    # and a meshless load of the same snapshot degrades to host serving
    host_restored = SketchStore().load(path)
    assert host_restored.residency == "host"
    np.testing.assert_array_equal(Q.spread_estimates(host_restored, sets),
                                  Q.spread_estimates(e, sets))
