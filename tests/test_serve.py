"""Serving engine: greedy generation matches a manual forward argmax chain."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_params
from repro.serve import Engine, ServeConfig

CFG = ModelConfig(name="t", family="decoder", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32", remat="none")


def test_greedy_generation_matches_forward_chain():
    params = init_params(CFG, jax.random.PRNGKey(0))
    eng = Engine(CFG, params, ServeConfig(temperature=0.0))
    prompt = np.array([[3, 17, 42, 99], [5, 5, 5, 5]], np.int32)
    out = eng.generate(prompt, 6)

    # reference: repeatedly run the full forward and take argmax
    toks = jnp.asarray(prompt)
    ref = []
    for _ in range(6):
        logits = forward(params, toks, CFG)[:, -1, : CFG.vocab_size]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(out, np.stack(ref, axis=1))


def test_generation_clamps_to_logical_vocab():
    params = init_params(CFG, jax.random.PRNGKey(1))
    eng = Engine(CFG, params, ServeConfig(temperature=0.7, seed=3))
    out = eng.generate(np.array([[1, 2, 3]], np.int32), 20)
    assert out.max() < CFG.vocab_size


def test_encdec_generation_runs():
    cfg = ModelConfig(name="w", family="encdec", num_layers=2, enc_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=128, param_dtype="float32", compute_dtype="float32",
                      remat="none")
    params = init_params(cfg, jax.random.PRNGKey(2))
    eng = Engine(cfg, params)
    enc = np.random.default_rng(0).standard_normal((1, 10, 64)).astype(np.float32)
    out = eng.generate(np.array([[1, 2]], np.int32), 4, enc_embeds=enc)
    assert out.shape == (1, 4)
