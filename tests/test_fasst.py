"""FASST partitioner tests (paper §4.1, Tables 5/6/7)."""
import numpy as np

from repro.core.fasst import (build_partition, duplication_histogram,
                              lane_fill_rate, max_shard_fraction,
                              partition_samples)
from repro.core.sampling import make_x_vector
from repro.graphs import rmat_graph


def test_partition_is_permutation():
    x = make_x_vector(256, seed=1)
    for method in ("fasst", "naive"):
        shards, perm = partition_samples(x, 8, method=method)
        assert sorted(x.tolist()) == sorted(shards.reshape(-1).tolist())
        assert sorted(perm.tolist()) == list(range(256))


def test_fasst_shards_are_contiguous_ranges():
    x = make_x_vector(128, seed=2)
    shards, _ = partition_samples(x, 4, method="fasst")
    flat = shards.reshape(-1)
    assert (np.diff(flat.astype(np.int64)) >= 0).all()  # globally sorted


def test_fasst_reduces_duplication_and_max_shard():
    g = rmat_graph(9, edge_factor=8, seed=5, setting="w1")
    x = make_x_vector(256, seed=3)
    fasst = build_partition(g, x, 4, method="fasst")
    naive = build_partition(g, x, 4, method="naive")
    # Table 7: FASST's largest device-local graph is no larger than naive's
    assert max_shard_fraction(g, fasst) <= max_shard_fraction(g, naive) + 1e-9
    # Table 5: FASST puts more edges in exactly-1 shard
    hf = duplication_histogram(g, fasst)
    hn = duplication_histogram(g, naive)
    assert hf[1] >= hn[1] - 1e-9
    # never-sampled fraction is partition-independent
    np.testing.assert_allclose(hf[0], hn[0], atol=1e-12)


def test_fasst_improves_lane_fill():
    g = rmat_graph(9, edge_factor=8, seed=6, setting="w1")
    x = make_x_vector(512, seed=4)
    naive_fill = lane_fill_rate(g, x, lane_width=32)
    fasst_fill = lane_fill_rate(g, np.sort(x), lane_width=32)
    assert fasst_fill > naive_fill, (naive_fill, fasst_fill)


def test_device_local_edges_cover_all_sampled():
    """Every edge sampled by a shard's X values is in its local edge list."""
    from repro.core.sampling import edge_hash, weight_to_threshold

    g = rmat_graph(8, edge_factor=6, seed=7, setting="u01")
    x = make_x_vector(128, seed=9)
    part = build_partition(g, x, 4, method="fasst")
    h = edge_hash(g.src, g.dst)
    thr = weight_to_threshold(g.weight)
    for t in range(4):
        sampled = ((h[:, None] ^ part.x_shards[t][None, :]) < thr[:, None]).any(1)
        local = set(part.edge_index[t].tolist())
        missing = set(np.nonzero(sampled)[0].tolist()) - local
        assert not missing, (t, len(missing))
