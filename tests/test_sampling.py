"""Hash / fused-sampling unit tests (paper §2.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling import (clz32, edge_hash, make_x_vector, mix32,
                                 register_hash, sample_mask,
                                 weight_to_threshold)


def test_mix32_avalanche():
    """Flipping one input bit flips ~half the output bits on average."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1 << 32, 2000, dtype=np.uint64).astype(np.uint32)
    flips = []
    for bit in (0, 7, 16, 31):
        y = x ^ np.uint32(1 << bit)
        d = mix32(x) ^ mix32(y)
        flips.append(np.mean([bin(v).count("1") for v in d.astype(np.uint64)]))
    assert all(12 < f < 20 for f in flips), flips


def test_mix32_numpy_jnp_agree():
    x = np.arange(4096, dtype=np.uint32) * np.uint32(2654435761)
    a = mix32(x)
    b = np.asarray(mix32(jnp.asarray(x)))
    np.testing.assert_array_equal(a, b)


def test_edge_hash_order_sensitive():
    src = np.array([1, 2, 3], dtype=np.int32)
    dst = np.array([2, 1, 3], dtype=np.int32)
    h1 = edge_hash(src, dst)
    h2 = edge_hash(dst, src)
    assert (h1 != h2).any()


def test_clz32_matches_lax():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 1 << 32, 10000, dtype=np.uint64).astype(np.uint32)
    x[:33] = [0] + [1 << i for i in range(32)]  # exact boundary cases
    ours = clz32(x)
    lax = np.asarray(jax.lax.clz(jnp.asarray(x))).astype(np.int32)
    np.testing.assert_array_equal(ours, lax)


def test_sample_rate_matches_weight():
    """Empirical sampling probability ~ w for the XOR scheme (paper eq. 2)."""
    rng = np.random.default_rng(2)
    m, r = 2000, 512
    src = rng.integers(0, 1000, m).astype(np.int32)
    dst = rng.integers(0, 1000, m).astype(np.int32)
    x = make_x_vector(r, seed=5)
    h = edge_hash(src, dst)
    for w in (0.01, 0.1, 0.5):
        thr = weight_to_threshold(np.full(m, w, np.float32))
        mask = sample_mask(h, thr, x)
        rate = mask.mean()
        assert abs(rate - w) < 0.01 + 0.1 * w, (w, rate)


def test_zero_weight_never_sampled():
    src = np.arange(100, dtype=np.int32)
    dst = src + 1
    thr = weight_to_threshold(np.zeros(100, np.float32))
    mask = sample_mask(edge_hash(src, dst), thr, make_x_vector(64))
    assert not mask.any()


def test_threshold_monotone_in_weight():
    w = np.linspace(0, 1, 101).astype(np.float32)
    thr = weight_to_threshold(w)
    assert (np.diff(thr.astype(np.int64)) >= 0).all()
    assert thr[0] == 0
