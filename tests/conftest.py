import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 fake devices.


@pytest.fixture(scope="session")
def small_graph():
    from repro.graphs import rmat_graph

    return rmat_graph(8, edge_factor=8, seed=3, setting="w1").sorted_by_dst()


@pytest.fixture(scope="session")
def tiny_graph():
    from repro.graphs.structs import Graph

    src = np.array([0, 0, 1, 2, 2, 3, 4])
    dst = np.array([1, 2, 3, 3, 4, 4, 0])
    w = np.full(7, 0.9, np.float32)
    return Graph.from_edges(5, src, dst, w, edge_block=8).sorted_by_dst()
