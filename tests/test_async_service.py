"""Async serving pipeline: scheduler, eviction, async≡sync equivalence,
double-buffered swap overlap, cross-entry dispatch.

The contract under test everywhere: the async layer reorders work but never
changes it — every result is byte-identical to the synchronous engine's
answer for the same query against the same entry version.
"""
import time

import numpy as np
import pytest

from repro.core.difuser import DiFuserConfig
from repro.graphs import rmat_graph
from repro.graphs.structs import GraphDelta
from repro.service import (AsyncInfluenceEngine, CostAwareEvictor,
                           CoverageProbe, InfluenceEngine, MarginalGain,
                           Request, SketchStore, SpreadEstimate, TopKSeeds)
from repro.service.scheduler import MicroBatchScheduler


@pytest.fixture(scope="module")
def graphs():
    g1 = rmat_graph(8, edge_factor=8, seed=1, setting="w1")
    g2 = rmat_graph(8, edge_factor=8, seed=2, setting="w1")
    cfg = DiFuserConfig(num_registers=64, seed=0)
    return g1, g2, cfg


def _mixed_stream(n, num, seed, k=4):
    """A shuffled mixed-class query stream over vertex ids < n."""
    rng = np.random.default_rng(seed)
    out = []
    for kind in rng.integers(0, 4, size=num):
        if kind == 0:
            out.append(TopKSeeds(k))
        elif kind == 1:
            out.append(SpreadEstimate(rng.integers(0, n, int(rng.integers(1, 5)))))
        elif kind == 2:
            out.append(MarginalGain(int(rng.integers(0, n)),
                                    rng.integers(0, n, int(rng.integers(0, 4)))))
        else:
            out.append(CoverageProbe(rng.integers(0, n, int(rng.integers(1, 4)))))
    return out


def _same_value(a, b) -> bool:
    if isinstance(a, dict):
        return (np.array_equal(a["est"], b["est"])
                and np.array_equal(a["max_register"], b["max_register"]))
    if isinstance(a, float):
        return a == b
    return (np.array_equal(np.asarray(a.seeds), np.asarray(b.seeds))
            and np.array_equal(np.asarray(a.est_gains),
                               np.asarray(b.est_gains)))


def _run_both(g1, g2, cfg, stream, which, deadline_ms=25.0):
    """Serve the same (key, query) stream sync and async; return results."""
    sync = InfluenceEngine(SketchStore())
    ks = [sync.register(g1, cfg), sync.register(g2, cfg)]
    sync_res = sync.run([Request(key=ks[w], query=q)
                         for w, q in zip(which, stream)])
    with AsyncInfluenceEngine(store=SketchStore(),
                              deadline_ms=deadline_ms) as aeng:
        ka = [aeng.engine.register(g1, cfg), aeng.engine.register(g2, cfg)]
        futs = [aeng.submit(ka[w], q) for w, q in zip(which, stream)]
        aeng.drain()
        async_res = [f.result(5) for f in futs]
    return sync_res, async_res


# ---------------------------------------------------------------------------
# async ≡ sync equivalence
# ---------------------------------------------------------------------------


def test_async_equals_sync_mixed_stream(graphs):
    """Acceptance: a shuffled mixed-class stream over two resident graphs is
    byte-identical between the blocking engine and the async pipeline."""
    g1, g2, cfg = graphs
    stream = _mixed_stream(g1.n, 48, seed=11)
    which = np.random.default_rng(12).integers(0, 2, size=len(stream))
    sync_res, async_res = _run_both(g1, g2, cfg, stream, which)
    for s, a in zip(sync_res, async_res):
        assert _same_value(s.value, a.value)


def test_async_equals_sync_property(graphs):
    """Property form of the above: arbitrary shuffled streams and graph
    routing, byte-identical per-query results."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    g1, g2, cfg = graphs
    # warm both graphs' jit caches once so examples run fast
    _run_both(g1, g2, cfg, _mixed_stream(g1.n, 4, seed=0), [0, 1, 0, 1])

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), num=st.integers(1, 24),
           route_seed=st.integers(0, 2**16))
    def prop(seed, num, route_seed):
        stream = _mixed_stream(g1.n, num, seed=seed)
        which = np.random.default_rng(route_seed).integers(0, 2, size=num)
        sync_res, async_res = _run_both(g1, g2, cfg, stream, which,
                                        deadline_ms=10.0)
        for s, a in zip(sync_res, async_res):
            assert _same_value(s.value, a.value)

    prop()


def test_cross_entry_dispatch_bit_identical(graphs):
    """SpreadEstimate buckets against two different graphs coalesce into one
    concatenated device call — and the values stay bit-identical."""
    g1, g2, cfg = graphs
    rng = np.random.default_rng(7)
    stream = [SpreadEstimate(rng.integers(0, g1.n, 3)) for _ in range(24)]
    which = [i % 2 for i in range(len(stream))]
    sync_res, async_res = _run_both(g1, g2, cfg, stream, which,
                                    deadline_ms=60.0)
    assert any(r.backend == "cross:host" for r in async_res)
    for s, a in zip(sync_res, async_res):
        assert s.value == a.value


# ---------------------------------------------------------------------------
# double-buffered swap: serve N while N+1 builds
# ---------------------------------------------------------------------------


def test_delta_swap_overlaps_serving(graphs):
    """Queries submitted *while the repair is mid-flight* complete against
    version N; the swap lands afterwards and bumps the entry. Proven by
    resolving a query inside the _before_swap hook (mutation thread blocked
    between shadow-propagate and swap)."""
    g1, g2, cfg = graphs
    observed = {}

    class Hooked(AsyncInfluenceEngine):
        def _before_swap(self, key):
            entry = self.store.entry(key)
            fut = self.submit(key, SpreadEstimate((1, 2, 3)))
            observed["value"] = fut.result(10).value
            observed["version_during"] = entry.version

    with Hooked(store=SketchStore(), deadline_ms=20.0) as aeng:
        key = aeng.engine.register(g1, cfg)
        v0 = aeng.store.entry(key).version
        pre = aeng.submit(key, SpreadEstimate((1, 2, 3))).result(10).value
        rng = np.random.default_rng(3)
        delta = GraphDelta.make(add=(rng.integers(0, g1.n, 16),
                                     rng.integers(0, g1.n, 16)))
        rep = aeng.apply_delta_async(key, delta).result(30)
        assert rep.added == 16
        post = aeng.submit(key, SpreadEstimate((1, 2, 3))).result(10).value
        v1 = aeng.store.entry(key).version

    # the mid-repair query served version N and resolved before the swap
    assert observed["version_during"] == v0
    assert observed["value"] == pre
    assert v1 > v0
    # post-swap queries serve the repaired index (equal to a cold build)
    sync = InfluenceEngine(SketchStore())
    entry = sync.store.get_or_build(
        aeng.store.entry(key).graph, cfg, aeng.store.entry(key).x)
    assert post == sync(entry.key, SpreadEstimate((1, 2, 3))).value


def test_stale_topk_rebuilds_off_serving_path(graphs):
    """A removal delta leaves the entry stale; async TopKSeeds triggers a
    background rebuild (hold + requeue) and resolves against the pristine
    post-rebuild index — same answer the sync lazy rebuild gives."""
    g1, _, cfg = graphs
    sync = InfluenceEngine(SketchStore())
    ks = sync.register(g1, cfg)
    rem = (np.asarray(sync.store.entry(ks).graph.src[:4]),
           np.asarray(sync.store.entry(ks).graph.dst[:4]))

    with AsyncInfluenceEngine(store=SketchStore(), deadline_ms=20.0) as aeng:
        ka = aeng.engine.register(g1, cfg)
        aeng.apply_delta_async(ka, GraphDelta.make(remove=rem)).result(30)
        assert aeng.store.entry(ka).stale
        res = aeng.submit(ka, TopKSeeds(5)).result(30)
        assert not aeng.store.entry(ka).stale
        assert aeng.store.entry(ka).rebuilds == 1

    from repro.service import apply_delta
    apply_delta(sync.store, ks, GraphDelta.make(remove=rem))
    want = sync(ks, TopKSeeds(5)).value
    np.testing.assert_array_equal(res.value.seeds, want.seeds)


# ---------------------------------------------------------------------------
# eviction
# ---------------------------------------------------------------------------


def test_eviction_keeps_bytes_under_budget_and_rebuilds(graphs):
    """Device bytes stay under budget; evicted entries transparently rebuild
    on next touch with a bit-identical matrix."""
    g1, g2, cfg = graphs
    g3 = rmat_graph(8, edge_factor=8, seed=3, setting="w1")
    store = SketchStore()
    entries = [store.get_or_build(g, cfg) for g in (g1, g2, g3)]
    per = entries[0].device_bytes()
    before = {e.key: np.asarray(e.matrix) for e in entries}
    budget = 2 * per + per // 2     # room for two of the three
    ev = CostAwareEvictor(budget)
    for e in entries:               # equal rebuild cost: recency decides
        e.build_time_s = 1.0        # (first build pays jit compile otherwise)
    now = time.monotonic()
    ev.touch(entries[1].key, now)   # hottest
    ev.touch(entries[2].key, now - 0.5)
    ev.touch(entries[0].key, now - 5.0)  # coldest -> the victim
    evicted = ev.enforce(store)
    assert evicted == [entries[0].key]
    assert store.resident_bytes() <= budget
    assert store.is_evicted(entries[0].key)
    assert len(store) == 3          # evicted keys still count as known
    # transparent rebuild on touch, bit-identical matrix, version advanced
    e0 = store.entry(entries[0].key)
    assert not store.is_evicted(entries[0].key)
    assert e0.evictions == 1
    np.testing.assert_array_equal(np.asarray(e0.matrix),
                                  before[entries[0].key])


def test_async_engine_enforces_resident_budget(graphs):
    """With max_resident_mb set, registrations beyond the budget evict the
    coldest entry, and queries against the evicted key still answer
    correctly (rebuild on touch)."""
    g1, g2, cfg = graphs
    g3 = rmat_graph(8, edge_factor=8, seed=3, setting="w1")
    probe = SketchStore().get_or_build(g1, cfg)
    budget_mb = (2 * probe.device_bytes() + 100) / 2**20
    sync = InfluenceEngine(SketchStore())
    want = {}
    for g in (g1, g2, g3):
        k = sync.register(g, cfg)
        want[k] = sync(k, SpreadEstimate((0, 1))).value

    with AsyncInfluenceEngine(store=SketchStore(), deadline_ms=20.0,
                              max_resident_mb=budget_mb) as aeng:
        keys = [aeng.register_async(g, cfg).result(60) for g in (g1, g2, g3)]
        aeng.drain()
        assert aeng.store.resident_bytes() <= aeng.evictor.budget_bytes
        assert any(aeng.store.is_evicted(k) for k in keys)
        # every key — including the evicted one — still serves correctly
        for k in keys:
            got = aeng.submit(k, SpreadEstimate((0, 1))).result(60)
            assert got.value == want[k]


def test_stale_entries_are_not_evictable(graphs):
    """A stale matrix is history-dependent: evicting it would change
    answers, so the store refuses and the evictor skips it."""
    g1, _, cfg = graphs
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g1, cfg)
    e = store.entry(key)
    rem = (np.asarray(e.graph.src[:2]), np.asarray(e.graph.dst[:2]))
    from repro.service import apply_delta
    apply_delta(store, key, GraphDelta.make(remove=rem))
    assert store.entry(key).stale
    with pytest.raises(ValueError):
        store.evict(key)
    ev = CostAwareEvictor(0)        # budget 0: evict everything evictable
    assert ev.enforce(store) == []  # ...which is nothing


# ---------------------------------------------------------------------------
# scheduler units
# ---------------------------------------------------------------------------


def test_scheduler_flush_on_full_and_window():
    s = MicroBatchScheduler(max_batch=4, flush_window_s=10.0)
    k = "key"
    reqs = [s.make_request(k, SpreadEstimate((1,)), None, now=100.0)
            for _ in range(3)]
    assert [s.offer(r) for r in reqs] == [False, False, False]
    assert s.take_due(100.1) == []              # window not expired, not full
    assert s.next_flush_t() == 110.0
    r4 = s.make_request(k, SpreadEstimate((2,)), None, now=100.2)
    assert s.offer(r4) is True                  # full -> flush now
    (bucket,) = s.take_due(100.2)
    assert [r.seq for r in bucket] == [r.seq for r in reqs + [r4]]
    assert s.depth() == 0
    # window flush: a lone request goes out once its deadline passes
    r5 = s.make_request(k, SpreadEstimate((3,)), None, now=200.0)
    s.offer(r5)
    assert s.take_due(205.0) == []
    assert [[r5.seq]] == [[r.seq for r in b] for b in s.take_due(210.0)]


def test_scheduler_holds_and_requeue():
    s = MicroBatchScheduler(max_batch=8, flush_window_s=0.0)
    k1, k2 = "k1", "k2"
    a = s.make_request(k1, TopKSeeds(3), None, now=0.0)
    b = s.make_request(k2, TopKSeeds(3), None, now=0.0)
    s.offer(a), s.offer(b)
    s.hold(k1, "TopKSeeds")
    due = s.take_due(1.0)
    assert [r.key for bucket in due for r in bucket] == [k2]
    assert s.next_flush_t() is None             # held bucket costs no wakeups
    s.requeue([b])
    s.hold(k2)                                  # qclass=None parks every class
    assert s.take_due(2.0) == []
    s.release(k1, "TopKSeeds"), s.release(k2)
    got = {r.key for bucket in s.take_due(2.0) for r in bucket}
    assert got == {k1, k2}
    # distinct query classes bucket separately
    s.offer(s.make_request(k1, TopKSeeds(3), None, now=0.0))
    s.offer(s.make_request(k1, SpreadEstimate((1,)), None, now=0.0))
    assert len(s.take_due(1.0)) == 2


def test_swap_drops_engine_topk_memo(graphs):
    """The engine's swap hook retires memoized top-k for the swapped key."""
    g1, _, cfg = graphs
    store = SketchStore()
    engine = InfluenceEngine(store)
    key = engine.register(g1, cfg)
    engine(key, TopKSeeds(4))
    assert engine(key, TopKSeeds(4)).cache_hit
    shadow = store.shadow(key)
    shadow.rebuild(key)
    store.swap_entry(key, shadow.entry(key))
    assert (key, 4) not in engine._topk_memo
    assert not engine(key, TopKSeeds(4)).cache_hit
