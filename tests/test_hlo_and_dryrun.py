"""HLO collective parser units + a miniature dry-run (8 fake devices,
subprocess) covering the IM shard_map cell."""
import json
import os
import subprocess
import sys

import pytest

from repro.utils.hlo import collective_stats
from repro.utils.roofline import Roofline


def test_parser_all_reduce():
    text = ('  %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), '
            'replica_groups={{0,1,2,3}}, to_apply=%add\n')
    s = collective_stats(text)
    # ring all-reduce: 2 * 4096 B * 3/4
    assert abs(s.wire_bytes - 2 * 4096 * 3 / 4) < 1e-6
    assert s.op_count == 1


def test_parser_all_gather_and_permute():
    text = (
        "%all-gather = bf16[16,128]{1,0} all-gather(bf16[2,128]{1,0} %p), "
        "replica_groups=[1,8]<=[8], dimensions={0}\n"
        "%collective-permute = u8[64]{0} collective-permute(u8[64]{0} %q), "
        "source_target_pairs={{0,1},{1,2}}\n")
    s = collective_stats(text)
    ag = 16 * 128 * 2 * (7 / 8)
    cp = 64
    assert abs(s.wire_bytes - (ag + cp)) < 1e-6
    assert set(s.by_kind) == {"all-gather", "collective-permute"}


def test_parser_skips_async_done():
    text = ("%all-gather-start = f32[8]{0} all-gather(f32[1]{0} %p), replica_groups={{0,1}}\n"
            "%all-gather-done = f32[8]{0} all-gather-done(%all-gather-start)\n")
    s = collective_stats(text)
    assert s.op_count == 1


def test_roofline_terms():
    r = Roofline(arch="x", shape="train_4k", mesh="m", chips=256,
                 flops_per_device=197e12, bytes_per_device=819e9,
                 wire_bytes_per_device=50e9, model_flops_total=197e12 * 256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert r.useful_flops_ratio == 1.0


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.launch.mesh import make_mesh
from repro.utils.hlo import collective_stats

out = {}
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

# IM cell on the mini mesh
from repro.launch.dryrun import lower_im_cell, IM_CELLS
IM_CELLS["mini"] = (1 << 12, 1 << 14, 64, 1.5)
lowered, part = lower_im_cell("mini", mesh)
compiled = lowered.compile()
coll = collective_stats(compiled.as_text())
out["im"] = {"wire": coll.wire_bytes, "ok": True,
             "kinds": sorted(coll.by_kind)}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def mini_dryrun():
    from repro.core.distributed import JAX_HAS_AXIS_TYPE

    if not JAX_HAS_AXIS_TYPE:
        pytest.skip("jax.sharding.AxisType missing (old jax) — mesh/shard_map "
                    "API drift; dry-run cannot build its mesh")
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env, cwd=root,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_mini_dryrun_im_cell_compiles_with_ring(mini_dryrun):
    im = mini_dryrun["im"]
    assert im["ok"]
    # ring ppermute + selection psum must both be present
    assert "collective-permute" in im["kinds"], im["kinds"]
    assert any(k in im["kinds"] for k in ("all-reduce", "all-gather")), im["kinds"]
