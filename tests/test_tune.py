"""The measured kernel autotuner (repro.tune): cache round-trips, candidate
seeding, resolve_spec mode semantics, and the padded-kernel prime-edge
regression — plus the bit-identity contract the tuner rests on (seed sets
and matrices never move, only wall time).
"""
import json
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import rmat_graph
from repro.tune import (KernelConfig, TuningCache, cache_key, default_cache,
                        default_config, reset_default_cache,
                        schedule_candidates, size_bucket, spec_overrides,
                        sweep_candidates)
from repro.tune.autotuner import families_for, resolve_spec
from repro.tune.cache import CACHE_ENV, CACHE_VERSION


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def test_size_bucket_and_key():
    assert size_bucket(1) == 256
    assert size_bucket(256) == 256
    assert size_bucket(257) == 512
    assert size_bucket(5000) == 8192
    k = cache_key("sketch_propagate", backend="single", impl="ref",
                  model="wc", num_edges=5000)
    assert k == "sketch_propagate|single|ref|wc|e8192"
    # neighbors in the same bucket share an entry
    assert k == cache_key("sketch_propagate", backend="single", impl="ref",
                          model="wc", num_edges=4097)


def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "tune.json")
    c = TuningCache(path)
    cfg = KernelConfig(edge_block=256, local_sweeps=1)
    c.put("k1", cfg, measurement={"speedup": 1.2})
    c.save()
    c2 = TuningCache(path)
    assert c2.lookup("k1") == cfg
    assert c2.record("k1")["measurement"]["speedup"] == 1.2
    assert len(c2) == 1
    assert c2.lookup("absent") is None and c2.record("absent") is None


def test_cache_corrupt_and_version_mismatch(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert len(TuningCache(str(bad))) == 0      # silently empty
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"version": CACHE_VERSION + 1,
                                 "entries": {"k": {"config": {}}}}))
    assert TuningCache(str(wrong)).lookup("k") is None


def test_default_cache_env_override(tmp_path, monkeypatch):
    p = str(tmp_path / "env.json")
    monkeypatch.setenv(CACHE_ENV, p)
    reset_default_cache()
    assert default_cache().path == p
    monkeypatch.setenv(CACHE_ENV, "")           # disables persistence
    assert default_cache().path is None
    default_cache().save()                      # no-op, nothing written
    assert not tmp_path.joinpath("env.json").exists()
    monkeypatch.delenv(CACHE_ENV)
    reset_default_cache()


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------


def test_sweep_candidates_ref_clamped_and_unique():
    cands = sweep_candidates(100, impl="ref", default_chunk=2048)
    blocks = [c.edge_block for c in cands]
    assert len(blocks) == len(set(blocks))
    assert 100 in blocks and 2048 in blocks     # full-E + the default
    assert all(b <= 2048 for b in blocks)
    big = sweep_candidates(100_000, impl="ref")
    assert {128, 256, 2048, 8192, 100_000} <= {c.edge_block for c in big}


def test_sweep_candidates_pallas_grid():
    cands = sweep_candidates(10_000, impl="pallas")
    assert all(c.reg_tile in (128, 256) for c in cands)
    assert all(0 < c.edge_block <= 1024 for c in cands)


def test_schedule_candidates_seeded_from_measurement():
    # no stats at all: the conservative (0, 1) probe
    assert [c.local_sweeps for c in schedule_candidates(None, None)] == [0, 1]
    prof = types.SimpleNamespace(sweeps=1, step_bytes=np.array([700.0]))
    # comm is 30% of sweep traffic -> worth probing 1 and 2 local sweeps
    hot = types.SimpleNamespace(ring_bytes_per_sweep=300.0,
                                pad_waste_frac=0.5)
    assert [c.local_sweeps for c in schedule_candidates(hot, prof)] == [0, 1, 2]
    # comm is 2% -> extra sweeps are not worth timing
    cold = types.SimpleNamespace(ring_bytes_per_sweep=15.0,
                                 pad_waste_frac=0.5)
    assert [c.local_sweeps for c in schedule_candidates(cold, prof)] == [0]
    # global padding only offered when measured step-mode waste is small
    lean = types.SimpleNamespace(ring_bytes_per_sweep=300.0,
                                 pad_waste_frac=0.05)
    assert "global" in {c.pad_mode for c in schedule_candidates(lean, prof)}
    assert {c.pad_mode for c in schedule_candidates(hot, prof)} == {"step"}


def test_spec_overrides_mapping():
    from repro.runtime import RunSpec

    spec = RunSpec(num_registers=64)
    cfg = KernelConfig(edge_block=256, reg_tile=128, local_sweeps=1,
                       pad_mode="global")
    assert spec_overrides("sketch_propagate", cfg, spec) == {"edge_chunk": 256}
    assert spec_overrides("cascade_step", cfg, spec) == {"cascade_chunk": 256}
    assert spec_overrides("bucket_propagate", cfg, spec) == {
        "local_sweeps": 1, "pad_mode": "global"}
    fused = KernelConfig(fuse_sweeps=True, lane_fill=256)
    assert spec_overrides("fused_sweep", fused, spec) == {
        "fuse_sweeps": True, "lane_fill": 256}
    assert spec_overrides("fused_sweep", KernelConfig(), spec) == {
        "fuse_sweeps": False, "lane_fill": 0}
    assert spec_overrides("fused_sample", cfg, spec) == {}
    pal = spec.with_(impl="pallas")
    assert spec_overrides("sketch_propagate", cfg, pal) == {
        "edge_block": 256, "reg_tile": 128}
    # the all-defaults config resolves to the spec's own values
    assert spec_overrides("sketch_propagate", KernelConfig(), spec) == {
        "edge_chunk": spec.edge_chunk}


def test_families_for():
    from repro.runtime import RunSpec

    spec = RunSpec(num_registers=64)
    assert families_for(spec, "single") == ("sketch_propagate", "cascade_step")
    assert families_for(spec, "serial") == ()            # 1x1 grid: no ring
    sharded = spec.with_(mu_v=2, mu_s=2)
    assert families_for(sharded, "serial") == ("bucket_propagate",
                                               "fused_sweep")
    assert families_for(sharded, "mesh") == ("bucket_propagate",
                                             "fused_sweep")


def test_fused_candidates_seeded_from_measurement():
    from repro.tune import fused_candidates

    # no measurements: fills scale with the register count alone
    def fills(cands):
        return [c.lane_fill for c in cands]

    small = fused_candidates(None, None, model="wc", num_regs=128)
    assert fills(small) == [0] and all(c.fuse_sweeps for c in small)
    assert fills(fused_candidates(None, None, model="wc",
                                  num_regs=512)) == [0, 256]
    assert fills(fused_candidates(None, None, model="wc",
                                  num_regs=2048)) == [0, 256, 512]
    # lt's remixed hash spreads lanes -> a denser 128 slab is worth probing
    assert 128 in fills(fused_candidates(None, None, model="lt",
                                         num_regs=2048))
    assert 128 not in fills(fused_candidates(None, None, model="ic:0.2",
                                             num_regs=2048))
    # comm-dominated runs keep the slab probes; comm-free runs (<5% ring
    # traffic) collapse to the single full-width fused candidate
    prof = types.SimpleNamespace(sweeps=1, step_bytes=np.array([700.0]))
    cold = types.SimpleNamespace(ring_bytes_per_sweep=15.0,
                                 pad_waste_frac=0.5)
    assert fills(fused_candidates(cold, prof, model="wc",
                                  num_regs=2048)) == [0]
    hot = types.SimpleNamespace(ring_bytes_per_sweep=300.0,
                                pad_waste_frac=0.5)
    assert fills(fused_candidates(hot, prof, model="wc",
                                  num_regs=2048)) == [0, 256, 512]


# ---------------------------------------------------------------------------
# resolve_spec mode semantics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_graph():
    return rmat_graph(6, edge_factor=4, seed=7, setting="w1")


def test_resolve_spec_off_is_identity(small_graph):
    from repro.runtime import RunSpec

    spec = RunSpec(num_registers=64, seed=1)
    assert resolve_spec(small_graph, spec, backend="single") is spec
    # no graph -> no tuning, spec comes back untouched even in auto mode
    auto = spec.with_(tuning="auto")
    assert resolve_spec(None, auto, backend="single") is auto


def test_resolve_spec_rejects_unknown_mode(small_graph):
    from repro.runtime import RunSpec

    spec = RunSpec(num_registers=64, tuning="banana")
    with pytest.raises(ValueError):
        resolve_spec(small_graph, spec, backend="single")


def test_resolve_spec_cached_hit_and_miss(small_graph):
    from repro.runtime import RunSpec

    g = small_graph
    spec = RunSpec(num_registers=64, seed=1, tuning="cached")
    cache = TuningCache(None)                   # in-memory, cold
    out = resolve_spec(g, spec, backend="single", cache=cache)
    assert out.edge_chunk == spec.edge_chunk    # miss: deterministic fallback
    key = cache_key("sketch_propagate", backend="single", impl=spec.impl,
                    model=spec.model, num_edges=int(g.m))
    cache.put(key, KernelConfig(edge_block=256))
    out = resolve_spec(g, spec, backend="single", cache=cache)
    assert out.edge_chunk == 256                # hit: winner applied
    assert out.cascade_chunk == spec.cascade_chunk  # other family still miss
    assert out.tuning == "cached"               # mode itself never overridden


def test_resolve_spec_auto_measures_and_persists(small_graph):
    from repro.runtime import RunSpec

    g = small_graph
    spec = RunSpec(num_registers=64, seed=1, tuning="auto")
    cache = TuningCache(None)
    resolve_spec(g, spec, backend="single", cache=cache)
    assert len(cache) == 2                      # both single-device families
    for key, entry in cache.records().items():
        m = entry["measurement"]
        assert m["speedup"] >= 1.0              # default is always a candidate
        assert m["candidates"][0]["config"] == default_config(
            key.split("|")[0]).to_dict()
    # second resolve is pure cache hits (no new entries) applying the winner
    out = resolve_spec(g, spec, backend="single", cache=cache)
    assert len(cache) == 2
    winner = cache.lookup(cache_key("sketch_propagate", backend="single",
                                    impl=spec.impl, model=spec.model,
                                    num_edges=int(g.m)))
    assert out.edge_chunk == (winner.edge_block or spec.edge_chunk)


def test_tuning_bit_identity_single_backend(small_graph, tmp_path, monkeypatch):
    """The whole point: tuning="auto" measures and re-tiles, but seeds,
    spreads, and the canonical matrix are bit-identical to tuning="off"."""
    from repro.runtime import InfluenceSession, RunSpec

    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "tune.json"))
    reset_default_cache()
    try:
        g = small_graph
        base = RunSpec(num_registers=64, seed=3, backend="single")
        res_off = InfluenceSession(g, base).find_seeds(4)
        m_off, _, _ = InfluenceSession(g, base).build_sketch_matrix()
        for mode in ("cached", "auto", "cached"):   # cached-cold, auto, warm
            sess = InfluenceSession(g, base.with_(tuning=mode))
            res = sess.find_seeds(4)
            np.testing.assert_array_equal(np.asarray(res.seeds),
                                          np.asarray(res_off.seeds))
            m_tuned, _, _ = sess.build_sketch_matrix()
            np.testing.assert_array_equal(np.asarray(m_tuned),
                                          np.asarray(m_off))
        assert tmp_path.joinpath("tune.json").exists()
    finally:
        monkeypatch.delenv(CACHE_ENV)
        reset_default_cache()


def test_tuning_bit_identity_serial_ring(small_graph, tmp_path, monkeypatch):
    """Ring-schedule tuning (local_sweeps / pad_mode) on the 2x2 serial
    backend: same seeds and matrix as the untuned ring."""
    from repro.runtime import InfluenceSession, RunSpec

    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "tune.json"))
    reset_default_cache()
    try:
        g = small_graph
        base = RunSpec(num_registers=64, seed=3, backend="serial",
                       mu_v=2, mu_s=2)
        res_off = InfluenceSession(g, base).find_seeds(4)
        sess = InfluenceSession(g, base.with_(tuning="auto"))
        res = sess.find_seeds(4)
        np.testing.assert_array_equal(np.asarray(res.seeds),
                                      np.asarray(res_off.seeds))
        cache = default_cache()
        key = cache_key("bucket_propagate", backend="serial", impl=base.impl,
                        model=base.model, num_edges=int(g.m))
        assert cache.lookup(key) is not None
    finally:
        monkeypatch.delenv(CACHE_ENV)
        reset_default_cache()


# ---------------------------------------------------------------------------
# serial local_sweeps result-invariance (the knob the ring tuner moves)
# ---------------------------------------------------------------------------


def test_serial_local_sweeps_result_invariant(small_graph):
    from repro.core.difuser import DiFuserConfig
    from repro.core.sampling import make_x_vector
    from repro.partition.serial import build_matrix_ring_serial

    g = small_graph.sorted_by_dst()
    cfg = DiFuserConfig(num_registers=64, seed=5)
    x = np.sort(np.asarray(make_x_vector(64, seed=5), dtype=np.uint32))
    mats = [build_matrix_ring_serial(g, cfg, x, mu_v=2, mu_s=2,
                                     local_sweeps=ls)[0]
            for ls in (0, 1, 2)]
    np.testing.assert_array_equal(mats[0], mats[1])
    np.testing.assert_array_equal(mats[0], mats[2])


# ---------------------------------------------------------------------------
# prime-edge-count regression (kernels/common.py pad+mask instead of the
# largest-divisor pick_block, whose worst case was block=1 on prime axes)
# ---------------------------------------------------------------------------


def test_clamp_and_pad_prime_axis():
    from repro.kernels.common import clamp_block, pad_amount, pick_block

    assert pick_block(997, 512) == 1            # the footgun, documented
    assert clamp_block(997, 512) == 512         # the fix: clamp ...
    assert pad_amount(997, 512) == 27           # ... and pad to 1024
    assert clamp_block(251, 512) == 251         # block never exceeds the axis
    assert pad_amount(251, 251) == 0


@pytest.mark.parametrize("m_prime", [251, 509])
def test_pallas_sweeps_prime_edge_count(m_prime):
    """Pallas edge kernels on a prime edge count (no divisor-friendly
    block exists) must still match the jnp oracle bit-exactly — the padded
    tail is predicate-dead (thr=0 never fires)."""
    from repro.core.sampling import make_x_vector, weight_to_threshold
    from repro.kernels import ops

    g = rmat_graph(7, edge_factor=8, seed=2, setting="u01").sorted_by_dst()
    assert g.m >= m_prime
    src = jnp.asarray(g.src[:m_prime])
    dst = jnp.asarray(g.dst[:m_prime])
    thr = jnp.asarray(weight_to_threshold(g.weight[:m_prime]))
    x = jnp.asarray(make_x_vector(128, seed=3))
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, 128), jnp.int8), impl="ref")
    ref = ops.propagate_sweep(m0, src, dst, thr, x, impl="ref")
    pal = ops.propagate_sweep(m0, src, dst, thr, x, impl="pallas",
                              edge_block=64)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    mc = m0.at[0].set(-1)
    ref_c = ops.cascade_sweep(mc, src, dst, thr, x, impl="ref")
    pal_c = ops.cascade_sweep(mc, src, dst, thr, x, impl="pallas",
                              edge_block=64)
    np.testing.assert_array_equal(np.asarray(ref_c), np.asarray(pal_c))


def test_ref_sweep_chunk_invariant(small_graph):
    """The scan-chunk knob the tuner moves on the ref impl is bit-invariant
    (including chunk = full E: no scan at all)."""
    from repro.core.sampling import make_x_vector, weight_to_threshold
    from repro.kernels import ops

    g = small_graph.sorted_by_dst()
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    thr = jnp.asarray(weight_to_threshold(g.weight))
    x = jnp.asarray(make_x_vector(64, seed=1))
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, 64), jnp.int8), impl="ref")
    outs = [np.asarray(ops.propagate_sweep(m0, src, dst, thr, x, impl="ref",
                                           edge_chunk=c))
            for c in (7, 128, 2048, int(src.shape[0]))]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_fused_sweep_matches_sweep_loop(small_graph):
    """The fused multi-sweep kernel is bit-identical to S separate
    propagate_sweep launches, for every lane_fill (including a non-divisor
    slab width) on the ref impl and every lane tile on the Pallas impl."""
    from repro.core.sampling import make_x_vector, weight_to_threshold
    from repro.kernels import ops

    g = small_graph.sorted_by_dst()
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    thr = jnp.asarray(weight_to_threshold(g.weight))
    x = jnp.asarray(make_x_vector(64, seed=1))
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, 64), jnp.int8), impl="ref")
    oracle = m0
    for _ in range(3):
        oracle = ops.propagate_sweep(oracle, src, dst, thr, x, impl="ref")
    oracle = np.asarray(oracle)
    for lf in (0, 16, 24):                      # 24 does not divide 64
        out = ops.fused_sweep(m0, src, dst, thr, x, num_sweeps=3,
                              impl="ref", lane_fill=lf)
        np.testing.assert_array_equal(oracle, np.asarray(out))
    for tile in (16, 64):
        out = ops.fused_sweep(m0, src, dst, thr, x, num_sweeps=3,
                              impl="pallas", lane_fill=tile)
        np.testing.assert_array_equal(oracle, np.asarray(out))


@pytest.mark.parametrize("m_prime", [251, 509])
def test_fused_sweep_prime_edge_count(m_prime):
    """Fused Pallas sweeps on a prime edge count: the pad+mask path (padded
    tail is predicate-dead) must hold across every fused iteration, not just
    the first — a sticky bit leaking from the pad would compound per sweep."""
    from repro.core.sampling import make_x_vector, weight_to_threshold
    from repro.kernels import ops

    g = rmat_graph(7, edge_factor=8, seed=2, setting="u01").sorted_by_dst()
    assert g.m >= m_prime
    src = jnp.asarray(g.src[:m_prime])
    dst = jnp.asarray(g.dst[:m_prime])
    thr = jnp.asarray(weight_to_threshold(g.weight[:m_prime]))
    x = jnp.asarray(make_x_vector(128, seed=3))
    m0 = ops.sketch_fill(jnp.zeros((g.n_pad, 128), jnp.int8), impl="ref")
    oracle = m0
    for _ in range(2):
        oracle = ops.propagate_sweep(oracle, src, dst, thr, x, impl="ref")
    pal = ops.fused_sweep(m0, src, dst, thr, x, num_sweeps=2, impl="pallas",
                          lane_fill=64)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(pal))
    ref = ops.fused_sweep(m0, src, dst, thr, x, num_sweeps=2, impl="ref",
                          lane_fill=48)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(ref))
