"""Unified runtime API: backend invariance, shims, sharded store/delta.

The contract under test (ISSUE 4 acceptance):

  * identical seed sets across the ``single`` / ``serial`` (/ ``mesh``,
    under the jax version guard) backends, for every registered diffusion
    model and every partition strategy;
  * the deprecated entry points (``find_seeds``,
    ``find_seeds_ring_serial``, ``find_seeds_distributed``) are thin shims
    over the facade and return byte-identical results while warning;
  * ``SketchStore`` banks build bit-identically through any registered
    backend;
  * a ``GraphDelta`` repair through the ``serial`` backend re-propagates
    only ``plan_shards_touched`` shards, bit-identical to a full rebuild.
"""
import numpy as np
import pytest

from repro.core.difuser import DiFuserConfig, find_seeds
from repro.graphs import rmat_graph
from repro.graphs.structs import Graph, GraphDelta
from repro.partition import find_seeds_ring_serial, plan_partition
from repro.runtime import (BackendUnavailable, InfluenceSession, RunSpec,
                           available_backends, get_backend, resolve_backend,
                           run)
from repro.service import SketchStore, apply_delta
from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

MODELS = ["wc", "ic:0.2", "lt", "dic:0.5"]
STRATEGIES = ["block", "degree", "edge", "random"]


def _graph():
    return rmat_graph(7, edge_factor=6, seed=9, setting="w1")


def _spec(model="wc", **kw):
    return RunSpec(num_registers=128, seed=3, model=model, **kw)


_single_cache: dict = {}


def _single_result(model: str):
    """One single-backend reference run per model (shared across params)."""
    if model not in _single_cache:
        _single_cache[model] = run(_graph(), 4, _spec(model, backend="single"))
    return _single_cache[model].result


# ---------------------------------------------------------------------------
# Backend invariance: single == serial (== mesh) for all models x strategies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_serial_backend_invariance(model, strategy):
    ref = _single_result(model)
    rep = run(_graph(), 4, _spec(model, backend="serial", mu_v=2, mu_s=2,
                                 partition=strategy))
    assert rep.backend == "serial"
    assert rep.partition is not None and rep.partition.plan.strategy == strategy
    np.testing.assert_array_equal(rep.result.seeds, ref.seeds)
    np.testing.assert_array_equal(rep.result.scores, ref.scores)
    np.testing.assert_array_equal(rep.result.est_gains, ref.est_gains)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_mesh_backend_invariance(model, strategy):
    if not JAX_HAS_AXIS_TYPE:
        pytest.skip("jax.sharding.AxisType missing (old jax) — API drift")
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("mesh backend needs >= 4 devices (export XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    ref = _single_result(model)
    rep = run(_graph(), 4, _spec(model, backend="mesh", mu_v=2, mu_s=2,
                                 partition=strategy))
    assert rep.backend == "mesh"
    np.testing.assert_array_equal(rep.result.seeds, ref.seeds)
    np.testing.assert_array_equal(rep.result.scores, ref.scores)


# ---------------------------------------------------------------------------
# auto resolution + registry
# ---------------------------------------------------------------------------


def test_auto_resolution_rules():
    g = _graph()
    assert resolve_backend(_spec(), g).name == "single"
    sharded = resolve_backend(_spec(mu_v=2, mu_s=2), g)
    if JAX_HAS_AXIS_TYPE:
        import jax

        expect = "mesh" if len(jax.devices()) >= 4 else "serial"
    else:
        expect = "serial"
    assert sharded.name == expect
    with pytest.raises(KeyError):
        get_backend("warp-drive")
    if not JAX_HAS_AXIS_TYPE:
        with pytest.raises(BackendUnavailable):
            resolve_backend(_spec(backend="mesh", mu_v=2, mu_s=2), g)


def test_registry_reports_capabilities():
    caps = {name: get_backend(name).capabilities()
            for name in ("single", "serial", "mesh")}
    assert not caps["single"].distributed and not caps["single"].needs_mesh
    assert caps["serial"].distributed and caps["serial"].shard_repair
    assert caps["mesh"].needs_mesh and caps["mesh"].shard_repair
    avail = available_backends()
    assert avail["single"][0] and avail["serial"][0]


def test_session_reports_provenance():
    sess = InfluenceSession(_graph(), _spec(mu_v=2, mu_s=2, backend="serial",
                                            partition="degree"))
    res = sess.find_seeds(3)
    assert sess.last_report.backend == "serial"
    assert sess.last_report.wall_s > 0
    assert sess.last_report.partition.plan.strategy == "degree"
    assert res.seeds.shape == (3,)


# ---------------------------------------------------------------------------
# Deprecated entry points: thin shims, byte-identical through the facade
# ---------------------------------------------------------------------------


def test_shim_find_seeds_byte_identical():
    g = _graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    with pytest.warns(DeprecationWarning, match="find_seeds is deprecated"):
        old = find_seeds(g, 4, cfg)
    new = InfluenceSession(g, RunSpec.from_config(cfg)).find_seeds(4)
    for f in ("seeds", "est_gains", "scores", "rebuilds", "x"):
        np.testing.assert_array_equal(getattr(old, f), getattr(new, f))
    assert old.propagate_iters == new.propagate_iters


def test_shim_find_seeds_ring_serial_byte_identical():
    g = _graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    with pytest.warns(DeprecationWarning, match="find_seeds_ring_serial"):
        old, old_part = find_seeds_ring_serial(g, 4, cfg, mu_v=2, mu_s=2,
                                               strategy="degree")
    rep = run(g, 4, RunSpec.from_config(cfg, backend="serial", mu_v=2, mu_s=2,
                                        partition="degree"))
    for f in ("seeds", "est_gains", "scores", "rebuilds", "x"):
        np.testing.assert_array_equal(getattr(old, f), getattr(rep.result, f))
    assert old_part.mu_v == rep.partition.mu_v == 2


def test_shim_find_seeds_distributed_byte_identical():
    if not JAX_HAS_AXIS_TYPE:
        pytest.skip("jax.sharding.AxisType missing (old jax) — API drift")
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("mesh shim needs >= 4 devices")
    from repro.core.distributed import DistributedConfig, find_seeds_distributed
    from repro.launch.mesh import make_mesh

    g = _graph()
    cfg = DistributedConfig(num_registers=128, seed=3)
    mesh = make_mesh((2, 2), ("data", "model"))
    with pytest.warns(DeprecationWarning, match="find_seeds_distributed"):
        old, _ = find_seeds_distributed(g, 4, mesh, cfg)
    rep = run(g, 4, RunSpec.from_config(cfg, backend="mesh", mu_v=2, mu_s=2),
              mesh=mesh)
    np.testing.assert_array_equal(old.seeds, rep.result.seeds)
    np.testing.assert_array_equal(old.scores, rep.result.scores)


# ---------------------------------------------------------------------------
# Store banks through any backend + warm path
# ---------------------------------------------------------------------------


def test_store_banks_build_through_any_backend():
    g = _graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    ref = np.asarray(SketchStore(num_banks=2).get_or_build(g, cfg).matrix)
    for spec in (RunSpec(mu_v=2, mu_s=1, partition="degree"),
                 RunSpec(mu_v=2, mu_s=2, partition="edge")):
        st = SketchStore(num_banks=2, backend="serial", spec=spec)
        m = np.asarray(st.get_or_build(g, cfg).matrix)
        np.testing.assert_array_equal(m, ref)
    if JAX_HAS_AXIS_TYPE:
        import jax

        if len(jax.devices()) >= 2:
            st = SketchStore(num_banks=2, backend="mesh",
                             spec=RunSpec(mu_v=2, mu_s=1))
            m = np.asarray(st.get_or_build(g, cfg).matrix)
            np.testing.assert_array_equal(m, ref)


def test_session_warm_matches_cold_across_backends():
    g = _graph()
    for backend, grid in (("single", dict()),
                          ("serial", dict(mu_v=2, mu_s=2))):
        spec = _spec(backend=backend, **grid)
        sess = InfluenceSession(g, spec)
        cold = sess.find_seeds(4)
        warm = sess.find_seeds_warm(4)
        np.testing.assert_array_equal(cold.seeds, warm.seeds)
        np.testing.assert_array_equal(cold.scores, warm.scores)


def test_build_sketch_matrix_canonical_across_backends():
    g = _graph()
    m_single, _, x1 = InfluenceSession(g, _spec(backend="single")).build_sketch_matrix()
    m_serial, _, x2 = InfluenceSession(
        g, _spec(backend="serial", mu_v=2, mu_s=2,
                 partition="random")).build_sketch_matrix()
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(m_single), np.asarray(m_serial))


# ---------------------------------------------------------------------------
# GraphDelta repair through the serial backend (the acceptance criterion)
# ---------------------------------------------------------------------------


#: n=48 pads to n_pad=56, so a mu_v=2 block plan owns rows [0, 28) / [28, 56)
_CUT = 28
_N = 48


def _two_community_graph(seed: int = 4):
    """Two disconnected communities split at the block plan's shard boundary
    (``_CUT == n_loc``): community A on ids [0, 28) lands in shard 0,
    community B on [28, 48) in shard 1, so a delta inside one community must
    repair exactly one plan shard."""
    rng = np.random.default_rng(seed)
    m_half = _N * 4
    a_src = rng.integers(0, _CUT, m_half)
    a_dst = rng.integers(0, _CUT, m_half)
    b_src = rng.integers(_CUT, _N, m_half)
    b_dst = rng.integers(_CUT, _N, m_half)
    src = np.concatenate([a_src, b_src])
    dst = np.concatenate([a_dst, b_dst])
    w = np.full(src.shape[0], 0.35, dtype=np.float32)
    g = Graph.from_edges(_N, src, dst, w)
    assert g.n_pad == 2 * _CUT, "padding layout moved; realign _CUT"
    return g


def _delta(src, dst):
    return GraphDelta(
        add_src=np.asarray(src, np.int64), add_dst=np.asarray(dst, np.int64),
        add_weight=np.full(len(src), 0.9, np.float32),
        rem_src=np.zeros(0, np.int64), rem_dst=np.zeros(0, np.int64))


def _store_with_plan(g, cfg, mu_v=2):
    store = SketchStore()
    entry = store.get_or_build(g, cfg)
    plan = plan_partition(entry.graph, mu_v, mu_s=1, strategy="block",
                          x=entry.x, seed=cfg.seed)
    store.attach_plan(entry.key, plan)
    return store, entry


def test_delta_repair_serial_backend_touches_only_dirty_shards():
    g = _two_community_graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    # delta strictly inside community B -> plan shard 1 only
    delta = _delta([_CUT + 1, _CUT + 3], [_CUT + 5, _CUT + 2])

    store, entry = _store_with_plan(g, cfg)
    rep = apply_delta(store, entry.key, delta, backend="serial")
    assert rep.repair_backend == "serial"
    assert rep.plan_shards_touched == (1,)
    # only the dirtied shard re-propagated: the communities are disconnected,
    # so the restricted sweeps can never escape shard 1
    assert rep.shards_swept == (1,)
    assert rep.repair_sweeps > 0 and not rep.rebuilt
    m_repaired = np.asarray(store.entry(entry.key).matrix)

    # bit-identical to a full pristine rebuild of the post-delta graph
    ref_store = SketchStore()
    m_rebuild = np.asarray(
        ref_store.get_or_build(entry.graph, cfg).matrix)
    np.testing.assert_array_equal(m_repaired, m_rebuild)

    # and to the historical per-bank single-device repair
    store2, entry2 = _store_with_plan(g, cfg)
    apply_delta(store2, entry2.key, delta)   # backend=None -> legacy path
    np.testing.assert_array_equal(
        m_repaired, np.asarray(store2.entry(entry2.key).matrix))


def test_delta_repair_serial_backend_spreads_when_it_must():
    """A cross-community delta dirties both shards; the repair still matches
    the rebuild bit-for-bit."""
    g = _two_community_graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    delta = _delta([1], [_CUT + 7])          # A -> B bridge edge

    store, entry = _store_with_plan(g, cfg)
    rep = apply_delta(store, entry.key, delta, backend="serial")
    assert set(rep.plan_shards_touched) == {0, 1}
    assert set(rep.shards_swept) >= set(rep.plan_shards_touched)
    m_repaired = np.asarray(store.entry(entry.key).matrix)
    m_rebuild = np.asarray(
        SketchStore().get_or_build(entry.graph, cfg).matrix)
    np.testing.assert_array_equal(m_repaired, m_rebuild)


def test_delta_repair_without_plan_falls_back_to_legacy():
    g = _two_community_graph()
    cfg = DiFuserConfig(num_registers=128, seed=3)
    store = SketchStore()
    entry = store.get_or_build(g, cfg)      # no plan attached
    rep = apply_delta(store, entry.key, _delta([2], [5]), backend="serial")
    assert rep.repair_backend == "single"   # graceful fallback
    assert rep.shards_swept == ()
    m_after = np.asarray(store.entry(entry.key).matrix)
    m_ref = np.asarray(SketchStore().get_or_build(entry.graph, cfg).matrix)
    np.testing.assert_array_equal(m_after, m_ref)


def test_session_apply_delta_routes_backend():
    g = _two_community_graph()
    spec = _spec(backend="serial", mu_v=2, mu_s=1)
    sess = InfluenceSession(g, spec)
    entry = sess.entry()
    plan = plan_partition(entry.graph, 2, mu_s=1, strategy="block",
                          x=entry.x, seed=spec.seed)
    sess.store.attach_plan(entry.key, plan)
    rep = sess.apply_delta(_delta([_CUT + 1], [_CUT + 9]))
    assert rep.repair_backend == "serial"
    assert rep.plan_shards_touched == (1,)
    # seeds after the delta still match a cold run on the post-delta graph
    post = run(entry.graph, 3, _spec(backend="single")).result
    warm = sess.find_seeds_warm(3)
    np.testing.assert_array_equal(post.seeds, warm.seeds)


# ---------------------------------------------------------------------------
# fixpoint / cascade backend hooks
# ---------------------------------------------------------------------------


def test_backend_hooks_fixpoint_and_cascade():
    from repro.core.difuser import normalize_inputs
    from repro.core.sketch import VISITED

    g = _graph()
    spec = _spec()
    gn, xn = normalize_inputs(g, spec.difuser_config())
    single = get_backend("single")
    m, _ = single.build_matrix(gn, spec, xn, normalized=True)

    # a propagated matrix is already at fixpoint: both hooks are no-ops
    m_fix, _ = single.fixpoint(m, gn, spec, xn)
    np.testing.assert_array_equal(np.asarray(m_fix), np.asarray(m))
    serial = get_backend("serial")
    m_fix2, _ = serial.fixpoint(np.asarray(m), gn,
                                spec.with_(mu_v=2, mu_s=2), xn)
    np.testing.assert_array_equal(np.asarray(m_fix2), np.asarray(m))

    # cascade: committing a seed floods its row (and matches the in-loop op)
    s = int(run(g, 1, spec).result.seeds[0])
    m_casc, _ = single.cascade(m, s, gn, spec, xn)
    assert (np.asarray(m_casc)[s] == VISITED).all()
    with pytest.raises(NotImplementedError):
        serial.cascade(np.asarray(m), s, gn, spec, xn)
    # shard_repair protocol: only capable backends implement it
    with pytest.raises(NotImplementedError):
        single.repair_plan_shards(gn, spec, xn, np.asarray(m), None, (0,))
