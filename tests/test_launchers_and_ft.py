"""Launcher CLIs + fault-tolerance supervisor behavior."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest


def test_im_cli_end_to_end(capsys):
    from repro.launch.im import run

    out = run(["--graph", "rmat:8", "--setting", "0.1", "--k", "5",
               "--registers", "128", "--validate"])
    assert out["difuser_score"] > 0
    assert out["oracle_score"] > 0
    rel = abs(out["difuser_score"] - out["oracle_score"]) / out["oracle_score"]
    assert rel < 0.25


def test_train_cli_resumes_from_checkpoint(tmp_path):
    from repro.launch.train import run

    ck = str(tmp_path / "ck")
    args = ["--arch", "tinyllama-1.1b", "--reduced", "--width", "64", "--layers", "2",
            "--steps", "6", "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
            "--ckpt-every", "3"]
    run(args)
    # resume: should start from step 6 checkpoint and do nothing more
    m = run(args)
    assert np.isfinite(m["final_loss"]) or np.isnan(m["final_loss"])  # resumed at end
    from repro.train.checkpoint import latest_step

    assert latest_step(ck) == 6


def test_ft_supervisor_restarts_until_success(tmp_path):
    """A command that fails twice then succeeds is relaunched transparently."""
    from repro.launch.ft import supervise

    marker = tmp_path / "attempts"
    script = (
        "import sys, pathlib\n"
        f"p = pathlib.Path({str(marker)!r})\n"
        "n = int(p.read_text()) if p.exists() else 0\n"
        "p.write_text(str(n + 1))\n"
        "sys.exit(0 if n >= 2 else 1)\n"
    )
    rc = supervise([sys.executable, "-c", script], max_restarts=5)
    assert rc == 0
    assert marker.read_text() == "3"


def test_ft_supervisor_gives_up(tmp_path):
    from repro.launch.ft import supervise

    rc = supervise([sys.executable, "-c", "import sys; sys.exit(3)"], max_restarts=1)
    assert rc == 3


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir from a killed writer is ignored and overwritten."""
    from repro.train.checkpoint import latest_step, restore, save

    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated crash debris
    save(d, 9, {"a": np.arange(4)})
    assert latest_step(d) == 9
    step, tree = restore(d)
    np.testing.assert_array_equal(tree["a"], np.arange(4))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on one 'topology', restore onto another sharding layout."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import JAX_HAS_AXIS_TYPE

    if not JAX_HAS_AXIS_TYPE:
        pytest.skip("jax.sharding.AxisType missing (old jax) — API drift")

    from repro.train.checkpoint import restore_sharded, save

    d = str(tmp_path / "ck")
    x = np.arange(64, dtype=np.float32).reshape(8, 8)
    save(d, 1, {"w": x})
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1],
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, tree = restore_sharded(d, sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), x)
