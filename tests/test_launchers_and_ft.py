"""Launcher CLIs + fault-tolerance supervisor behavior."""
import sys


def test_im_cli_end_to_end(capsys):
    from repro.launch.im import run

    out = run(["--graph", "rmat:8", "--setting", "0.1", "--k", "5",
               "--registers", "128", "--validate"])
    assert out["difuser_score"] > 0
    assert out["oracle_score"] > 0
    rel = abs(out["difuser_score"] - out["oracle_score"]) / out["oracle_score"]
    assert rel < 0.25


def test_ft_supervisor_restarts_until_success(tmp_path):
    """A command that fails twice then succeeds is relaunched transparently."""
    from repro.launch.ft import supervise

    marker = tmp_path / "attempts"
    script = (
        "import sys, pathlib\n"
        f"p = pathlib.Path({str(marker)!r})\n"
        "n = int(p.read_text()) if p.exists() else 0\n"
        "p.write_text(str(n + 1))\n"
        "sys.exit(0 if n >= 2 else 1)\n"
    )
    rc = supervise([sys.executable, "-c", script], max_restarts=5)
    assert rc == 0
    assert marker.read_text() == "3"


def test_ft_supervisor_gives_up(tmp_path):
    from repro.launch.ft import supervise

    rc = supervise([sys.executable, "-c", "import sys; sys.exit(3)"], max_restarts=1)
    assert rc == 3


def test_elastic_snapshot_roundtrip(tmp_path):
    """The FT story's index half: a server relaunch restores the persisted
    SketchStore snapshot (plan included) instead of re-running the cold
    fixpoint, on any topology (host restore here; mesh restore is the
    AxisType-guarded half in test_sharded_serving.py)."""
    import numpy as np

    from repro.core.difuser import DiFuserConfig
    from repro.graphs import rmat_graph
    from repro.partition import plan_partition
    from repro.service import SketchStore

    g = rmat_graph(7, edge_factor=6, seed=2, setting="w1")
    cfg = DiFuserConfig(num_registers=64, seed=2)
    store = SketchStore()
    e = store.get_or_build(g, cfg)
    store.attach_plan(e.key, plan_partition(e.graph, 4, mu_s=1, x=e.x))
    path = str(tmp_path / "index")
    store.save(path, e.key)
    restored = SketchStore().load(path)
    np.testing.assert_array_equal(np.asarray(restored.matrix),
                                  np.asarray(e.matrix))
    assert restored.plan is not None and restored.plan.mu_v == 4
    assert restored.residency == "host"
