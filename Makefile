# One entry point for the repo's verify/bench/lint loops.
#
#   make test           tier-1 suite (the ROADMAP verify command)
#   make test-property  hypothesis property suite (needs requirements-dev.txt)
#   make bench-smoke    fast benchmark pass (small graphs, CI-sized) +
#                       model-zoo smoke (every registered diffusion model)
#   make lint           syntax + import sanity over src/tests/benchmarks/scripts

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-property bench-smoke lint

test:
	python -m pytest -x -q

test-property:
	python -m pytest -q tests/test_property.py

bench-smoke:
	python scripts/check_models.py
	python -m benchmarks.run --fast

# lint's import check covers the IM API surface (repro.IM_API_MODULES and
# friends). The LM seed-template modules were deleted in PR 5; everything
# left is importable API.
lint:
	python -m compileall -q src tests benchmarks examples scripts
	python -c "import importlib; [importlib.import_module(m) for m in ('repro', 'repro.obs', 'repro.obs.trace', 'repro.obs.metrics', 'repro.obs.shardprof', 'repro.obs.slo', 'repro.obs.flight', 'repro.obs.report', 'repro.runtime', 'repro.runtime.session', 'repro.core.difuser', 'repro.diffusion', 'repro.diffusion.models', 'repro.partition', 'repro.partition.serial', 'repro.service', 'repro.service.engine', 'repro.kernels.fused_sweep', 'repro.tune', 'repro.tune.config', 'repro.tune.cache', 'repro.tune.autotuner', 'repro.configs', 'repro.launch.common', 'repro.launch.serve_im', 'repro.__main__', 'benchmarks.model_zoo', 'benchmarks.partition_balance', 'benchmarks.runtime_bench', 'benchmarks.trend')]; print('imports ok')"
