# One entry point for the repo's verify/bench/lint loops.
#
#   make test         tier-1 suite (the ROADMAP verify command)
#   make bench-smoke  fast benchmark pass (small graphs, CI-sized)
#   make lint         syntax + import sanity over src/tests/benchmarks

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench-smoke lint

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --fast

lint:
	python -m compileall -q src tests benchmarks examples
	python -c "import importlib; [importlib.import_module(m) for m in ('repro', 'repro.core.difuser', 'repro.service', 'repro.service.engine', 'repro.launch.serve_im')]; print('imports ok')"
