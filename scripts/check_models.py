"""Model-zoo smoke check: every registered diffusion model builds a
1k-vertex sketch end to end.

    PYTHONPATH=src python scripts/check_models.py

Wired into ``make bench-smoke`` so CI catches a model whose host
preprocessing or fused predicate stopped composing with the kernel stack.
Exit code is non-zero on any failure.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.difuser import DiFuserConfig, build_sketch_matrix
from repro.runtime import RunSpec, run as run_im
from repro.diffusion import available_models, resolve
from repro.graphs import erdos_renyi_graph

SMOKE_SPECS = {"ic": "ic:0.1", "wc": "wc", "lt": "lt", "dic": "dic:1.0"}


def main() -> int:
    g = erdos_renyi_graph(1024, avg_degree=8, seed=0, setting="w1")
    failures = 0
    for name in available_models():
        spec = SMOKE_SPECS.get(name, name)
        try:
            mdl = resolve(spec)
            cfg = DiFuserConfig(num_registers=64, seed=0, model=spec)
            m, iters, x = build_sketch_matrix(g, cfg)
            assert m.shape == (g.n_pad, 64), m.shape
            assert iters >= 1, iters
            # at least one register must carry signal (not all VISITED)
            assert int(np.asarray((m != -1).sum())) > 0
            res = run_im(g, 2, RunSpec.from_config(cfg)).result
            assert len(set(res.seeds.tolist())) == 2
            assert np.isfinite(res.scores).all()
            print(f"check_models.{spec}: ok "
                  f"(build {iters} sweeps, spread {res.scores[-1]:.1f}, "
                  f"context_free_edges={mdl.context_free_edges})")
        except Exception as e:  # noqa: BLE001 — report every model, then fail
            failures += 1
            print(f"check_models.{spec}: FAIL {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
