"""Train a reduced tinyllama-family LM for a few hundred steps on CPU with
the full production substrate: AdamW, remat, grad clipping, checkpointing,
deterministic restartable data pipeline.

    PYTHONPATH=src python examples/train_lm.py            # ~25M params, 200 steps
    PYTHONPATH=src python examples/train_lm.py --full100m # ~100M params (slower)
"""
import sys

from repro.launch.train import run

argv = [
    "--arch", "tinyllama-1.1b", "--reduced",
    "--width", "256", "--layers", "4",
    "--steps", "200", "--batch", "8", "--seq", "128",
    "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
]
if "--full100m" in sys.argv:
    argv = [
        "--arch", "tinyllama-1.1b", "--reduced",
        "--width", "512", "--layers", "8",
        "--steps", "300", "--batch", "8", "--seq", "256",
        "--lr", "6e-4", "--ckpt-dir", "/tmp/repro_train_lm_100m",
    ]

metrics = run(argv)
print(f"\nfirst loss {metrics['first_loss']:.3f} -> final loss {metrics['final_loss']:.3f}")
assert metrics["final_loss"] < metrics["first_loss"], "training did not learn"
