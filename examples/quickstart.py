"""Quickstart: find influential seeds in a small social graph with DiFuseR.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.baselines import influence_score
from repro.runtime import InfluenceSession, RunSpec
from repro.graphs import rmat_graph

# a power-law graph standing in for a social network (n=1024, ~8k edges)
graph = rmat_graph(10, edge_factor=8, seed=0, setting="w1")
print(f"graph: n={graph.n:,} vertices, m={graph.m_real:,} edges")

# DiFuseR with J=512 registers (one FM register per Monte-Carlo simulation);
# backend="auto" resolves the execution strategy for this environment
spec = RunSpec(num_registers=512, seed=0)
result = InfluenceSession(graph, spec).find_seeds(10)

print(f"seed set:          {result.seeds.tolist()}")
print(f"estimated spread:  {result.scores[-1]:.1f} vertices")
print(f"sketch rebuilds:   {int(result.rebuilds.sum())}/10 rounds (lazy rebuild, e=0.01)")

# validate against the independent Monte-Carlo oracle (paper §5.1)
oracle = influence_score(graph, result.seeds, num_sims=200)
print(f"oracle spread:     {oracle:.1f} vertices "
      f"(relative error {abs(oracle - result.scores[-1]) / oracle * 100:.1f}%)")

# FASST in action: the sorted random vector clusters correlated samples
from repro.core.fasst import lane_fill_rate
from repro.core.sampling import make_x_vector

x_unsorted = make_x_vector(512, seed=0)  # what a naive run would use
fill_naive = lane_fill_rate(graph, x_unsorted)
fill_fasst = lane_fill_rate(graph, np.sort(x_unsorted))
print(f"VPU lane fill:     naive {fill_naive*100:.0f}% -> FASST {fill_fasst*100:.0f}%")
