"""End-to-end driver (the paper's kind of workload): distributed influence
maximization over a larger synthetic social network on a 2x4 device mesh,
with FASST sample-space tasking, ring-schedule propagation, quality
validation, and the paper's Table-5/7 metrics printed along the way.

    PYTHONPATH=src python examples/distributed_im.py
(re-executes itself with 8 fake XLA devices if needed)
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time

import numpy as np

from repro.baselines import influence_score, ris_find_seeds
from repro.core.fasst import build_partition, duplication_histogram, max_shard_fraction
from repro.core.sampling import make_x_vector
from repro.graphs import rmat_graph
from repro.runtime import RunSpec, run as run_im

K, J = 20, 512
graph = rmat_graph(12, edge_factor=8, seed=7, setting="u01")
print(f"graph: n={graph.n:,} m={graph.m_real:,} (RMAT, U(0,0.1) weights)")

# --- FASST structural metrics (paper Tables 5/7) ---
x = make_x_vector(J, seed=0)
for method in ("naive", "fasst"):
    part = build_partition(graph, x, 4, method=method)
    hist = duplication_histogram(graph, part)
    print(f"{method:6s}: max-shard {max_shard_fraction(graph, part)*100:4.0f}% of edges; "
          f"exactly-1-shard {hist[1]*100:4.0f}%")

# --- sharded run: 2-way vertex x 4-way sample-space grid; "auto" picks the
# shard_map mesh when jax supports it, else the serial-ring twin ---
spec = RunSpec(num_registers=J, seed=0, schedule="ring", mu_v=2, mu_s=4)
t0 = time.time()
dreport = run_im(graph, K, spec)
dres = dreport.result
t_dist = time.time() - t0
print(f"\nsharded (2x4 {dreport.backend}, ring): {t_dist:.1f}s "
      f"spread={dres.scores[-1]:.0f} rebuilds={int(dres.rebuilds.sum())}/{K}")

# --- single-device reference: must agree bit-for-bit ---
t0 = time.time()
sres = run_im(graph, K, spec.with_(backend="single")).result
print(f"single-device:                {time.time()-t0:.1f}s "
      f"spread={sres.scores[-1]:.0f}")
assert (sres.seeds == dres.seeds).all(), "distributed != single-device!"
print("distributed == single-device: bitwise identical seeds")

# --- quality vs the RIS/IMM baseline (gIM/cuRipples family) ---
ris_seeds, _ = ris_find_seeds(graph, K, num_rr_sets=4000)
o_ours = influence_score(graph, dres.seeds, num_sims=100)
o_ris = influence_score(graph, ris_seeds, num_sims=100)
print(f"oracle: difuser={o_ours:.0f} ris={o_ris:.0f} "
      f"(quality ratio {o_ours/o_ris:.3f}; paper reports ~1.00-1.02x)")
