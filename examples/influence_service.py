"""Influence-as-a-service: build the sketch index once, answer ~1k queries.

    PYTHONPATH=src python examples/influence_service.py

The cold path (``find_seeds``) pays fill + propagate-to-fixpoint on every
call. The service keeps the propagated register matrix resident in a
SketchStore, so top-k selection, spread estimates, and marginal gains are
register reductions — then repairs the index in place when the graph gains
edges.
"""
import time

import numpy as np

from repro.core.difuser import DiFuserConfig
from repro.runtime import RunSpec, run as run_im
from repro.graphs import rmat_graph
from repro.graphs.structs import GraphDelta
from repro.launch.serve_im import make_workload
from repro.service import (InfluenceEngine, SketchStore, TopKSeeds,
                           apply_delta, summarize_latencies)

graph = rmat_graph(12, edge_factor=8, seed=0, setting="w1")
print(f"graph: n={graph.n:,} vertices, m={graph.m_real:,} edges")
# explicit diffusion model id ("wc" = the backward-compatible default);
# the model is part of the SketchStore key, so one engine can serve
# ic/lt/dic indexes of the same graph side by side
config = DiFuserConfig(num_registers=512, seed=0, model="wc")

# --- cold baseline: one offline batch answer, full build every call -------
t0 = time.perf_counter()
cold = run_im(graph, 10, RunSpec.from_config(config)).result
cold_s = time.perf_counter() - t0
print(f"cold find_seeds:   {cold_s:.2f}s -> seeds {cold.seeds[:5].tolist()}...")

# --- warm service: build once, then ~1k mixed queries ---------------------
store = SketchStore()
engine = InfluenceEngine(store)
key = engine.register(graph, config)
print(f"index build:       {store.entry(key).build_time_s:.2f}s (one-time)")

for q in make_workload(graph.n, 1000, k=10, seed=7):
    engine.submit(key, q)
t0 = time.perf_counter()
results = engine.run()
wall_s = time.perf_counter() - t0
stats = summarize_latencies(results)
print(f"1000 mixed queries: {wall_s:.2f}s "
      f"({1000 / wall_s:.0f} qps, p50 {stats['p50_ms']:.2f}ms, "
      f"p99 {stats['p99_ms']:.2f}ms)")
print(f"amortized:         {wall_s:.1f}ms/query vs {cold_s * 1e3:.0f}ms cold "
      f"-> {cold_s / (wall_s / 1000):.0f}x per query")

# warm top-k agrees with the cold run bit-for-bit
warm = engine(key, TopKSeeds(10)).value
assert np.array_equal(warm.seeds, cold.seeds), "warm top-k must match cold"
print(f"warm TopKSeeds == cold find_seeds: {warm.seeds[:5].tolist()}... ✓")

# --- the graph changes: repair the index instead of rebuilding ------------
rng = np.random.default_rng(1)
delta = GraphDelta.make(add=(rng.integers(0, graph.n, 64),
                             rng.integers(0, graph.n, 64)))
report = apply_delta(store, key, delta)
print(f"delta(+64 edges):  repaired in {report.time_s:.2f}s "
      f"({report.repair_sweeps} sweeps, {report.banks_touched} bank(s)) "
      f"vs {store.entry(key).build_time_s:.2f}s rebuild")
fresh = engine(key, TopKSeeds(10)).value
print(f"post-delta top-10: {fresh.seeds[:5].tolist()}...")

# --- mixed-model traffic: one engine, distinct store keys per model --------
lt_key = engine.register(graph, DiFuserConfig(num_registers=512, seed=0, model="lt"))
assert lt_key != key, "model id must separate store keys"
lt_top = engine(lt_key, TopKSeeds(10)).value
print(f"lt model top-10:   {lt_top.seeds[:5].tolist()}... "
      f"({len(store)} model-keyed indexes resident)")
