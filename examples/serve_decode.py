"""Serve a small model with batched requests: prefill the prompts once,
then step the KV cache one token at a time (the decode_32k cell's job, at
example scale). Runs the SSM family too to show the O(1)-state decode path.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.transformer import init_params
from repro.serve import Engine, ServeConfig

for arch in ("tinyllama-1.1b", "mamba2-780m"):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(temperature=0.8, seed=1))

    batch, prompt_len, gen = 8, 64, 32
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)

    t0 = time.time()
    out = engine.generate(prompts, gen)
    dt = time.time() - t0
    print(f"{arch:16s} ({cfg.family:7s}): {batch} seqs x {gen} new tokens "
          f"in {dt:.2f}s ({batch*gen/dt:.0f} tok/s)  sample: {out[0][:8].tolist()}")
