"""Paper Tables 3/4: execution time + influence score, DiFuseR vs the
RIS/IMM baseline (the gIM/cuRipples algorithm family), scored by the
independent MC oracle. Synthetic RMAT graphs stand in for the SNAP
datasets (CPU container); all five influence settings run.

derived column: quality ratio oracle(difuser)/oracle(ris) — the paper
reports 1.02x (Table 3) / 1.00x (Table 4).
"""
from __future__ import annotations

from benchmarks.common import SETTING_KEYS, SETTINGS, emit, timed
from repro.baselines import influence_score, ris_find_seeds
from repro.core.difuser import DiFuserConfig
from repro.runtime import RunSpec, run as run_im
from repro.graphs import rmat_graph


def main(scale: int = 10, k: int = 10, registers: int = 256) -> None:
    for setting in SETTINGS:
        g = rmat_graph(scale, edge_factor=8, seed=31, setting=SETTING_KEYS[setting])
        cfg = DiFuserConfig(num_registers=registers, seed=0)
        spec = RunSpec.from_config(cfg, backend="single")
        report, dif_us = timed(run_im, g, k, spec)
        res = report.result
        (ris_seeds, _), ris_us = timed(ris_find_seeds, g, k, num_rr_sets=3000)
        o_dif = influence_score(g, res.seeds, num_sims=100, rng_seed=77)
        o_ris = influence_score(g, ris_seeds, num_sims=100, rng_seed=77)
        q = o_dif / max(o_ris, 1e-9)
        emit(f"table3.difuser.{setting}", dif_us, f"score={o_dif:.1f}")
        emit(f"table3.ris.{setting}", ris_us, f"score={o_ris:.1f}")
        emit(f"table3.quality_ratio.{setting}", 0.0, f"{q:.3f}")


if __name__ == "__main__":
    main()
