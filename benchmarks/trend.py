"""CI perf-trend gate: compare fresh BENCH_*.json records against the
previous CI run's artifacts and fail on significant regressions.

    PYTHONPATH=src python -m benchmarks.trend --baseline-dir bench-baseline \
        [--threshold 0.2] [BENCH_runtime.json BENCH_service.json]

``benchmarks/run.py --fast`` calls :func:`compare` automatically when a
baseline directory is configured (``--baseline-dir`` / the
``BENCH_BASELINE_DIR`` env var, which CI points at the downloaded artifact
of the previous run) and exits non-zero when any tracked metric moved the
wrong way by more than ``threshold`` (20% by default). Metrics carry a
direction: throughput metrics (per-backend cold/warm seeds/sec from
``BENCH_runtime.json``, host/device qps from ``BENCH_service.json``,
tuned-kernel speedups from ``BENCH_kernels.json``) are higher-is-better and
regress on drops; tail-latency/sweep-time metrics (host/device p99 ms,
per-family tuned_us) are lower-is-better and regress on rises. A missing baseline (first run, expired artifact) skips cleanly: the
gate compares trajectories, it doesn't demand one exists.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Iterator, Optional

from benchmarks.common import emit

DEFAULT_FILES = ("BENCH_runtime.json", "BENCH_service.json",
                 "BENCH_kernels.json")


def _load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


#: metric directions: "higher" regresses on drops, "lower" on rises
HIGHER, LOWER = "higher", "lower"


def _runtime_metrics(rec: dict) -> Iterator[tuple[str, float, str]]:
    """(metric name, seeds/sec, direction) per available backend."""
    for name, b in (rec.get("backends") or {}).items():
        if not b.get("available"):
            continue
        for kind in ("seeds_per_s_cold", "seeds_per_s_warm"):
            if b.get(kind):
                yield f"{name}.{kind}", float(b[kind]), HIGHER


def _service_metrics(rec: dict) -> Iterator[tuple[str, float, str]]:
    """(metric name, value, direction) for host/device serving rows:
    qps (higher-is-better) and tail latency p99 (lower-is-better)."""
    for row in ("host", "device"):
        stats = rec.get(row)
        if not stats:
            continue
        if stats.get("qps"):
            yield f"{row}.qps", float(stats["qps"]), HIGHER
        if stats.get("p99_ms"):
            yield f"{row}.p99_ms", float(stats["p99_ms"]), LOWER
    # async admission pipeline: sustained open-loop throughput and e2e tail
    stats = rec.get("async")
    if stats:
        if stats.get("sustained_qps"):
            yield "async.sustained_qps", float(stats["sustained_qps"]), HIGHER
        if stats.get("p99_ms"):
            yield "async.p99_ms", float(stats["p99_ms"]), LOWER


def _kernel_metrics(rec: dict) -> Iterator[tuple[str, float, str]]:
    """(metric name, value, direction) per tuned kernel family: tuned sweep
    time (lower-is-better) and tuned-over-default speedup (higher — a
    speedup collapsing toward 1x means the tuner stopped finding wins).
    Families come straight from the record, so ``fused_sweep.tuned_us`` /
    ``fused_sweep.speedup`` are gated the same way as the older families."""
    for family, r in (rec.get("kernels") or {}).items():
        if r.get("tuned_us"):
            yield f"{family}.tuned_us", float(r["tuned_us"]), LOWER
        if r.get("speedup"):
            yield f"{family}.speedup", float(r["speedup"]), HIGHER


_METRICS = {"BENCH_runtime.json": _runtime_metrics,
            "BENCH_service.json": _service_metrics,
            "BENCH_kernels.json": _kernel_metrics}


def compare(baseline_dir: str, files=DEFAULT_FILES, *,
            threshold: float = 0.2) -> int:
    """Emit one CSV row per tracked metric; returns the regression count."""
    regressions = 0
    for name in files:
        cur = _load(name)
        base = _load(os.path.join(baseline_dir, name))
        if cur is None:
            emit(f"trend.{name}", 0.0, "skipped: no current record")
            continue
        if base is None:
            emit(f"trend.{name}", 0.0, "skipped: no baseline artifact")
            continue
        metrics_fn = _METRICS.get(name, _runtime_metrics)
        baseline = {m: v for m, v, _ in metrics_fn(base)}
        for metric, new, direction in metrics_fn(cur):
            old = baseline.get(metric)
            if not old:
                emit(f"trend.{name}.{metric}", 0.0, f"new metric ({new:.2f})")
                continue
            ratio = new / old
            if direction == LOWER:
                ok = ratio <= 1.0 + threshold      # latency rising = bad
            else:
                ok = ratio >= 1.0 - threshold      # throughput dropping = bad
            verdict = "ok" if ok else "REGRESSION"
            if verdict == "REGRESSION":
                regressions += 1
            emit(f"trend.{name}.{metric}", 0.0,
                 f"{verdict} {new:.2f} vs {old:.2f} ({ratio:.2f}x, "
                 f"{direction}-is-better)")
    return regressions


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--baseline-dir", required=True)
    ap.add_argument("--threshold", type=float, default=0.2)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    n = compare(args.baseline_dir, args.files or DEFAULT_FILES,
                threshold=args.threshold)
    if n:
        print(f"trend: {n} metric(s) regressed > "
              f"{args.threshold:.0%}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
