"""Kernel microbenchmarks: per-sweep timings of the five DiFuseR kernels
(ref implementations under XLA:CPU — on TPU the same harness times the
Pallas kernels with interpret=False).

derived: throughput in (edge, register) pairs per second for the sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.sampling import make_x_vector, weight_to_threshold
from repro.graphs import rmat_graph
from repro.kernels import ops


def main(scale: int = 12, registers: int = 512) -> None:
    g = rmat_graph(scale, edge_factor=8, seed=71, setting="w1").sorted_by_dst()
    x = jnp.asarray(make_x_vector(registers, seed=3))
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    thr = jnp.asarray(weight_to_threshold(g.weight))
    m = ops.sketch_fill(jnp.zeros((g.n_pad, registers), jnp.int8))
    pairs = g.m * registers

    block = jax.block_until_ready
    _, us = timed(lambda: block(ops.sketch_fill(m)), warmup=2, iters=5)
    emit("kernel.sketch_fill", us, f"{g.n_pad * registers / (us/1e6):.3g} regs/s")
    _, us = timed(lambda: block(ops.fused_sample(src, dst, thr, x)), warmup=2, iters=5)
    emit("kernel.fused_sample", us, f"{pairs / (us/1e6):.3g} pair/s")
    _, us = timed(lambda: block(ops.propagate_sweep(m, src, dst, thr, x)), warmup=2, iters=5)
    emit("kernel.propagate_sweep", us, f"{pairs / (us/1e6):.3g} pair/s")
    mv = m.at[0].set(-1)
    _, us = timed(lambda: block(ops.cascade_sweep(mv, src, dst, thr, x)), warmup=2, iters=5)
    emit("kernel.cascade_sweep", us, f"{pairs / (us/1e6):.3g} pair/s")
    _, us = timed(lambda: block(ops.cardinality_stats(m)), warmup=2, iters=5)
    emit("kernel.cardinality_stats", us, f"{g.n_pad * registers / (us/1e6):.3g} regs/s")


if __name__ == "__main__":
    main()
