"""Kernel microbenchmarks: tuned-vs-default per-sweep timings of the DiFuseR
kernels (ref implementations under XLA:CPU — on TPU the same harness times
the Pallas kernels with interpret=False).

The tunable sweep families (plus ``fused_sweep``, measured at the
register-heavy scale-10/R=2048 shape where multi-sweep fusion and lane-fill
slabbing actually move the needle) go through :func:`repro.tune.autotune`, so
every row reports the hard-coded default against the measured winner (same
timing discipline: min-of-N, device-synced spans, roofline-annotated GB/s)
and the winners land in the persistent ``TUNE_cache.json``. With
``out_json`` the full records are written as ``BENCH_kernels.json`` —
a first-class artifact :mod:`benchmarks.trend` gates on.

derived: tuned-over-default speedup for the tuned families; throughput for
the untuned kernels.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops
from repro.graphs import rmat_graph
from repro.runtime.spec import RunSpec
from repro.tune import SWEEP_FAMILIES, autotune, default_cache


def main(scale: int = 12, registers: int = 512,
         out_json: str | None = None, fused_scale: int = 10,
         fused_registers: int = 2048) -> dict:
    g = rmat_graph(scale, edge_factor=8, seed=71, setting="w1").sorted_by_dst()
    spec = RunSpec(num_registers=registers, seed=3)
    records = autotune(g, spec, backend="single",
                       families=SWEEP_FAMILIES, cache=default_cache())

    # fused_sweep is measured at a fixed register-heavy shape (scale 10,
    # R=2048) regardless of the sweep-family shape above: the fused win is
    # register-bandwidth-bound — lane-fill slabbing only has something to
    # keep resident when the full-width working set doesn't fit — so gating
    # it at a register-light shape would measure nothing
    if (fused_scale, fused_registers) == (scale, registers):
        gf, fspec = g, spec
    else:
        gf = rmat_graph(fused_scale, edge_factor=8, seed=71,
                        setting="w1").sorted_by_dst()
        fspec = RunSpec(num_registers=fused_registers, seed=3)
    records.update(autotune(gf, fspec, backend="single",
                            families=("fused_sweep",), cache=default_cache()))
    for family, rec in records.items():
        emit(f"kernel.{family}.default", rec["default_us"], "hard-coded")
        emit(f"kernel.{family}.tuned", rec["tuned_us"],
             f"{rec['speedup']:.3g}x @ {rec['tuned_gbps']:.3g} GB/s "
             f"({rec['frac_of_roof']:.2%} of roof)")

    # the two untuned (vertex-dimension) kernels, timed as before
    m = ops.sketch_fill(jnp.zeros((g.n_pad, registers), jnp.int8), seed=3)
    block = jax.block_until_ready
    _, us = timed(lambda: block(ops.sketch_fill(m, seed=3)), warmup=2, iters=5)
    emit("kernel.sketch_fill", us, f"{g.n_pad * registers / (us/1e6):.3g} regs/s")
    fill_us = us
    _, us = timed(lambda: block(ops.cardinality_stats(m)), warmup=2, iters=5)
    emit("kernel.cardinality_stats", us,
         f"{g.n_pad * registers / (us/1e6):.3g} regs/s")

    doc = {"scale": scale, "registers": registers, "edges": int(g.m),
           "fused_shape": {"scale": fused_scale,
                           "registers": fused_registers, "edges": int(gf.m)},
           "kernels": records,
           "untuned": {"sketch_fill": {"us": round(fill_us, 3)},
                       "cardinality_stats": {"us": round(us, 3)}}}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
    return doc


if __name__ == "__main__":
    main()
