"""Paper Table 9: communication overhead as a fraction of total step time.

Derived from the roofline model over the *measured structure*: per seed
round the distributed runtime moves

  ring:       sweeps x (mu_v - 1) x (n/mu_v) x J_loc bytes   (ppermute)
  selection:  psum of (2, n/mu_v) float32 over the sim axis + mu_v scalars

and computes  edges_local x J_loc x ~3 ops. Times use the assignment's
v5e constants (197 TFLOP/s, 819 GB/s, 50 GB/s link). The paper reports
1.4 - 5.4%; our 2-D partition should sit in the same band because FASST
bounds the busiest shard.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SETTING_KEYS, SETTINGS, emit
from repro.core.fasst import build_partition
from repro.core.sampling import make_x_vector
from repro.graphs import rmat_graph
from repro.partition import build_partition_2d, plan_partition, sample_edge_sets
from repro.utils.roofline import HBM_BW, ICI_BW

SWEEPS_PER_ROUND = 6  # measured propagate+cascade fixpoint sweeps (rmat graphs)


def main(scale: int = 11, registers: int = 1024, mu_v: int = 4, mu_s: int = 2,
         backend: str = "serial") -> None:
    # ``backend`` selects the runtime backend whose measured ring structure
    # (one real bucketed sweep + its Partition2D) grounds the 2-D rows; the
    # analytic model itself is backend-independent. Resolved (not just
    # looked up) so "auto" works like the sibling benchmarks' flag.
    from repro.runtime import RunSpec, resolve_backend

    backend_name = resolve_backend(
        RunSpec(num_registers=registers, backend=backend,
                mu_v=mu_v, mu_s=mu_s)).name
    x = make_x_vector(registers, seed=9)
    for setting in SETTINGS:
        g = rmat_graph(scale, edge_factor=8, seed=61, setting=SETTING_KEYS[setting])
        # --- paper-faithful sim-only partition (the paper's Table 9) ---
        # per seed round: selection psum of (2, n) f32 over mu devices; the
        # sweeps are comm-free (device-local graphs).
        part_sim = build_partition(g, x, mu_v * mu_s, method="fasst")
        j_sim = registers // (mu_v * mu_s)
        sweep_bytes = (g.n_pad * j_sim                      # register matrix
                       + float(part_sim.edge_counts.max()) * j_sim * 3.0)
        t_comp = SWEEPS_PER_ROUND * sweep_bytes / HBM_BW
        sel = 2 * g.n_pad * 4 * 2 * (mu_v * mu_s - 1) / (mu_v * mu_s) / ICI_BW
        frac = sel / (t_comp + sel)
        emit(f"table9.sim_only.{setting}", 0.0,
             f"comm={frac*100:.1f}% sel_B={sel*ICI_BW:.3g} (paper mode: 1.4-5.4%)")

        # --- beyond-paper 2-D partition: ring traffic per sweep, from the
        # *built* partition (measured busiest shard + per-step pad overhead
        # instead of the old uniform-split approximation) ---
        g2 = g.sorted_by_dst()
        sampled2 = sample_edge_sets(g2, x, mu_s, seed=9)
        for strat in ("block", "edge"):
            part2 = build_partition_2d(g2, x, mu_v, mu_s, seed=9,
                                       sampled=sampled2,
                                       plan=plan_partition(g2, mu_v, mu_s=mu_s,
                                                           strategy=strat,
                                                           sampled=sampled2,
                                                           seed=9))
            stats = part2.stats()
            j_loc = part2.j_loc
            n_loc = part2.n_loc
            # device sweep traffic: local register block + the device's
            # padded bucket slots (h, w, r, t, l operands ~ 3 useful reads).
            # Per-step widths are shared by every device, so padded slots
            # per device = total padded / (mu_v * mu_s) — dead slots are how
            # the straggler cost shows up under uniform shapes
            real_total = float(part2.p_counts.sum() + part2.c_counts.sum())
            padded_total = real_total / max(1.0 - stats.pad_waste_frac, 1e-9)
            padded_dev = padded_total / (mu_v * mu_s)
            sweep_bytes2 = n_loc * j_loc + padded_dev * j_loc * 3.0
            t_comp2 = SWEEPS_PER_ROUND * sweep_bytes2 / HBM_BW
            ring = SWEEPS_PER_ROUND * stats.ring_bytes_per_sweep / ICI_BW
            sel2 = 2 * n_loc * 4 * 2 * (mu_s - 1) / mu_s / ICI_BW
            frac2 = (ring + sel2) / (t_comp2 + ring + sel2)
            emit(f"table9.ring2d.{strat}.{setting}", 0.0,
                 f"comm={frac2*100:.1f}% ring_B={ring*ICI_BW:.3g} "
                 f"edge_imb={stats.edge_imbalance:.2f} "
                 f"backend={backend_name} "
                 f"(2-D mode trades ring traffic for n beyond HBM; "
                 f"planner shrinks the busiest-shard compute term)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--registers", type=int, default=1024)
    ap.add_argument("--backend", default="serial",
                    help="runtime backend grounding the 2-D rows "
                         "(repro.runtime registry)")
    a = ap.parse_args()
    main(scale=a.scale, registers=a.registers, backend=a.backend)
