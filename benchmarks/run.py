"""Benchmark harness: one module per paper table + kernel micro + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only tableX] [--fast] \
        [--baseline-dir bench-baseline]

Prints ``name,us_per_call,derived`` CSV rows (assignment contract). With a
baseline directory (``--baseline-dir`` or the ``BENCH_BASELINE_DIR`` env
var — CI points it at the previous run's artifact), the fresh
``BENCH_runtime.json`` / ``BENCH_service.json`` records are compared via
:mod:`benchmarks.trend` and the process exits non-zero on a >20% seeds/sec
or qps regression.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true", help="smaller graphs (CI)")
    ap.add_argument("--baseline-dir",
                    default=os.environ.get("BENCH_BASELINE_DIR", ""),
                    help="previous CI artifact dir with BENCH_*.json to "
                         "trend against (empty: no gate)")
    ap.add_argument("--regression-threshold", type=float, default=0.2)
    args = ap.parse_args()

    from benchmarks import (kernels_micro, model_zoo, partition_balance,
                            roofline_report, runtime_bench, service_throughput,
                            table8_scaling, table9_comm,
                            table34_quality_speed, table567_fasst)

    jobs = {
        "runtime": lambda: runtime_bench.main(
            scale=9 if args.fast else 10,
            registers=128 if args.fast else 256,
            k=4 if args.fast else 8,
            out_json="BENCH_runtime.json"),
        "partition": lambda: partition_balance.main(
            scale=9 if args.fast else 11,
            registers=128 if args.fast else 256,
            k=2 if args.fast else 4),
        "service": lambda: service_throughput.main(
            scale=11 if args.fast else 14,
            num_queries=50 if args.fast else 200,
            mu_v=4 if args.fast else 8,
            out_json="BENCH_service.json"),
        "model_zoo": lambda: model_zoo.main(
            scale=9 if args.fast else None,          # None -> preset graphs
            k=8 if args.fast else None,
            registers=256 if args.fast else None,
            num_sims=40 if args.fast else 120),
        "table34": lambda: table34_quality_speed.main(scale=9 if args.fast else 10),
        "table567": lambda: table567_fasst.main(scale=10 if args.fast else 11),
        "table8": lambda: table8_scaling.main(scale=10 if args.fast else 11),
        "table9": lambda: table9_comm.main(scale=10 if args.fast else 11),
        # register-heavy shape: the scan-chunk working set (chunk x R) is
        # what the tuner actually gets to move, so give it a workload where
        # the default chunk is measurably cache-hostile
        "kernels": lambda: kernels_micro.main(
            scale=10 if args.fast else 12,
            registers=2048 if args.fast else 512,
            out_json="BENCH_kernels.json"),
        "roofline": roofline_report.main,
    }
    # --fast (the CI sweep) records the run's spans + metrics as artifacts
    # next to the BENCH_*.json records: BENCH_trace.json opens in Perfetto,
    # BENCH_metrics.jsonl is the registry snapshot
    recorder = None
    if args.fast:
        from repro.obs import trace as obs_trace

        recorder = obs_trace.get_recorder().start()

    # reuse the previous run's tuning cache (CI artifact) so jobs running
    # with tuning="cached" skip re-measuring; the kernels job refreshes the
    # winners and the updated cache is uploaded with this run's artifacts
    if args.baseline_dir:
        import shutil

        base_cache = os.path.join(args.baseline_dir, "TUNE_cache.json")
        if os.path.exists(base_cache) and not os.path.exists("TUNE_cache.json"):
            shutil.copy(base_cache, "TUNE_cache.json")
            print("tune.cache,0,reused baseline TUNE_cache.json")

    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            job()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
        print(f"{name}.total_s,{(time.time()-t0)*1e6:.0f},done")

    if recorder is not None:
        from repro.obs import metrics as obs_metrics
        from repro.obs import report as obs_report

        recorder.stop()
        n_spans = recorder.save_chrome_trace("BENCH_trace.json")
        n_series = obs_metrics.registry().write_jsonl("BENCH_metrics.jsonl")
        print(f"obs.trace,0,{n_spans} spans -> BENCH_trace.json")
        print(f"obs.metrics,0,{n_series} series -> BENCH_metrics.jsonl")
        # the self-contained HTML perf report CI uploads with the BENCH
        # artifacts: trajectory tiles, phase breakdown, measured shard skew
        rpt = obs_report.write_report_from_artifacts(
            "BENCH_report.html", recorder=recorder,
            generated=time.strftime("%Y-%m-%d %H:%M UTC", time.gmtime()))
        print(f"obs.report,0,{rpt}")

    if args.baseline_dir:
        from benchmarks import trend

        regressed = trend.compare(args.baseline_dir,
                                  threshold=args.regression_threshold)
        if regressed:
            print(f"trend gate: {regressed} metric(s) regressed > "
                  f"{args.regression_threshold:.0%} vs {args.baseline_dir}",
                  file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
