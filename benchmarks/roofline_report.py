"""Aggregate the dry-run artifacts into the §Roofline table
(EXPERIMENTS.md). Reads artifacts/dryrun/*.json written by launch/dryrun.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load_records(art_dir: str = None):
    if art_dir is None:
        art_dir = ("artifacts/dryrun_v2"
                   if glob.glob("artifacts/dryrun_v2/*.json") else "artifacts/dryrun")
    recs = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs, mesh: str = "pod16x16") -> str:
    rows = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | useful | roofline-frac | temp GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok") or r.get("mesh") != mesh or "roofline" not in r:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.3g} | {rf['t_memory_s']:.3g} "
            f"| {rf['t_collective_s']:.3g} | {rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.4f} | {r['memory']['temp_bytes']/1e9:.1f} |")
    return "\n".join(rows)


def main() -> None:
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    emit("roofline.cells_ok", 0.0, len(ok))
    emit("roofline.cells_failed", 0.0, len(fail))
    for r in ok:
        if "roofline" not in r:
            emit(f"dryrun.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0, "compiled")
            continue
        rf = r["roofline"]
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
             f"bound={rf['bottleneck']} t={rf['t_compute_s']:.3g}/{rf['t_memory_s']:.3g}/"
             f"{rf['t_collective_s']:.3g}s useful={rf['useful_flops_ratio']:.2f}")
    for r in fail:
        emit(f"roofline.FAILED.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
             r.get("error", "?")[:80])


if __name__ == "__main__":
    main()
