"""Paper Table 8: multi-device scaling.

The CPU container multiplexes fake devices onto one core, so wall-clock
"speedup" is meaningless here; what IS measurable and what *drives* the
paper's (super-linear) scaling is the per-device work reduction: FASST's
max device-local edge count divided by sweeps. We report

  modeled_speedup(mu) = work(1) / max_shard_work(mu)

per influence setting (work = edges processed per sweep on the busiest
device), plus the selection-communication bytes that Table 9 shows are
negligible. On real hardware the same harness times the shard_map step.
"""
from __future__ import annotations

from benchmarks.common import SETTING_KEYS, SETTINGS, emit, timed
from repro.core.fasst import build_partition
from repro.core.sampling import make_x_vector
from repro.graphs import rmat_graph
from repro.partition import build_partition_2d, plan_partition, sample_edge_sets


def main(scale: int = 11, registers: int = 1024, backend: str = "auto") -> None:
    x = make_x_vector(registers, seed=8)
    for setting in SETTINGS:
        g = rmat_graph(scale, edge_factor=8, seed=51, setting=SETTING_KEYS[setting])
        base = None
        for mu in (1, 2, 4, 8):
            part, us = timed(build_partition, g, x, mu, method="fasst")
            # per-device work: busiest shard's edge-register pairs, floored
            # by the register-matrix sweep itself (every sweep touches all
            # n x J/mu local registers even when few edges sample)
            j_loc = registers // mu
            edge_work = int(part.edge_counts.max()) * j_loc
            floor = g.n_pad * j_loc
            work = max(edge_work, floor)
            if base is None:
                base = work
            emit(f"table8.mu{mu}.{setting}", us,
                 f"modeled_speedup={base/max(work,1):.2f}x "
                 f"max_shard_edges={int(part.edge_counts.max())} "
                 f"(work-model upper bound; paper measures up to 20.7x)")

    # ---- beyond-paper 2-D scaling: planner strategies at mu_v = 8 ----
    # (full vertex sharding; the sim-only rows above are the paper's mode).
    # The planner bounds the busiest device, so the modeled speedup tracks
    # mean/max edge load instead of the block split's hub shard.
    g2 = rmat_graph(scale, edge_factor=8, seed=51,
                    setting=SETTING_KEYS["0.1"]).sorted_by_dst()
    mu_v = 8
    sampled = sample_edge_sets(g2, x, 1, seed=8)
    for strat in ("block", "degree", "edge"):
        plan = plan_partition(g2, mu_v, mu_s=1, strategy=strat, seed=8,
                              sampled=sampled)
        part2, us = timed(build_partition_2d, g2, x, mu_v, 1, seed=8, plan=plan,
                          sampled=sampled)
        stats = part2.stats()
        busiest = int(part2.edge_counts.max())
        mean = float(part2.edge_counts.mean())
        emit(f"table8.2d.mu{mu_v}.{strat}", us,
             f"modeled_speedup={mean * mu_v / max(busiest, 1):.2f}x "
             f"edge_imb={stats.edge_imbalance:.2f} max_shard_edges={busiest}")

    # ---- measured: the full Alg. 4 loop through the selected runtime
    # backend (auto = mesh when jax + devices allow, else serial) — no
    # hand-rolled mesh setup, the backend owns it
    from repro.runtime import RunSpec, resolve_backend, run as run_im

    k = 4
    spec = RunSpec(num_registers=min(registers, 256), seed=8,
                   backend=backend, mu_v=4, mu_s=2, partition="degree")
    resolved = resolve_backend(spec, g2)
    report = run_im(g2, k, spec)
    emit(f"table8.backend.{resolved.name}", report.wall_s * 1e6,
         f"seeds_per_s={k / max(report.wall_s, 1e-9):.2f} "
         f"grid={spec.mu_v}x{spec.mu_s} (selected via --backend={backend})")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--registers", type=int, default=1024)
    ap.add_argument("--backend", default="auto",
                    help="runtime backend for the measured Alg. 4 row "
                         "(repro.runtime registry)")
    a = ap.parse_args()
    main(scale=a.scale, registers=a.registers, backend=a.backend)
