"""Service throughput: cold-build vs warm-store serving on an RMAT graph,
host-order vs device-resident (shard-local) serving side by side.

    PYTHONPATH=src python -m benchmarks.service_throughput [--scale 14] \
        [--backend auto|host|mesh] [--mu-v 8]

Emits the repo's standard ``name,us_per_call,derived`` CSV rows (the
benchmarks/run.py schema) plus one ``service.json`` row whose derived field
is the full JSON stats blob. Two acceptance metrics:

  * ``service.speedup`` — amortized per-query cost of the 2nd..Nth warm
    query vs repeated cold runs (the PR 1 store claim);
  * ``service.device_vs_host`` — amortized per-query cost of the
    gather-to-host path vs shard-local serving off mesh-placed row blocks
    (> 1 means device residency wins; needs a multi-device mesh, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--out-json BENCH_service.json`` records both for the CI trend gate
(``benchmarks/run.py --fast`` + ``benchmarks/trend.py``).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core.difuser import DiFuserConfig
from repro.runtime import RunSpec, run as run_im
from repro.graphs import rmat_graph
from repro.launch.serve_im import make_workload
from repro.service import (InfluenceEngine, SketchStore, TopKSeeds,
                           summarize_latencies)


def _serve_workload(engine, key, g, num_queries, k, seed):
    """Push the standard mixed workload through the engine; returns
    (wall_s, stats). Warms the jit caches with one TopKSeeds first and
    clears the memo so the timed top-k queries execute for real."""
    warm = engine(key, TopKSeeds(k)).value
    engine.clear_topk_memo()
    for q in make_workload(g.n, num_queries, k=k, seed=seed):
        engine.submit(key, q)
    t0 = time.perf_counter()
    results = engine.run()
    wall_s = time.perf_counter() - t0
    return warm, wall_s, summarize_latencies(results)


def _device_placement_ok(mu_v: int):
    """(ok, reason) for shard-local serving on this host."""
    from repro.utils.jax_compat import JAX_HAS_AXIS_TYPE

    if not JAX_HAS_AXIS_TYPE:
        return False, "jax.sharding.AxisType missing (old jax)"
    import jax

    if len(jax.devices()) < mu_v:
        return False, (f"{mu_v} row blocks need {mu_v} devices, have "
                       f"{len(jax.devices())} (export XLA_FLAGS="
                       f"--xla_force_host_platform_device_count={mu_v})")
    return True, ""


def main(scale: int = 14, *, registers: int = 256, k: int = 10,
         num_queries: int = 200, seed: int = 0, backend: str = "auto",
         mu_v: int = 8, out_json: str = "") -> dict:
    g = rmat_graph(scale, edge_factor=8, seed=seed, setting="w1")
    cfg = DiFuserConfig(num_registers=registers, seed=seed)

    # cold: what every query costs without the store (build + rounds)
    t0 = time.perf_counter()
    cold = run_im(g, k, RunSpec.from_config(cfg, backend="single")).result
    cold_s = time.perf_counter() - t0
    emit(f"service.cold_find_seeds.n{g.n}", cold_s * 1e6, cold.propagate_iters)

    store = SketchStore()
    engine = InfluenceEngine(store)
    t0 = time.perf_counter()
    key = engine.register(g, cfg)
    build_s = time.perf_counter() - t0
    emit(f"service.store_build.n{g.n}", build_s * 1e6,
         store.entry(key).build_iters)

    # ---- host-order serving (the single/serial fallback path) ----
    host_stats = device_stats = None
    device_skip = ""
    if backend != "mesh":
        warm, host_wall, host_stats = _serve_workload(
            engine, key, g, num_queries, k, seed + 7)
        assert np.array_equal(warm.seeds, cold.seeds), "warm/cold seed mismatch"
        host_amort = host_wall / num_queries
        emit(f"service.warm_query.n{g.n}", host_amort * 1e6,
             f"{host_stats['qps']:.0f}qps")
        emit(f"service.p50.n{g.n}", host_stats["p50_ms"] * 1e3, "")
        emit(f"service.p99.n{g.n}", host_stats["p99_ms"] * 1e3, "")
        emit(f"service.speedup.n{g.n}", host_amort * 1e6,
             f"{cold_s / host_amort:.1f}x")
        host_stats = {**host_stats, "wall_s": host_wall,
                      "amortized_s": host_amort,
                      "qps": num_queries / host_wall,
                      "speedup_vs_cold": cold_s / host_amort}

    # ---- device-resident serving (shard-local reductions on the mesh) ----
    if backend in ("auto", "mesh"):
        ok, why = _device_placement_ok(mu_v)
        if not ok:
            device_skip = why
            emit(f"service.device.n{g.n}", 0.0, f"skipped: {why}")
            if backend == "mesh":
                raise SystemExit(f"--backend mesh: {why}")
        else:
            from repro.launch.mesh import make_serving_mesh
            from repro.partition import plan_partition

            entry = store.entry(key)
            t0 = time.perf_counter()
            plan = plan_partition(entry.graph, mu_v, mu_s=1, x=entry.x,
                                  seed=seed, model=cfg.model)
            store.attach_plan(key, plan)
            entry.place_on_mesh(make_serving_mesh(mu_v))
            place_s = time.perf_counter() - t0
            emit(f"service.device_place.n{g.n}", place_s * 1e6,
                 f"{mu_v} row blocks")
            engine.clear_topk_memo()
            warm_d, dev_wall, device_stats = _serve_workload(
                engine, key, g, num_queries, k, seed + 7)
            assert np.array_equal(warm_d.seeds, cold.seeds), \
                "device warm/cold seed mismatch"
            dev_amort = dev_wall / num_queries
            emit(f"service.device.warm_query.n{g.n}", dev_amort * 1e6,
                 f"{device_stats['qps']:.0f}qps")
            emit(f"service.device.p50.n{g.n}",
                 device_stats["p50_ms"] * 1e3, "")
            emit(f"service.device.p99.n{g.n}",
                 device_stats["p99_ms"] * 1e3, "")
            device_stats = {**device_stats, "wall_s": dev_wall,
                            "amortized_s": dev_amort,
                            "qps": num_queries / dev_wall,
                            "speedup_vs_cold": cold_s / dev_amort,
                            "mu_v": mu_v, "place_s": place_s}
            if host_stats is not None:
                ratio = host_stats["amortized_s"] / dev_amort
                emit(f"service.device_vs_host.n{g.n}", dev_amort * 1e6,
                     f"{ratio:.2f}x")

    out = {"n": g.n, "m": g.m_real, "registers": registers, "k": k,
           "num_queries": num_queries, "cold_s": cold_s, "build_s": build_s,
           "host": host_stats, "device": device_stats,
           "device_skip": device_skip}
    if host_stats is not None:
        # the legacy top-level fields (older BENCH baselines / table tooling)
        out.update(wall_s=host_stats["wall_s"],
                   amortized_s=host_stats["amortized_s"],
                   speedup=host_stats["speedup_vs_cold"],
                   qps=host_stats["qps"])
    if host_stats is not None and device_stats is not None:
        out["device_vs_host"] = (host_stats["amortized_s"]
                                 / device_stats["amortized_s"])
    emit("service.json", (out.get("wall_s", 0.0)) * 1e6, json.dumps(out))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
        emit("service.out_json", 0.0, out_json)
    return out


if __name__ == "__main__":
    from repro.launch.common import add_obs_args, observe

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--registers", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "host", "mesh"],
                    help="auto: host rows + device rows when a mesh is "
                         "available; host/mesh: that path only")
    ap.add_argument("--mu-v", type=int, default=8,
                    help="row blocks (devices) of the serving mesh")
    ap.add_argument("--out-json", default="")
    add_obs_args(ap)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    with observe(args):
        main(args.scale, registers=args.registers, k=args.k,
             num_queries=args.queries, backend=args.backend, mu_v=args.mu_v,
             out_json=args.out_json)
