"""Service throughput: cold-build vs warm-store serving on an RMAT graph.

    PYTHONPATH=src python -m benchmarks.service_throughput [--scale 14]

Emits the repo's standard ``name,us_per_call,derived`` CSV rows (the
benchmarks/run.py schema) plus one ``service.json`` row whose derived field
is the full JSON stats blob. The acceptance metric is ``service.speedup``:
amortized per-query cost of the 2nd..Nth warm query vs repeated cold runs.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core.difuser import DiFuserConfig
from repro.runtime import RunSpec, run as run_im
from repro.graphs import rmat_graph
from repro.launch.serve_im import make_workload
from repro.service import (InfluenceEngine, SketchStore, TopKSeeds,
                           summarize_latencies)


def main(scale: int = 14, *, registers: int = 256, k: int = 10,
         num_queries: int = 200, seed: int = 0) -> dict:
    g = rmat_graph(scale, edge_factor=8, seed=seed, setting="w1")
    cfg = DiFuserConfig(num_registers=registers, seed=seed)

    # cold: what every query costs without the store (build + rounds)
    t0 = time.perf_counter()
    cold = run_im(g, k, RunSpec.from_config(cfg, backend="single")).result
    cold_s = time.perf_counter() - t0
    emit(f"service.cold_find_seeds.n{g.n}", cold_s * 1e6, cold.propagate_iters)

    store = SketchStore()
    engine = InfluenceEngine(store)
    t0 = time.perf_counter()
    key = engine.register(g, cfg)
    build_s = time.perf_counter() - t0
    emit(f"service.store_build.n{g.n}", build_s * 1e6,
         store.entry(key).build_iters)

    # warm: the 1st query eats jit compiles; report 2nd..Nth amortized
    warm = engine(key, TopKSeeds(k)).value
    assert np.array_equal(warm.seeds, cold.seeds), "warm/cold seed mismatch"
    # drop the memo this check just populated: the timed workload below must
    # execute its top-k queries for real, not serve them as 0-cost cache hits
    engine.clear_topk_memo()

    for q in make_workload(g.n, num_queries, k=k, seed=seed + 7):
        engine.submit(key, q)
    t0 = time.perf_counter()
    results = engine.run()
    wall_s = time.perf_counter() - t0
    stats = summarize_latencies(results)

    amortized_s = wall_s / num_queries
    speedup = cold_s / amortized_s
    emit(f"service.warm_query.n{g.n}", amortized_s * 1e6,
         f"{stats['qps']:.0f}qps")
    emit(f"service.p50.n{g.n}", stats["p50_ms"] * 1e3, "")
    emit(f"service.p99.n{g.n}", stats["p99_ms"] * 1e3, "")
    emit(f"service.speedup.n{g.n}", amortized_s * 1e6, f"{speedup:.1f}x")

    out = {"n": g.n, "m": g.m_real, "registers": registers, "k": k,
           "num_queries": num_queries, "cold_s": cold_s, "build_s": build_s,
           "wall_s": wall_s, "amortized_s": amortized_s, "speedup": speedup,
           **stats}
    emit("service.json", wall_s * 1e6, json.dumps(out))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--registers", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=200)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(args.scale, registers=args.registers, k=args.k,
         num_queries=args.queries)
